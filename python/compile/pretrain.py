"""Build-time MLM pretraining: manufactures the frozen base weights.

The paper fine-tunes Hugging Face checkpoints (RoBERTa/DeBERTa/Llama2);
offline we create the pretrained base ourselves by running a short
masked-LM pass over the synthetic corpus (DESIGN.md §2). This runs once
inside ``make artifacts`` and its output is cached in
``artifacts/base_weights.bin``.
"""

from __future__ import annotations

import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from . import configs, datagen, model
from .configs import ModelConfig


def pretrain_base(cfg: ModelConfig, steps: int = 300, batch: int = 16,
                  lr: float = 3e-4, seed: int = 7,
                  log_every: int = 50) -> Dict[str, jnp.ndarray]:
    """Returns the pretrained base parameter dict (BASE_ORDER keys)."""
    spec = configs.task_spec()
    rng = np.random.default_rng(seed)
    base = model.init_base(cfg, jax.random.PRNGKey(seed))
    opt = model.init_opt(base)
    step_fn = jax.jit(model.make_pretrain_step(cfg))

    mask_id = spec["special"]["mask"]
    pad_id = spec["special"]["pad"]
    t0 = time.time()
    losses = []
    for t in range(1, steps + 1):
        toks = datagen.corpus_batch(spec, batch, rng)
        # Sentences are generated at cfg seq_len via the spec; clip in
        # case cfg.seq_len differs from the spec (e.g. LARGE config).
        if toks.shape[1] != cfg.seq_len:
            toks = toks[:, :cfg.seq_len]
        inp, tgt, mm = datagen.mlm_mask_batch(toks, rng, mask_id, pad_id)
        # Cosine LR decay with short warmup.
        warm = min(1.0, t / 30.0)
        cos = 0.5 * (1.0 + np.cos(np.pi * t / steps))
        cur_lr = lr * warm * (0.1 + 0.9 * cos)
        base, opt, loss = step_fn(base, opt, jnp.asarray(inp),
                                  jnp.asarray(tgt), jnp.asarray(mm),
                                  cur_lr, float(t))
        losses.append(float(loss))
        if log_every and t % log_every == 0:
            avg = sum(losses[-log_every:]) / log_every
            print(f"[pretrain] step {t}/{steps} mlm-loss {avg:.4f} "
                  f"({time.time() - t0:.1f}s)", flush=True)
    return base


def save_base(base: Dict[str, jnp.ndarray], path: str) -> int:
    """Raw little-endian f32 concat in BASE_ORDER; returns bytes written."""
    chunks = [np.asarray(base[n], dtype=np.float32).ravel()
              for n in model.BASE_ORDER]
    flat = np.concatenate(chunks)
    flat.astype("<f4").tofile(path)
    return flat.nbytes


def load_base(cfg: ModelConfig, path: str) -> Dict[str, jnp.ndarray]:
    flat = np.fromfile(path, dtype="<f4")
    shapes = model.base_shapes(cfg)
    out, off = {}, 0
    for n in model.BASE_ORDER:
        size = int(np.prod(shapes[n]))
        out[n] = jnp.asarray(flat[off:off + size].reshape(shapes[n]))
        off += size
    assert off == flat.size, f"base_weights.bin size mismatch: {off} vs {flat.size}"
    return out
