"""Model and task configuration shared across the compile path.

Everything here is *build-time* configuration: the model architecture
that gets lowered to HLO, and the synthetic-task grammar spec that is
serialized into ``artifacts/vocab.json`` so the rust data generators
(`rust/src/data/`) produce token streams from exactly the same vocab
layout the python pretraining corpus used.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """RoBERTa-style encoder classifier, stacked-layer layout.

    The federated experiments fine-tune LoRA adapters (padded to
    ``r_max`` per layer — see DESIGN.md "masking trick") and the
    classification head on top of a frozen base pretrained by
    ``pretrain.py``.
    """

    n_layers: int = 12          # L — matches RoBERTa-base used in the paper
    d_model: int = 128          # scaled for the single-core CPU testbed
    n_heads: int = 4
    d_ffn: int = 512
    vocab_size: int = 2048
    seq_len: int = 32
    n_classes: int = 4          # superset head: binary tasks use labels {0,1}
    r_max: int = 16             # LoRA rank padding (>= any assigned rank)
    lora_alpha: float = 16.0
    adapter_w_max: int = 32     # FedAdapter bottleneck width padding
    batch_size: int = 4         # matches the paper's on-device batch size
    dtype: str = "float32"

    # AdamW hyper-parameters baked into the train-step artifact.
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.01

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def to_json_dict(self) -> Dict:
        return dataclasses.asdict(self)


# The configuration every artifact in artifacts/ is lowered with.
DEFAULT = ModelConfig()

# A tiny config for fast unit tests (never lowered to artifacts).
TINY = ModelConfig(
    n_layers=2, d_model=16, n_heads=2, d_ffn=32, vocab_size=128,
    seq_len=8, n_classes=4, r_max=4, adapter_w_max=8, batch_size=2,
)

# A larger config exercised by the e2e example (see EXPERIMENTS.md) to
# demonstrate the stack scales beyond the default experiment size.
LARGE = ModelConfig(
    n_layers=12, d_model=256, n_heads=8, d_ffn=1024, vocab_size=4096,
    seq_len=32, r_max=16,
)


# ---------------------------------------------------------------------------
# Vocab layout + synthetic task grammars (shared spec with rust/src/data/)
# ---------------------------------------------------------------------------

PAD, CLS, MASK, SEP = 0, 1, 2, 3

# Reserved special tokens occupy [0, 4); filler (function) words occupy
# [4, 4+N_FILLER); task-specific word banks follow.
N_FILLER = 100
FILLER = (4, 4 + N_FILLER)          # half-open id range

_next = FILLER[1]


def _bank(size: int) -> Tuple[int, int]:
    global _next
    lo, hi = _next, _next + size
    _next = hi
    return (lo, hi)


# Sentiment banks (sst2-syn): 50 "positive" / 50 "negative" words.
SST2_POS = _bank(50)
SST2_NEG = _bank(50)

# Entailment indicator banks (qnli-syn / mnli-syn / qqp-syn share the
# pair-grammar; each task gets its own banks so the tasks are distinct).
QNLI_ENT = _bank(40)
QNLI_CON = _bank(40)
QQP_DUP = _bank(40)
QQP_NODUP = _bank(40)
MNLI_ENT = _bank(40)
MNLI_NEU = _bank(40)

# Topic banks (mmlu-syn): 4 academic-domain banks.
MMLU_TOPICS = [_bank(40) for _ in range(4)]

# Digit / operator tokens (gsm-syn).
DIGITS = _bank(10)     # token DIGITS[0]+d encodes digit d
OPS = _bank(3)         # +, -, *

NOISE = (_next, DEFAULT.vocab_size)   # everything else is noise vocab

assert _next < DEFAULT.vocab_size, "vocab too small for the banks"


def task_spec() -> Dict:
    """The grammar spec serialized to artifacts/vocab.json.

    rust/src/data/grammar.rs consumes this verbatim; any change here
    must keep the schema stable (see rust-side tests).
    """
    return {
        "vocab_size": DEFAULT.vocab_size,
        "seq_len": DEFAULT.seq_len,
        "special": {"pad": PAD, "cls": CLS, "mask": MASK, "sep": SEP},
        "filler": list(FILLER),
        "noise": list(NOISE),
        "tasks": {
            "sst2": {
                "kind": "single",
                "n_classes": 2,
                "banks": [list(SST2_POS), list(SST2_NEG)],
                "len_range": [8, 24],
                "bank_words": [3, 6],
                "label_noise": 0.02,
            },
            "qnli": {
                "kind": "pair",
                "n_classes": 2,
                "banks": [list(QNLI_ENT), list(QNLI_CON)],
                "len_range": [6, 14],
                "bank_words": [2, 5],
                "label_noise": 0.03,
            },
            "qqp": {
                "kind": "pair",
                "n_classes": 2,
                "banks": [list(QQP_DUP), list(QQP_NODUP)],
                "len_range": [6, 14],
                "bank_words": [2, 5],
                "label_noise": 0.03,
            },
            "mnli": {
                "kind": "pair",
                "n_classes": 2,
                "banks": [list(MNLI_ENT), list(MNLI_NEU)],
                "len_range": [6, 14],
                "bank_words": [2, 5],
                "label_noise": 0.03,
            },
            "mmlu": {
                "kind": "single",
                "n_classes": 4,
                "banks": [list(b) for b in MMLU_TOPICS],
                "len_range": [8, 24],
                "bank_words": [3, 6],
                "label_noise": 0.05,
            },
            "gsm": {
                "kind": "arith",
                "n_classes": 4,
                "digits": list(DIGITS),
                "ops": list(OPS),
                "n_terms": 3,
                "label_noise": 0.0,
            },
        },
    }
