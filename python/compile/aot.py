"""AOT compile path: lower every executable the rust runtime needs.

Emits HLO **text** (not serialized HloModuleProto): jax ≥ 0.5 writes
protos with 64-bit instruction ids that the `xla` crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Artifacts written to --out-dir (default ../artifacts):
  lora_train.hlo.txt     masked LoRA AdamW train step (all LoRA methods)
  lora_eval.hlo.txt      eval step (loss_sum, correct) for the LoRA family
  adapter_train.hlo.txt  FedAdapter family train step
  adapter_eval.hlo.txt   FedAdapter family eval step
  lora_kernel.hlo.txt    the L1 Pallas fused LoRA-linear (interpret) —
                         loaded by examples/quickstart.rs to prove the
                         three layers compose
  base_weights.bin       MLM-pretrained frozen base (f32, BASE_ORDER)
  manifest.json          tensor names/shapes/orderings + model config
  vocab.json             synthetic-task grammar spec for rust/src/data/

Python runs ONCE here; the rust binary is self-contained afterwards.
"""

from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import configs, model, pretrain
from .configs import ModelConfig

EVAL_BATCH = 64


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _named(shapes: Dict[str, tuple], order: List[str]):
    return [{"name": n, "shape": list(shapes[n])} for n in order]


def lower_family(cfg: ModelConfig, family: str):
    """Returns (train_hlo_text, eval_hlo_text, manifest_fragment)."""
    t_order = model.LORA_ORDER if family == "lora" else model.ADAPTER_ORDER
    t_shapes = (model.lora_shapes(cfg) if family == "lora"
                else model.adapter_shapes(cfg))
    b_shapes = model.base_shapes(cfg)
    o_order = model.opt_order(family)
    L = cfg.n_layers
    r = cfg.r_max if family == "lora" else cfg.adapter_w_max

    nb, nt = len(model.BASE_ORDER), len(t_order)
    no = len(o_order)
    train_step = model.make_train_step(cfg, family=family)
    eval_step = model.make_eval_step(cfg, family=family)

    def train_flat(*args):
        base = model.unflatten_base(args[:nb])
        trainable = model.unflatten_trainable(args[nb:nb + nt], family)
        opt = model.unflatten_opt(args[nb + nt:nb + nt + no], family)
        rank_mask, layer_mask, tokens, labels, lr, step = \
            args[nb + nt + no:]
        new_t, new_o, loss, correct = train_step(
            base, trainable, opt, rank_mask, layer_mask, tokens, labels,
            lr, step)
        return (tuple(model.flatten_trainable(new_t, family))
                + tuple(model.flatten_opt(new_o, family))
                + (loss, correct))

    def eval_flat(*args):
        base = model.unflatten_base(args[:nb])
        trainable = model.unflatten_trainable(args[nb:nb + nt], family)
        rank_mask, layer_mask, tokens, labels = args[nb + nt:]
        return eval_step(base, trainable, rank_mask, layer_mask, tokens,
                         labels)

    base_specs = [_spec(b_shapes[n]) for n in model.BASE_ORDER]
    t_specs = [_spec(t_shapes[n]) for n in t_order]
    o_specs = [_spec(t_shapes[n[2:]]) for n in o_order]
    mask_specs = [_spec((L, r)), _spec((L,))]
    train_batch = [_spec((cfg.batch_size, cfg.seq_len), jnp.int32),
                   _spec((cfg.batch_size,), jnp.int32)]
    eval_batch = [_spec((EVAL_BATCH, cfg.seq_len), jnp.int32),
                  _spec((EVAL_BATCH,), jnp.int32)]
    scalar = [_spec((), jnp.float32), _spec((), jnp.float32)]

    t0 = time.time()
    train_lowered = jax.jit(train_flat).lower(
        *(base_specs + t_specs + o_specs + mask_specs + train_batch
          + scalar))
    train_txt = to_hlo_text(train_lowered)
    eval_lowered = jax.jit(eval_flat).lower(
        *(base_specs + t_specs + mask_specs + eval_batch))
    eval_txt = to_hlo_text(eval_lowered)
    print(f"[aot] lowered {family} train+eval in {time.time()-t0:.1f}s "
          f"({len(train_txt)/1e6:.2f} MB + {len(eval_txt)/1e6:.2f} MB)",
          flush=True)

    frag = {
        "trainable": _named(t_shapes, t_order),
        "opt": o_order,
        "train": {
            "artifact": f"{family}_train.hlo.txt",
            "inputs": (list(model.BASE_ORDER) + t_order + o_order
                       + ["rank_mask", "layer_mask", "tokens", "labels",
                          "lr", "step"]),
            "outputs": t_order + o_order + ["loss", "correct"],
        },
        "eval": {
            "artifact": f"{family}_eval.hlo.txt",
            "inputs": (list(model.BASE_ORDER) + t_order
                       + ["rank_mask", "layer_mask", "tokens", "labels"]),
            "outputs": ["loss_sum", "correct"],
        },
    }
    return train_txt, eval_txt, frag


def lower_kernel(cfg: ModelConfig):
    """Lower the Pallas fused LoRA-linear (the L1 compose proof)."""
    from .kernels import lora as klora

    m, k, n, r = 64, cfg.d_model, cfg.d_model, cfg.r_max

    def kernel_fn(x, w, a, b, mask, scale):
        return (klora.lora_linear(x, w, a, b, mask, scale[0],
                                  block_m=32, block_n=64),)

    lowered = jax.jit(kernel_fn).lower(
        _spec((m, k)), _spec((k, n)), _spec((r, k)), _spec((n, r)),
        _spec((r,)), _spec((1,)))
    txt = to_hlo_text(lowered)
    frag = {
        "artifact": "lora_kernel.hlo.txt",
        "shapes": {"x": [m, k], "w": [k, n], "a": [r, k], "b": [n, r],
                   "mask": [r], "scale": [1]},
    }
    return txt, frag


def dump_stats(out_dir: str) -> None:
    """Per-artifact HLO stats for DESIGN §Perf (fusion sanity check)."""
    for f in sorted(os.listdir(out_dir)):
        if not f.endswith(".hlo.txt"):
            continue
        txt = open(os.path.join(out_dir, f)).read()
        n_instr = txt.count("\n  ")
        n_fusion = txt.count(" fusion(")
        n_dot = txt.count(" dot(")
        n_while = txt.count(" while(")
        print(f"[stats] {f}: {len(txt)/1e6:.2f} MB, ~{n_instr} instrs, "
              f"{n_dot} dots, {n_fusion} fusions, {n_while} whiles")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--config", default="default",
                    choices=["default", "tiny", "large"])
    ap.add_argument("--pretrain-steps", type=int, default=300)
    ap.add_argument("--force-pretrain", action="store_true")
    ap.add_argument("--skip-pretrain", action="store_true",
                    help="random base (tests only; accuracy won't climb)")
    ap.add_argument("--dump-stats", action="store_true")
    args = ap.parse_args()

    cfg = {"default": configs.DEFAULT, "tiny": configs.TINY,
           "large": configs.LARGE}[args.config]
    out = args.out_dir
    os.makedirs(out, exist_ok=True)

    # 1. Frozen base: pretrain (or random for smoke tests). Cached —
    # the pretraining corpus/model init do not depend on the train-step
    # code, so an existing base_weights.bin of the right size is reused
    # unless --force-pretrain is passed.
    base_path = os.path.join(out, "base_weights.bin")
    expect_bytes = 4 * sum(
        int(np.prod(s)) for s in model.base_shapes(cfg).values())
    if (not args.force_pretrain and not args.skip_pretrain
            and os.path.exists(base_path)
            and os.path.getsize(base_path) == expect_bytes):
        print(f"[aot] reusing cached {base_path}")
        n_bytes = expect_bytes
    else:
        if args.skip_pretrain:
            print("[aot] skipping pretraining (random base)")
            base = model.init_base(cfg, jax.random.PRNGKey(7))
        else:
            print(f"[aot] pretraining base ({args.pretrain_steps} steps)...")
            base = pretrain.pretrain_base(cfg, steps=args.pretrain_steps)
        n_bytes = pretrain.save_base(base, base_path)
        print(f"[aot] wrote {base_path} ({n_bytes/1e6:.1f} MB)")

    # 2. Lower both model families + the Pallas kernel.
    families = {}
    for family in ("lora", "adapter"):
        train_txt, eval_txt, frag = lower_family(cfg, family)
        with open(os.path.join(out, f"{family}_train.hlo.txt"), "w") as f:
            f.write(train_txt)
        with open(os.path.join(out, f"{family}_eval.hlo.txt"), "w") as f:
            f.write(eval_txt)
        families[family] = frag

    kern_txt, kern_frag = lower_kernel(cfg)
    with open(os.path.join(out, "lora_kernel.hlo.txt"), "w") as f:
        f.write(kern_txt)

    # 3. Manifest + grammar spec.
    manifest = {
        "version": 1,
        "model": cfg.to_json_dict(),
        "eval_batch": EVAL_BATCH,
        "base": _named(model.base_shapes(cfg), model.BASE_ORDER),
        "base_bytes": n_bytes,
        "families": families,
        "kernel": kern_frag,
    }
    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    with open(os.path.join(out, "vocab.json"), "w") as f:
        json.dump(configs.task_spec(), f, indent=1)
    print(f"[aot] wrote manifest.json + vocab.json to {out}")

    if args.dump_stats:
        dump_stats(out)


if __name__ == "__main__":
    main()
