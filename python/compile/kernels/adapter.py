"""L1: fused bottleneck-adapter Pallas kernel (FedAdapter family).

Same TPU framing as the LoRA kernel (see lora.py): grid over [bm, D]
activation strips; the bottleneck factors (w_max ≤ 32) stay
VMEM-resident; width masking in-register so one kernel serves every
FedAdapter width candidate. The adapter is residual
(`y = x + gelu(x·(d⊙m)+b)·(u⊙m)`), matching ref.adapter_ref and the
L2 model's adapter branch.

interpret=True on CPU (Mosaic custom-calls need a real TPU plugin).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _adapter_kernel(x_ref, down_ref, up_ref, b_ref, mask_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)            # [bm, D]
    mask = mask_ref[...].astype(jnp.float32)      # [w_max]
    down = down_ref[...].astype(jnp.float32) * mask[None, :]  # [D, w]
    up = up_ref[...].astype(jnp.float32) * mask[:, None]      # [w, D]
    b = b_ref[...].astype(jnp.float32)

    h = jax.lax.dot_general(
        x, down, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)       # [bm, w]
    h = jax.nn.gelu(h + b[None, :]) * mask[None, :]
    y = jax.lax.dot_general(
        h, up, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)       # [bm, D]
    o_ref[...] = x + y


@functools.partial(jax.jit, static_argnames=("block_m",))
def adapter_forward(x, down, up, b_down, width_mask, *, block_m=128):
    """Fused residual adapter via Pallas. See ``ref.adapter_ref``.

    Args:
      x: [M, D]; down: [D, w_max]; up: [w_max, D]; b_down: [w_max];
      width_mask: [w_max] {0,1}.

    Returns: [M, D] f32.
    """
    m, d = x.shape
    w = down.shape[1]
    assert down.shape == (d, w)
    assert up.shape == (w, d)
    assert b_down.shape == (w,)
    assert width_mask.shape == (w,)

    bm = min(block_m, m)
    mp = -(-m // bm) * bm
    xp = jnp.pad(x, ((0, mp - m), (0, 0))) if mp != m else x
    grid = (mp // bm,)
    out = pl.pallas_call(
        _adapter_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d), lambda i: (i, 0)),    # x strip
            pl.BlockSpec((d, w), lambda i: (0, 0)),     # down resident
            pl.BlockSpec((w, d), lambda i: (0, 0)),     # up resident
            pl.BlockSpec((w,), lambda i: (0,)),         # bias
            pl.BlockSpec((w,), lambda i: (0,)),         # width mask
        ],
        out_specs=pl.BlockSpec((bm, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, d), jnp.float32),
        interpret=True,
    )(xp, down, up, b_down, width_mask.astype(jnp.float32))
    return out[:m]


def vmem_bytes(block_m, d, w_max, dtype_bytes=4):
    """Static VMEM footprint per program (DESIGN §Perf)."""
    return dtype_bytes * (
        2 * block_m * d      # x strip + out
        + 2 * d * w_max      # down + up
        + block_m * w_max    # bottleneck intermediate
        + 2 * w_max          # bias + mask
    )
