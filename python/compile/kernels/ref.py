"""Pure-jnp oracles for the Pallas kernels.

These are the *correctness references*: the Pallas kernels in
``lora.py`` must match them (pytest + hypothesis sweep shapes, ranks,
masks and dtypes), and the L2 model uses these same formulas on its
default (non-pallas) path, so kernel==ref also proves kernel==model.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def lora_linear_ref(x, w, a, b, rank_mask, scale):
    """Reference fused LoRA linear: ``y = x·w + scale·((x·(m⊙a)ᵀ)·(m⊙b)ᵀ)``.

    Args:
      x:  [M, K] activations.
      w:  [K, N] frozen base weight.
      a:  [r_max, K] LoRA project-down factor (rows past the active rank
          are padding).
      b:  [N, r_max] LoRA project-up factor (columns past the active
          rank are padding).
      rank_mask: [r_max] {0,1} — 1 marks an active rank slot. Encodes
          any per-layer rank ≤ r_max (DESIGN.md "masking trick").
      scale: scalar LoRA scaling (α / r_effective).

    Returns:
      [M, N] output in f32.
    """
    xf = x.astype(jnp.float32)
    low = xf @ (a * rank_mask[:, None]).astype(jnp.float32).T      # [M, r]
    bypass = low @ (b * rank_mask[None, :]).astype(jnp.float32).T  # [M, N]
    return xf @ w.astype(jnp.float32) + scale * bypass


def adapter_ref(x, down, up, b_down, width_mask):
    """Reference bottleneck adapter: ``y = x + gelu(x·(d⊙m)+b)·(u⊙m)``.

    Args:
      x: [M, D] activations.
      down: [D, w_max] down-projection.
      up: [w_max, D] up-projection.
      b_down: [w_max] bottleneck bias.
      width_mask: [w_max] {0,1} active-width mask.

    Returns:
      [M, D] residual-added output in f32.
    """
    xf = x.astype(jnp.float32)
    h = xf @ (down * width_mask[None, :]).astype(jnp.float32)
    h = jax.nn.gelu(h + b_down.astype(jnp.float32)) * width_mask[None, :]
    return xf + h @ (up * width_mask[:, None]).astype(jnp.float32)


def effective_rank(rank_mask):
    """Number of active rank slots (≥1 to keep α/r finite)."""
    return jnp.maximum(rank_mask.sum(), 1.0)
