"""L1: fused LoRA-linear Pallas kernel.

The paper's per-device compute hot-spot is the LoRA bypass fused into
every adapted linear layer: ``y = x·W + (α/r)·B(Ax)``. On the paper's
Jetson GPUs that fusion is a CUDA threadblock tiling; here we re-think
it for the TPU memory hierarchy (DESIGN.md §Hardware-Adaptation):

  * grid over (M/bm, N/bn) *output* tiles — each program owns one
    [bm, bn] MXU-shaped tile of y;
  * the [bm, K] activation strip and [K, bn] weight strip stream
    HBM→VMEM per program (BlockSpec index maps below express exactly
    the schedule a CUDA kernel would do with cp.async);
  * the LoRA factors are tiny (r_max ≤ 16), so the [r_max, K] A strip
    and [bn, r_max] B strip stay VMEM-resident and the bypass never
    round-trips to HBM — this is the fusion the paper gets from
    running LoRA "for free" inside the frozen matmul's pass;
  * rank masking happens in-register: padded rank slots multiply by 0,
    which is how one artifact serves every rank distribution.

On CPU we must run ``interpret=True`` (real TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute); numerics are verified
against ``ref.lora_linear_ref`` by pytest + hypothesis.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _lora_linear_kernel(x_ref, w_ref, a_ref, b_ref, mask_ref, scale_ref,
                        o_ref):
    """One [bm, bn] output tile of y = x·w + scale·(x·(m⊙a)ᵀ)·(m⊙b)ᵀ."""
    x = x_ref[...].astype(jnp.float32)            # [bm, K]   VMEM
    w = w_ref[...].astype(jnp.float32)            # [K, bn]   VMEM
    mask = mask_ref[...].astype(jnp.float32)      # [r_max]
    a = a_ref[...].astype(jnp.float32) * mask[:, None]   # [r_max, K]
    b = b_ref[...].astype(jnp.float32) * mask[None, :]   # [bn, r_max]
    scale = scale_ref[0]

    # Base path: MXU matmul, f32 accumulation.
    acc = jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)       # [bm, bn]

    # Low-rank bypass: two skinny matmuls, fully VMEM-resident.
    low = jax.lax.dot_general(
        x, a, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)       # [bm, r_max]
    byp = jax.lax.dot_general(
        low, b, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)       # [bm, bn]

    o_ref[...] = acc + scale * byp


@functools.partial(jax.jit, static_argnames=("block_m", "block_n"))
def lora_linear(x, w, a, b, rank_mask, scale, *, block_m=128, block_n=128):
    """Fused LoRA linear via Pallas. See ``ref.lora_linear_ref``.

    Args:
      x: [M, K]; w: [K, N]; a: [r_max, K]; b: [N, r_max];
      rank_mask: [r_max] {0,1}; scale: scalar f32.
      block_m/block_n: output tile shape (clamped to M/N).

    Returns: [M, N] f32.
    """
    m, k = x.shape
    kw, n = w.shape
    assert k == kw, f"inner dims disagree: {k} vs {kw}"
    r_max = a.shape[0]
    assert a.shape == (r_max, k)
    assert b.shape == (n, r_max)
    assert rank_mask.shape == (r_max,)

    bm = min(block_m, m)
    bn = min(block_n, n)
    # Pad M/N up to tile multiples; padded rows/cols are sliced off.
    mp = -(-m // bm) * bm
    np_ = -(-n // bn) * bn
    xp = jnp.pad(x, ((0, mp - m), (0, 0))) if mp != m else x
    wp = jnp.pad(w, ((0, 0), (0, np_ - n))) if np_ != n else w
    bp = jnp.pad(b, ((0, np_ - n), (0, 0))) if np_ != n else b

    scale_arr = jnp.asarray([scale], dtype=jnp.float32)
    grid = (mp // bm, np_ // bn)
    out = pl.pallas_call(
        _lora_linear_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),      # x strip
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),      # w strip
            pl.BlockSpec((r_max, k), lambda i, j: (0, 0)),   # A resident
            pl.BlockSpec((bn, r_max), lambda i, j: (j, 0)),  # B strip
            pl.BlockSpec((r_max,), lambda i, j: (0,)),       # mask
            pl.BlockSpec((1,), lambda i, j: (0,)),           # scale
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(xp, wp, a, bp, rank_mask.astype(jnp.float32), scale_arr)
    return out[:m, :n]


def vmem_bytes(block_m, block_n, k, r_max, dtype_bytes=4):
    """Static VMEM footprint estimate for one program (DESIGN §Perf).

    x strip + w strip + A + B strip + out tile + f32 accumulators.
    """
    return dtype_bytes * (
        block_m * k          # x
        + k * block_n        # w
        + r_max * k          # a
        + block_n * r_max    # b
        + block_m * block_n  # out
        + block_m * r_max    # low-rank intermediate
    )


def mxu_utilization_estimate(m, n, k, r_max, block_m=128, block_n=128):
    """Fraction of MXU-issue slots doing useful work, vs 128×128 tiles.

    The base matmul dominates; the bypass adds 2·M·r·(K+N) MACs. Tiles
    whose edges are padded waste (tile - actual) lanes.
    """
    useful = m * n * k + m * r_max * (k + n)
    mp = -(-m // block_m) * block_m
    np_ = -(-n // block_n) * block_n
    issued = mp * np_ * k + mp * r_max * (k + np_)
    return useful / issued
