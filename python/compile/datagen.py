"""Python-side synthetic task generator (build-time only).

Generates the MLM pretraining corpus from the same vocab layout /
grammar spec that the rust data generators (`rust/src/data/grammar.rs`)
use for federated fine-tuning. The spec itself is serialized to
``artifacts/vocab.json`` by ``aot.py``; this module and the rust module
are two implementations of the same published grammar — they need to
agree on the *distribution*, not bit-for-bit samples.

Grammar (see DESIGN.md §2):
  single: CLS, then a shuffled mix of `k ~ U[bank_words]` words drawn
          from the label's bank and `ℓ-k` background words (80% filler,
          20% noise), PAD-padded to seq_len.
  pair:   CLS, premise of filler words, SEP, hypothesis containing the
          label's bank words — models must attend across the SEP.
  arith:  CLS d1 + d2 + d3 SEP, label = (d1+d2+d3) mod n_classes — the
          model must actually add (gsm-syn's stand-in for multi-step
          reasoning; converges slowly, like GSM-8K in the paper).
With probability `label_noise` the label is resampled uniformly.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

from . import configs


def _bg_word(spec: Dict, rng: np.random.Generator) -> int:
    if rng.random() < 0.8:
        lo, hi = spec["filler"]
    else:
        lo, hi = spec["noise"]
    return int(rng.integers(lo, hi))


def sample_single(spec: Dict, task: Dict, label: int,
                  rng: np.random.Generator) -> List[int]:
    lo_len, hi_len = task["len_range"]
    lo_k, hi_k = task["bank_words"]
    length = int(rng.integers(lo_len, hi_len + 1))
    k = int(rng.integers(lo_k, hi_k + 1))
    blo, bhi = task["banks"][label]
    words = [int(rng.integers(blo, bhi)) for _ in range(k)]
    words += [_bg_word(spec, rng) for _ in range(max(length - k, 0))]
    rng.shuffle(words)
    return [spec["special"]["cls"]] + words


def sample_pair(spec: Dict, task: Dict, label: int,
                rng: np.random.Generator) -> List[int]:
    lo_len, hi_len = task["len_range"]
    lo_k, hi_k = task["bank_words"]
    sep = spec["special"]["sep"]
    prem_len = int(rng.integers(lo_len, hi_len + 1))
    hyp_len = int(rng.integers(lo_len, hi_len + 1))
    k = int(rng.integers(lo_k, hi_k + 1))
    blo, bhi = task["banks"][label]
    premise = [_bg_word(spec, rng) for _ in range(prem_len)]
    hyp = [int(rng.integers(blo, bhi)) for _ in range(k)]
    hyp += [_bg_word(spec, rng) for _ in range(max(hyp_len - k, 0))]
    rng.shuffle(hyp)
    return [spec["special"]["cls"]] + premise + [sep] + hyp


def sample_arith(spec: Dict, task: Dict, rng: np.random.Generator
                 ) -> Tuple[List[int], int]:
    digits = task["digits"]
    plus = task["ops"][0]
    terms = [int(rng.integers(0, 10)) for _ in range(task["n_terms"])]
    label = sum(terms) % task["n_classes"]
    toks = [spec["special"]["cls"]]
    for i, t in enumerate(terms):
        if i:
            toks.append(plus)
        toks.append(digits[0] + t)
    toks.append(spec["special"]["sep"])
    return toks, label


def sample_example(spec: Dict, task_name: str,
                   rng: np.random.Generator) -> Tuple[List[int], int]:
    """One (token_ids, label) example, PADed/truncated to seq_len."""
    task = spec["tasks"][task_name]
    n = task["n_classes"]
    if task["kind"] == "arith":
        toks, label = sample_arith(spec, task, rng)
    else:
        label = int(rng.integers(0, n))
        fn = sample_single if task["kind"] == "single" else sample_pair
        toks = fn(spec, task, label, rng)
    if rng.random() < task.get("label_noise", 0.0):
        label = int(rng.integers(0, n))
    s = spec["seq_len"]
    pad = spec["special"]["pad"]
    toks = toks[:s] + [pad] * max(0, s - len(toks))
    return toks, label


def corpus_batch(spec: Dict, batch: int, rng: np.random.Generator
                 ) -> np.ndarray:
    """Unlabeled pretraining batch: sentences mixed across all tasks."""
    names = list(spec["tasks"].keys())
    rows = []
    for _ in range(batch):
        task = names[int(rng.integers(0, len(names)))]
        toks, _ = sample_example(spec, task, rng)
        rows.append(toks)
    return np.asarray(rows, dtype=np.int32)


def mlm_mask_batch(tokens: np.ndarray, rng: np.random.Generator,
                   mask_id: int, pad_id: int, rate: float = 0.15
                   ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """BERT-style masking: returns (inputs, targets, loss_mask)."""
    targets = tokens.copy()
    can_mask = tokens != pad_id
    chosen = (rng.random(tokens.shape) < rate) & can_mask
    inputs = tokens.copy()
    replace = chosen & (rng.random(tokens.shape) < 0.8)
    inputs[replace] = mask_id
    return inputs, targets, chosen.astype(np.float32)


def labeled_batch(spec: Dict, task_name: str, batch: int,
                  rng: np.random.Generator) -> Tuple[np.ndarray, np.ndarray]:
    xs, ys = [], []
    for _ in range(batch):
        t, l = sample_example(spec, task_name, rng)
        xs.append(t)
        ys.append(l)
    return np.asarray(xs, dtype=np.int32), np.asarray(ys, dtype=np.int32)
