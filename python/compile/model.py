"""L2: RoBERTa-style encoder classifier with masked LoRA / adapters.

This module defines *all* compute that runs on devices in the federated
system: the forward pass, the LoRA (and FedAdapter) train steps with
AdamW, the eval step, and the MLM pretraining step used to manufacture
the frozen base (DESIGN.md §2 — no Hugging Face checkpoints offline).

Layer parameters are **stacked** along a leading ``L`` axis and the
encoder runs ``lax.scan`` over layers, so one lowered HLO module covers
any depth/rank/position configuration through the ``layer_mask`` /
``rank_mask`` inputs (DESIGN.md "masking trick"). LoRA is applied to
the query and value projections, following the LoRA paper defaults the
FedFT baselines use.

Everything is lowered ONCE by ``aot.py``; Python never runs at
federated-training time.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from . import configs
from .configs import ModelConfig
from .kernels import ref as kref

# ---------------------------------------------------------------------------
# Parameter layout
# ---------------------------------------------------------------------------

# Canonical ordering of the frozen base tensors: this is the order they
# appear in artifacts/base_weights.bin and as executable inputs.
BASE_ORDER: List[str] = [
    "embed", "pos",
    "ln1_g", "ln1_b",
    "wq", "bq", "wk", "bk", "wv", "bv", "wo", "bo",
    "ln2_g", "ln2_b",
    "w1", "b1", "w2", "b2",
    "lnf_g", "lnf_b",
]

# Trainable tensors for the LoRA family (LEGEND/FedLoRA/HetLoRA/ablations).
LORA_ORDER: List[str] = [
    "aq", "bq", "av", "bv", "head_w", "head_b",
]

# Trainable tensors for the FedAdapter family.
ADAPTER_ORDER: List[str] = [
    "down", "bdown", "up", "head_w", "head_b",
]


def base_shapes(cfg: ModelConfig) -> Dict[str, Tuple[int, ...]]:
    L, d, f, V, S = (cfg.n_layers, cfg.d_model, cfg.d_ffn,
                     cfg.vocab_size, cfg.seq_len)
    return {
        "embed": (V, d), "pos": (S, d),
        "ln1_g": (L, d), "ln1_b": (L, d),
        "wq": (L, d, d), "bq": (L, d),
        "wk": (L, d, d), "bk": (L, d),
        "wv": (L, d, d), "bv": (L, d),
        "wo": (L, d, d), "bo": (L, d),
        "ln2_g": (L, d), "ln2_b": (L, d),
        "w1": (L, d, f), "b1": (L, f),
        "w2": (L, f, d), "b2": (L, d),
        "lnf_g": (d,), "lnf_b": (d,),
    }


def lora_shapes(cfg: ModelConfig) -> Dict[str, Tuple[int, ...]]:
    L, d, r, C = cfg.n_layers, cfg.d_model, cfg.r_max, cfg.n_classes
    return {
        "aq": (L, r, d), "bq": (L, d, r),
        "av": (L, r, d), "bv": (L, d, r),
        "head_w": (d, C), "head_b": (C,),
    }


def adapter_shapes(cfg: ModelConfig) -> Dict[str, Tuple[int, ...]]:
    L, d, w, C = cfg.n_layers, cfg.d_model, cfg.adapter_w_max, cfg.n_classes
    return {
        "down": (L, d, w), "bdown": (L, w), "up": (L, w, d),
        "head_w": (d, C), "head_b": (C,),
    }


def init_base(cfg: ModelConfig, key) -> Dict[str, jnp.ndarray]:
    """Random (pre-pretraining) base parameters.

    Scaling matters on a from-scratch base: token embeddings are
    initialized at unit per-element variance (‖e‖ ≈ √d) so the lexical
    signal is commensurate with the residual stream, and the residual
    output projections (wo, w2) carry the GPT-2-style 1/√(2L)
    down-scaling so 12 layers of additions don't drown it.
    """
    shapes = base_shapes(cfg)
    params = {}
    keys = jax.random.split(key, len(BASE_ORDER))
    resid_scale = 1.0 / jnp.sqrt(2.0 * cfg.n_layers)
    for k, name in zip(keys, BASE_ORDER):
        shp = shapes[name]
        if name.startswith(("ln", "lnf")):
            params[name] = (jnp.ones(shp, jnp.float32) if name.endswith("_g")
                            else jnp.zeros(shp, jnp.float32))
        elif name == "embed":
            params[name] = jax.random.normal(k, shp, jnp.float32)
        elif name == "pos":
            params[name] = 0.5 * jax.random.normal(k, shp, jnp.float32)
        elif name.startswith("b"):
            params[name] = jnp.zeros(shp, jnp.float32)
        else:
            fan_in = shp[-2] if len(shp) >= 2 else shp[-1]
            std = 1.0 / jnp.sqrt(fan_in)
            if name in ("wo", "w2"):
                std = std * resid_scale
            params[name] = jax.random.normal(k, shp, jnp.float32) * std
    return params


def init_lora(cfg: ModelConfig, key) -> Dict[str, jnp.ndarray]:
    """LoRA init: A ~ N(0, 1/d) (all slots, padded ones stay masked),
    B = 0 so BA = 0 at init — the standard LoRA initialization."""
    shapes = lora_shapes(cfg)
    k_aq, k_av, k_head = jax.random.split(key, 3)
    d = cfg.d_model
    return {
        "aq": jax.random.normal(k_aq, shapes["aq"], jnp.float32) / jnp.sqrt(d),
        "bq": jnp.zeros(shapes["bq"], jnp.float32),
        "av": jax.random.normal(k_av, shapes["av"], jnp.float32) / jnp.sqrt(d),
        "bv": jnp.zeros(shapes["bv"], jnp.float32),
        "head_w": jax.random.normal(k_head, shapes["head_w"], jnp.float32)
        / jnp.sqrt(d),
        "head_b": jnp.zeros(shapes["head_b"], jnp.float32),
    }


def init_adapter(cfg: ModelConfig, key) -> Dict[str, jnp.ndarray]:
    """Adapter init: near-identity (up = 0) as in Houlsby et al."""
    shapes = adapter_shapes(cfg)
    k_down, k_head = jax.random.split(key, 2)
    d = cfg.d_model
    return {
        "down": jax.random.normal(k_down, shapes["down"], jnp.float32)
        / jnp.sqrt(d),
        "bdown": jnp.zeros(shapes["bdown"], jnp.float32),
        "up": jnp.zeros(shapes["up"], jnp.float32),
        "head_w": jax.random.normal(k_head, shapes["head_w"], jnp.float32)
        / jnp.sqrt(d),
        "head_b": jnp.zeros(shapes["head_b"], jnp.float32),
    }


def init_opt(trainable: Dict[str, jnp.ndarray]) -> Dict[str, jnp.ndarray]:
    """AdamW first/second-moment state, one (m, v) pair per tensor."""
    opt = {}
    for name, p in trainable.items():
        opt["m_" + name] = jnp.zeros_like(p)
        opt["v_" + name] = jnp.zeros_like(p)
    return opt


def trainable_masks(cfg: ModelConfig, family: str, rank_mask, layer_mask):
    """Per-tensor {0,1} update masks (DESIGN.md: a masked slot never
    moves off its received value — update, incl. weight decay, is
    multiplied by this mask)."""
    lm = layer_mask[:, None, None]
    if family == "lora":
        rm_down = rank_mask[:, :, None]     # for a: [L, r, d]
        rm_up = rank_mask[:, None, :]       # for b: [L, d, r]
        return {
            "aq": lm * rm_down, "bq": lm * rm_up,
            "av": lm * rm_down, "bv": lm * rm_up,
            "head_w": jnp.ones((cfg.d_model, cfg.n_classes), jnp.float32),
            "head_b": jnp.ones((cfg.n_classes,), jnp.float32),
        }
    elif family == "adapter":
        wm_down = rank_mask[:, None, :]     # width mask for down [L, d, w]
        wm_up = rank_mask[:, :, None]       # for up [L, w, d]
        return {
            "down": lm * wm_down,
            "bdown": layer_mask[:, None] * rank_mask,
            "up": lm * wm_up,
            "head_w": jnp.ones((cfg.d_model, cfg.n_classes), jnp.float32),
            "head_b": jnp.ones((cfg.n_classes,), jnp.float32),
        }
    raise ValueError(family)


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------

def _layer_norm(x, g, b, eps=1e-5):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def _attention(q, k, v, bias, n_heads):
    b, s, d = q.shape
    dh = d // n_heads

    def split(t):
        return t.reshape(b, s, n_heads, dh).transpose(0, 2, 1, 3)

    qh, kh, vh = split(q), split(k), split(v)
    scores = jnp.einsum("bhqd,bhkd->bhqk", qh, kh) / jnp.sqrt(dh)
    probs = jax.nn.softmax(scores + bias, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, vh)
    return out.transpose(0, 2, 1, 3).reshape(b, s, d)


def _lora_proj(h, w, bias, a, b_up, rank_mask, layer_on, alpha,
               use_pallas: bool):
    """LoRA-adapted projection for one layer (h: [B, S, d])."""
    r_eff = kref.effective_rank(rank_mask)
    scale = (alpha / r_eff) * layer_on
    bsz, s, d = h.shape
    h2 = h.reshape(bsz * s, d)
    if use_pallas:
        from .kernels import lora as klora
        y = klora.lora_linear(h2, w, a, b_up, rank_mask, scale)
    else:
        y = kref.lora_linear_ref(h2, w, a, b_up, rank_mask, scale)
    return y.reshape(bsz, s, d) + bias


def encoder_forward(cfg: ModelConfig, base, trainable, rank_mask, layer_mask,
                    tokens, *, family: str = "lora",
                    use_pallas: bool = False):
    """Run the encoder; returns (cls_logits, final_hidden).

    rank_mask: [L, r_max] (LoRA) or [L, w_max] (adapter width).
    layer_mask: [L] — which layers carry a trainable module on this
    device (encodes LoRA depth / Fig. 3 position variants).
    """
    pad_id = configs.PAD
    bsz, s = tokens.shape
    x = base["embed"][tokens] + base["pos"][None, :s]
    attn_bias = jnp.where(tokens == pad_id, -1e9, 0.0)[:, None, None, :]

    # Stack the per-layer tensors as scan inputs. Trainable "bq" (LoRA
    # up-factor for q) would collide with base "bq" (query bias), so the
    # trainable slices get an "l_"/"ad_" prefix inside the scan body.
    stacked_names = [n for n in BASE_ORDER
                     if n not in ("embed", "pos", "lnf_g", "lnf_b")]
    xs = {n: base[n] for n in stacked_names}
    if family == "lora":
        xs["l_aq"] = trainable["aq"]
        xs["l_bq"] = trainable["bq"]
        xs["l_av"] = trainable["av"]
        xs["l_bv"] = trainable["bv"]
    else:
        xs["ad_down"] = trainable["down"]
        xs["ad_bdown"] = trainable["bdown"]
        xs["ad_up"] = trainable["up"]
    xs["rank_mask"] = rank_mask
    xs["layer_mask"] = layer_mask

    def layer_step(x, p):
        h = _layer_norm(x, p["ln1_g"], p["ln1_b"])
        if family == "lora":
            q = _lora_proj(h, p["wq"], p["bq"], p["l_aq"], p["l_bq"],
                           p["rank_mask"], p["layer_mask"], cfg.lora_alpha,
                           use_pallas)
            v = _lora_proj(h, p["wv"], p["bv"], p["l_av"], p["l_bv"],
                           p["rank_mask"], p["layer_mask"], cfg.lora_alpha,
                           use_pallas)
        else:
            q = h @ p["wq"] + p["bq"]
            v = h @ p["wv"] + p["bv"]
        k = h @ p["wk"] + p["bk"]
        attn = _attention(q, k, v, attn_bias, cfg.n_heads)
        x = x + attn @ p["wo"] + p["bo"]

        h2 = _layer_norm(x, p["ln2_g"], p["ln2_b"])
        ffn = jax.nn.gelu(h2 @ p["w1"] + p["b1"]) @ p["w2"] + p["b2"]
        if family == "adapter":
            wm = p["rank_mask"]  # width mask for this layer: [w_max]
            z = ffn
            bsz_, s_, d_ = z.shape
            z2 = z.reshape(bsz_ * s_, d_)
            adapted = kref.adapter_ref(z2, p["ad_down"], p["ad_up"],
                                       p["ad_bdown"], wm)
            ffn = ffn + p["layer_mask"] * (adapted.reshape(z.shape) - z)
        x = x + ffn
        return x, None

    x, _ = jax.lax.scan(layer_step, x, xs)
    x = _layer_norm(x, base["lnf_g"], base["lnf_b"])
    # Masked mean pooling: on a from-scratch pretrained base the CLS
    # token aggregates poorly, while the mean over non-pad positions
    # carries the full lexical signal (DESIGN.md §2 substitutions).
    pad_mask = (tokens != pad_id).astype(jnp.float32)[..., None]
    pooled = (x * pad_mask).sum(axis=1) \
        / jnp.maximum(pad_mask.sum(axis=1), 1.0)
    logits = pooled @ trainable["head_w"] + trainable["head_b"]
    return logits, x


def classification_loss(logits, labels):
    """Mean CE + correct count. labels: int32 [B]."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    loss = nll.mean()
    correct = (logits.argmax(-1) == labels).sum().astype(jnp.float32)
    return loss, correct


# ---------------------------------------------------------------------------
# Train / eval steps (the functions lowered to HLO)
# ---------------------------------------------------------------------------

def adamw_update(cfg: ModelConfig, p, g, m, v, mask, lr, step):
    """Masked AdamW: masked slots (padding ranks / absent layers) keep
    their incoming value bit-exactly — including no weight decay."""
    m = cfg.beta1 * m + (1.0 - cfg.beta1) * g
    v = cfg.beta2 * v + (1.0 - cfg.beta2) * g * g
    mhat = m / (1.0 - cfg.beta1 ** step)
    vhat = v / (1.0 - cfg.beta2 ** step)
    upd = lr * (mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p)
    return p - upd * mask, m * mask, v * mask


def make_train_step(cfg: ModelConfig, family: str = "lora",
                    use_pallas: bool = False):
    """Returns train_step(base, trainable, opt, rank_mask, layer_mask,
    tokens, labels, lr, step) -> (trainable', opt', loss, correct)."""

    order = LORA_ORDER if family == "lora" else ADAPTER_ORDER

    def loss_fn(trainable, base, rank_mask, layer_mask, tokens, labels):
        logits, _ = encoder_forward(cfg, base, trainable, rank_mask,
                                    layer_mask, tokens, family=family,
                                    use_pallas=use_pallas)
        return classification_loss(logits, labels)

    def train_step(base, trainable, opt, rank_mask, layer_mask, tokens,
                   labels, lr, step):
        (loss, correct), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(trainable, base, rank_mask, layer_mask,
                                   tokens, labels)
        if family == "adapter":
            # Full-width adapters on every layer destabilize at the
            # LoRA-tuned learning rate (the bottleneck's gelu path
            # feeds the residual stream directly); clip the global
            # gradient norm as FedAdapter-style trainers do.
            gnorm = jnp.sqrt(sum((g ** 2).sum() for g in grads.values()))
            scale = jnp.minimum(1.0, 1.0 / (gnorm + 1e-12))
            grads = {k: g * scale for k, g in grads.items()}
        masks = trainable_masks(cfg, family, rank_mask, layer_mask)
        new_t, new_o = {}, {}
        for name in order:
            p, g = trainable[name], grads[name]
            m, v = opt["m_" + name], opt["v_" + name]
            p2, m2, v2 = adamw_update(cfg, p, g, m, v, masks[name], lr, step)
            new_t[name] = p2
            new_o["m_" + name] = m2
            new_o["v_" + name] = v2
        return new_t, new_o, loss, correct

    return train_step


def make_eval_step(cfg: ModelConfig, family: str = "lora"):
    """Returns eval_step(base, trainable, rank_mask, layer_mask, tokens,
    labels) -> (loss_sum, correct)."""

    def eval_step(base, trainable, rank_mask, layer_mask, tokens, labels):
        logits, _ = encoder_forward(cfg, base, trainable, rank_mask,
                                    layer_mask, tokens, family=family)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
        correct = (logits.argmax(-1) == labels).sum().astype(jnp.float32)
        return nll.sum(), correct

    return eval_step


# ---------------------------------------------------------------------------
# MLM pretraining step (build-time only; manufactures the frozen base)
# ---------------------------------------------------------------------------

def make_pretrain_step(cfg: ModelConfig):
    """Full-parameter masked-LM step with a tied decoder (embedᵀ).

    Used only by pretrain.py to create artifacts/base_weights.bin; the
    federated system never trains base weights.
    """

    zero_lora_names = ("aq", "bq", "av", "bv")

    def mlm_loss(base, tokens, targets, mlm_mask):
        # Forward with LoRA disabled (zero masks): plain base encoder.
        L, r = cfg.n_layers, cfg.r_max
        dummy = {
            "aq": jnp.zeros((L, r, cfg.d_model)),
            "bq": jnp.zeros((L, cfg.d_model, r)),
            "av": jnp.zeros((L, r, cfg.d_model)),
            "bv": jnp.zeros((L, cfg.d_model, r)),
            "head_w": jnp.zeros((cfg.d_model, cfg.n_classes)),
            "head_b": jnp.zeros((cfg.n_classes,)),
        }
        rank_mask = jnp.zeros((L, r))
        layer_mask = jnp.zeros((L,))
        _, hidden = encoder_forward(cfg, base, dummy, rank_mask, layer_mask,
                                    tokens)
        logits = hidden @ base["embed"].T                 # [B, S, V]
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
        denom = jnp.maximum(mlm_mask.sum(), 1.0)
        return (nll * mlm_mask).sum() / denom

    def pretrain_step(base, opt, tokens, targets, mlm_mask, lr, step):
        loss, grads = jax.value_and_grad(mlm_loss)(base, tokens, targets,
                                                   mlm_mask)
        new_b, new_o = {}, {}
        for name in BASE_ORDER:
            p, g = base[name], grads[name]
            m, v = opt["m_" + name], opt["v_" + name]
            ones = jnp.ones_like(p)
            p2, m2, v2 = adamw_update(cfg, p, g, m, v, ones, lr, step)
            new_b[name] = p2
            new_o["m_" + name] = m2
            new_o["v_" + name] = v2
        return new_b, new_o, loss

    _ = zero_lora_names
    return pretrain_step


# ---------------------------------------------------------------------------
# Flattening helpers (artifact input/output ordering)
# ---------------------------------------------------------------------------

def flatten_base(base) -> List[jnp.ndarray]:
    return [base[n] for n in BASE_ORDER]

def unflatten_base(flat) -> Dict[str, jnp.ndarray]:
    return dict(zip(BASE_ORDER, flat))

def flatten_trainable(t, family="lora") -> List[jnp.ndarray]:
    order = LORA_ORDER if family == "lora" else ADAPTER_ORDER
    return [t[n] for n in order]

def unflatten_trainable(flat, family="lora") -> Dict[str, jnp.ndarray]:
    order = LORA_ORDER if family == "lora" else ADAPTER_ORDER
    return dict(zip(order, flat))

def opt_order(family="lora") -> List[str]:
    order = LORA_ORDER if family == "lora" else ADAPTER_ORDER
    out = []
    for n in order:
        out += ["m_" + n, "v_" + n]
    return out

def flatten_opt(o, family="lora") -> List[jnp.ndarray]:
    return [o[n] for n in opt_order(family)]

def unflatten_opt(flat, family="lora") -> Dict[str, jnp.ndarray]:
    return dict(zip(opt_order(family), flat))
