"""Synthetic grammar + spec tests (python side; the rust generators
mirror the same spec and carry their own tests)."""

import numpy as np
import pytest

from compile import configs, datagen


@pytest.fixture(scope="module")
def spec():
    return configs.task_spec()


def test_spec_banks_disjoint(spec):
    """Task word banks must not overlap each other or the filler."""
    ranges = [tuple(spec["filler"])]
    for t in spec["tasks"].values():
        for b in t.get("banks", []):
            ranges.append(tuple(b))
        if "digits" in t:
            d = t["digits"]
            ranges.append((d[0], d[-1] + 1))
    ranges.sort()
    for (al, ah), (bl, bh) in zip(ranges, ranges[1:]):
        assert ah <= bl, f"overlap: ({al},{ah}) vs ({bl},{bh})"
    assert ranges[-1][1] <= spec["vocab_size"]


def test_examples_padded_and_labeled(spec):
    rng = np.random.default_rng(0)
    for task in spec["tasks"]:
        toks, label = datagen.sample_example(spec, task, rng)
        assert len(toks) == spec["seq_len"]
        assert toks[0] == spec["special"]["cls"]
        assert 0 <= label < spec["tasks"][task]["n_classes"]
        assert all(0 <= t < spec["vocab_size"] for t in toks)


def test_single_task_bank_words_present(spec):
    rng = np.random.default_rng(1)
    task = spec["tasks"]["sst2"]
    hits = 0
    for _ in range(50):
        toks, label = datagen.sample_example(spec, "sst2", rng)
        lo, hi = task["banks"][label]
        if any(lo <= t < hi for t in toks):
            hits += 1
    # label_noise can flip a couple, but the vast majority must carry
    # their bank words.
    assert hits >= 45


def test_arith_label_is_sum_mod_classes(spec):
    rng = np.random.default_rng(2)
    task = spec["tasks"]["gsm"]
    d0 = task["digits"][0]
    for _ in range(100):
        toks, label = datagen.sample_example(spec, "gsm", rng)
        digits = [t - d0 for t in toks if d0 <= t < d0 + 10]
        assert label == sum(digits) % task["n_classes"]


def test_pair_task_has_separator(spec):
    rng = np.random.default_rng(3)
    toks, _ = datagen.sample_example(spec, "qnli", rng)
    assert spec["special"]["sep"] in toks


def test_corpus_batch_shape(spec):
    rng = np.random.default_rng(4)
    batch = datagen.corpus_batch(spec, 16, rng)
    assert batch.shape == (16, spec["seq_len"])
    assert batch.dtype == np.int32


def test_mlm_masking(spec):
    rng = np.random.default_rng(5)
    toks = datagen.corpus_batch(spec, 32, rng)
    inp, tgt, mask = datagen.mlm_mask_batch(
        toks, rng, spec["special"]["mask"], spec["special"]["pad"])
    assert (tgt == toks).all()
    rate = mask.mean()
    assert 0.05 < rate < 0.3
    # PAD positions never masked.
    assert (mask[toks == spec["special"]["pad"]] == 0).all()
    # Masked positions mostly carry the MASK token.
    masked_inputs = inp[mask.astype(bool)]
    frac_mask_tok = (masked_inputs == spec["special"]["mask"]).mean()
    assert frac_mask_tok > 0.6


def test_labels_roughly_balanced(spec):
    rng = np.random.default_rng(6)
    _, ys = datagen.labeled_batch(spec, "mmlu", 400, rng)
    counts = np.bincount(ys, minlength=4)
    assert counts.min() > 50, counts
