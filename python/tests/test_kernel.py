"""L1 correctness: the Pallas fused LoRA kernel vs the pure-jnp oracle.

This is the CORE kernel correctness signal — hypothesis sweeps shapes,
ranks, masks, block sizes and dtypes; every case must match ref.py to
float32 tolerance.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import lora, ref

SETTINGS = dict(max_examples=25, deadline=None)


def _rand(rng, *shape, dtype=np.float32):
    return rng.standard_normal(shape).astype(dtype)


def _check(m, k, n, r, mask_frac, scale, block_m, block_n, dtype, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, m, k, dtype=dtype)
    w = _rand(rng, k, n, dtype=dtype)
    a = _rand(rng, r, k, dtype=dtype)
    b = _rand(rng, n, r, dtype=dtype)
    mask = (rng.random(r) < mask_frac).astype(np.float32)
    got = lora.lora_linear(
        x, w, a, b, jnp.asarray(mask), scale,
        block_m=block_m, block_n=block_n)
    want = ref.lora_linear_ref(x, w, a, b, jnp.asarray(mask), scale)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)


@settings(**SETTINGS)
@given(
    m=st.integers(1, 96),
    k=st.integers(1, 64),
    n=st.integers(1, 96),
    r=st.integers(1, 16),
    mask_frac=st.floats(0.0, 1.0),
    scale=st.floats(-4.0, 4.0),
    seed=st.integers(0, 2**31),
)
def test_kernel_matches_ref_f32(m, k, n, r, mask_frac, scale, seed):
    _check(m, k, n, r, mask_frac, scale, 32, 32, np.float32, seed)


@settings(**SETTINGS)
@given(
    block_m=st.sampled_from([8, 16, 32, 128]),
    block_n=st.sampled_from([8, 16, 64, 128]),
    seed=st.integers(0, 2**31),
)
def test_kernel_block_shape_invariance(block_m, block_n, seed):
    """Output must not depend on the tiling choice."""
    _check(40, 24, 56, 7, 0.6, 1.5, block_m, block_n, np.float32, seed)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31))
def test_kernel_bf16_inputs(seed):
    """bf16 inputs accumulate in f32 (MXU-style)."""
    _check(16, 32, 16, 4, 1.0, 2.0, 16, 16, jnp.bfloat16, seed)


def test_zero_mask_is_base_matmul():
    rng = np.random.default_rng(0)
    x, w = _rand(rng, 8, 16), _rand(rng, 16, 8)
    a, b = _rand(rng, 4, 16), _rand(rng, 8, 4)
    mask = jnp.zeros(4)
    got = lora.lora_linear(x, w, a, b, mask, 3.0, block_m=8, block_n=8)
    np.testing.assert_allclose(np.asarray(got), x @ w, rtol=1e-5,
                               atol=1e-5)


def test_zero_scale_is_base_matmul():
    rng = np.random.default_rng(1)
    x, w = _rand(rng, 8, 16), _rand(rng, 16, 8)
    a, b = _rand(rng, 4, 16), _rand(rng, 8, 4)
    got = lora.lora_linear(x, w, a, b, jnp.ones(4), 0.0,
                           block_m=8, block_n=8)
    np.testing.assert_allclose(np.asarray(got), x @ w, rtol=1e-5,
                               atol=1e-5)


def test_full_rank_additivity():
    """y(mask=m1) + bypass(m2) == y(mask=m1|m2) when m1 ∩ m2 = ∅."""
    rng = np.random.default_rng(2)
    x, w = _rand(rng, 8, 8), _rand(rng, 8, 8)
    a, b = _rand(rng, 6, 8), _rand(rng, 8, 6)
    m1 = jnp.asarray([1., 1., 1., 0., 0., 0.])
    m2 = jnp.asarray([0., 0., 0., 1., 1., 1.])
    both = jnp.asarray([1.] * 6)
    y1 = lora.lora_linear(x, w, a, b, m1, 1.0, block_m=8, block_n=8)
    y2 = lora.lora_linear(x, w, a, b, m2, 1.0, block_m=8, block_n=8)
    y12 = lora.lora_linear(x, w, a, b, both, 1.0, block_m=8, block_n=8)
    np.testing.assert_allclose(
        np.asarray(y1 + y2 - x @ w), np.asarray(y12), rtol=1e-4,
        atol=1e-4)


def test_vmem_estimate_monotone_in_blocks():
    small = lora.vmem_bytes(32, 32, 128, 16)
    big = lora.vmem_bytes(128, 128, 128, 16)
    assert big > small
    # Default tiling fits a 16 MB VMEM budget (DESIGN §Perf).
    assert lora.vmem_bytes(128, 128, 128, 16) < 16 * 2**20


def test_mxu_utilization_penalizes_ragged_tiles():
    aligned = lora.mxu_utilization_estimate(128, 128, 128, 8)
    ragged = lora.mxu_utilization_estimate(129, 129, 128, 8)
    assert aligned > 0.99
    assert ragged < aligned


@pytest.mark.parametrize("m,k,n,r", [(1, 1, 1, 1), (128, 128, 128, 16),
                                     (5, 3, 2, 1)])
def test_kernel_edge_shapes(m, k, n, r):
    _check(m, k, n, r, 1.0, 1.0, 32, 32, np.float32, 3)


def test_adapter_ref_identity_at_zero_width():
    rng = np.random.default_rng(4)
    x = _rand(rng, 6, 8)
    down, up = _rand(rng, 8, 4), _rand(rng, 4, 8)
    b = _rand(rng, 4)
    out = ref.adapter_ref(x, down, up, b, jnp.zeros(4))
    np.testing.assert_allclose(np.asarray(out), x, rtol=1e-6, atol=1e-6)
