"""L2 correctness: shapes, masking semantics, optimizer behaviour,
pallas-vs-jnp model parity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs, model

CFG = configs.TINY


@pytest.fixture(scope="module")
def base():
    return model.init_base(CFG, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def batch():
    k = jax.random.PRNGKey(9)
    tokens = jax.random.randint(
        k, (CFG.batch_size, CFG.seq_len), 4, CFG.vocab_size)
    labels = jnp.arange(CFG.batch_size, dtype=jnp.int32) % CFG.n_classes
    return tokens, labels


def full_masks():
    return (jnp.ones((CFG.n_layers, CFG.r_max)), jnp.ones(CFG.n_layers))


def test_base_shapes_match_spec(base):
    shapes = model.base_shapes(CFG)
    for n in model.BASE_ORDER:
        assert base[n].shape == shapes[n], n


def test_forward_shapes(base, batch):
    tokens, _ = batch
    lora = model.init_lora(CFG, jax.random.PRNGKey(1))
    rm, lm = full_masks()
    logits, hidden = model.encoder_forward(CFG, base, lora, rm, lm, tokens)
    assert logits.shape == (CFG.batch_size, CFG.n_classes)
    assert hidden.shape == (CFG.batch_size, CFG.seq_len, CFG.d_model)
    assert bool(jnp.isfinite(logits).all())


def test_zero_layer_mask_matches_zero_lora(base, batch):
    """layer_mask=0 must equal a model with B=0 (no bypass at all)."""
    tokens, _ = batch
    lora = model.init_lora(CFG, jax.random.PRNGKey(2))
    # Force non-zero B so masking actually does something.
    lora = dict(lora, bq=jnp.ones_like(lora["bq"]),
                bv=jnp.ones_like(lora["bv"]))
    rm = jnp.ones((CFG.n_layers, CFG.r_max))
    masked, _ = model.encoder_forward(
        CFG, base, lora, rm, jnp.zeros(CFG.n_layers), tokens)
    zeroed = dict(lora, bq=jnp.zeros_like(lora["bq"]),
                  bv=jnp.zeros_like(lora["bv"]))
    plain, _ = model.encoder_forward(
        CFG, base, zeroed, rm, jnp.ones(CFG.n_layers), tokens)
    np.testing.assert_allclose(np.asarray(masked), np.asarray(plain),
                               rtol=1e-5, atol=1e-5)


def test_rank_mask_prefix_equals_truncated_factors(base, batch):
    """rank_mask keeping r slots == physically truncating A/B to rank r."""
    tokens, _ = batch
    key = jax.random.PRNGKey(3)
    lora = model.init_lora(CFG, key)
    lora = dict(lora,
                bq=jax.random.normal(key, lora["bq"].shape) * 0.1,
                bv=jax.random.normal(key, lora["bv"].shape) * 0.1)
    keep = 2
    rm = jnp.zeros((CFG.n_layers, CFG.r_max)).at[:, :keep].set(1.0)
    lm = jnp.ones(CFG.n_layers)
    masked, _ = model.encoder_forward(CFG, base, lora, rm, lm, tokens)
    # Physically zero the padded slots instead.
    def trunc(t, axis):
        idx = [slice(None)] * t.ndim
        idx[axis] = slice(keep, None)
        return t.at[tuple(idx)].set(0.0)
    zeroed = dict(lora,
                  aq=trunc(lora["aq"], 1), av=trunc(lora["av"], 1),
                  bq=trunc(lora["bq"], 2), bv=trunc(lora["bv"], 2))
    # NOTE: the LoRA scale uses the effective rank from the mask, so
    # compare against the same mask-derived scale by keeping rm.
    truncated, _ = model.encoder_forward(CFG, base, zeroed, rm, lm,
                                         tokens)
    np.testing.assert_allclose(np.asarray(masked), np.asarray(truncated),
                               rtol=1e-5, atol=1e-5)


def test_train_step_moves_only_active_slots(base, batch):
    tokens, labels = batch
    lora = model.init_lora(CFG, jax.random.PRNGKey(4))
    opt = model.init_opt(lora)
    rm = jnp.zeros((CFG.n_layers, CFG.r_max)).at[:, :2].set(1.0)
    lm = jnp.zeros(CFG.n_layers).at[-1].set(1.0)  # depth 1
    ts = model.make_train_step(CFG)
    nt, no, loss, _ = ts(base, lora, opt, rm, lm, tokens, labels,
                         1e-2, 1.0)
    assert bool(jnp.isfinite(loss))
    # Shallow layer's B untouched; deepest layer's active B moved.
    np.testing.assert_array_equal(np.asarray(nt["bq"][0]),
                                  np.asarray(lora["bq"][0]))
    assert not np.allclose(np.asarray(nt["bq"][-1][:, :2]),
                           np.asarray(lora["bq"][-1][:, :2]))
    # Padded slots of the deep layer untouched.
    np.testing.assert_array_equal(np.asarray(nt["bq"][-1][:, 2:]),
                                  np.asarray(lora["bq"][-1][:, 2:]))
    # Head always trains.
    assert not np.allclose(np.asarray(no["m_head_w"]), 0.0)


def test_masked_slots_resist_weight_decay(base, batch):
    """AdamW weight decay must not leak into masked slots."""
    tokens, labels = batch
    lora = model.init_lora(CFG, jax.random.PRNGKey(5))
    # Put non-zero values in padded region; they must stay bit-equal.
    lora = dict(lora, aq=lora["aq"].at[:, -1].set(7.0))
    opt = model.init_opt(lora)
    rm = jnp.zeros((CFG.n_layers, CFG.r_max)).at[:, :1].set(1.0)
    lm = jnp.ones(CFG.n_layers)
    ts = model.make_train_step(CFG)
    nt = lora
    no = opt
    for step in range(1, 4):
        nt, no, _, _ = ts(base, nt, no, rm, lm, tokens, labels, 1e-2,
                          float(step))
    np.testing.assert_array_equal(np.asarray(nt["aq"][:, -1]),
                                  np.full_like(np.asarray(nt["aq"][:, -1]),
                                               7.0))


def test_eval_step_counts(base, batch):
    tokens, labels = batch
    lora = model.init_lora(CFG, jax.random.PRNGKey(6))
    rm, lm = full_masks()
    es = model.make_eval_step(CFG)
    loss_sum, correct = es(base, lora, rm, lm, tokens, labels)
    assert float(correct) <= CFG.batch_size
    assert float(loss_sum) > 0.0


def test_pallas_model_parity(base, batch):
    """The pallas-backed forward must equal the jnp-backed forward —
    this pins L1 == L2 at the model level, not just per-kernel."""
    tokens, _ = batch
    lora = model.init_lora(CFG, jax.random.PRNGKey(7))
    lora = dict(lora, bq=jnp.ones_like(lora["bq"]) * 0.05,
                bv=jnp.ones_like(lora["bv"]) * 0.05)
    rm = jnp.ones((CFG.n_layers, CFG.r_max)).at[:, 3:].set(0.0)
    lm = jnp.ones(CFG.n_layers).at[0].set(0.0)
    ref_logits, _ = model.encoder_forward(CFG, base, lora, rm, lm,
                                          tokens, use_pallas=False)
    pal_logits, _ = model.encoder_forward(CFG, base, lora, rm, lm,
                                          tokens, use_pallas=True)
    np.testing.assert_allclose(np.asarray(pal_logits),
                               np.asarray(ref_logits), rtol=1e-4,
                               atol=1e-4)


def test_adapter_zero_width_is_identity_model(base, batch):
    """Width-masked-out adapters must not change the forward pass."""
    tokens, _ = batch
    ad = model.init_adapter(CFG, jax.random.PRNGKey(8))
    ad = dict(ad, up=jnp.ones_like(ad["up"]))
    wm0 = jnp.zeros((CFG.n_layers, CFG.adapter_w_max))
    lm = jnp.ones(CFG.n_layers)
    with_ad, _ = model.encoder_forward(CFG, base, ad, wm0, lm, tokens,
                                       family="adapter")
    ad_zero = dict(ad, up=jnp.zeros_like(ad["up"]))
    wm1 = jnp.ones((CFG.n_layers, CFG.adapter_w_max))
    without, _ = model.encoder_forward(CFG, base, ad_zero, wm1, lm,
                                       tokens, family="adapter")
    np.testing.assert_allclose(np.asarray(with_ad), np.asarray(without),
                               rtol=1e-5, atol=1e-5)


def test_flatten_roundtrip():
    lora = model.init_lora(CFG, jax.random.PRNGKey(10))
    flat = model.flatten_trainable(lora)
    assert len(flat) == len(model.LORA_ORDER)
    back = model.unflatten_trainable(flat)
    for n in model.LORA_ORDER:
        np.testing.assert_array_equal(np.asarray(back[n]),
                                      np.asarray(lora[n]))
    opt = model.init_opt(lora)
    oflat = model.flatten_opt(opt)
    assert len(oflat) == 2 * len(flat)
    oback = model.unflatten_opt(oflat)
    assert set(oback) == set(opt)


def test_loss_decreases_under_training(base):
    spec = configs.task_spec()
    # tiny config has a smaller vocab than the spec; clip token ids.
    from compile import datagen
    rng = np.random.default_rng(0)
    ts = jax.jit(model.make_train_step(CFG))
    lora = model.init_lora(CFG, jax.random.PRNGKey(11))
    opt = model.init_opt(lora)
    rm, lm = full_masks()
    losses = []
    for step in range(1, 41):
        toks, labels = datagen.labeled_batch(spec, "sst2",
                                             CFG.batch_size, rng)
        toks = np.clip(toks, 0, CFG.vocab_size - 1)[:, :CFG.seq_len]
        lora, opt, loss, _ = ts(base, lora, opt, rm, lm,
                                jnp.asarray(toks),
                                jnp.asarray(labels % CFG.n_classes),
                                5e-3, float(step))
        losses.append(float(loss))
    assert np.mean(losses[-10:]) < np.mean(losses[:10])
