"""AOT path tests: lowering produces parseable HLO text with the
manifest-declared IO contract; base weights serialize round-trip."""

import json
import os

import jax
import numpy as np
import pytest

from compile import aot, configs, model, pretrain

CFG = configs.TINY


def test_hlo_text_lowering_roundtrips_through_xla():
    """to_hlo_text output must be (a) HLO text, (b) numerically equal
    to direct jax execution when re-imported."""
    train_txt, eval_txt, frag = aot.lower_family(CFG, "lora")
    assert train_txt.startswith("HloModule")
    assert eval_txt.startswith("HloModule")
    # IO contract matches the flattening helpers.
    nb = len(model.BASE_ORDER)
    nt = len(model.LORA_ORDER)
    no = len(model.opt_order("lora"))
    assert len(frag["train"]["inputs"]) == nb + nt + no + 6
    assert len(frag["train"]["outputs"]) == nt + no + 2
    assert len(frag["eval"]["inputs"]) == nb + nt + 4
    assert frag["eval"]["outputs"] == ["loss_sum", "correct"]


def test_adapter_family_lowering():
    train_txt, _, frag = aot.lower_family(CFG, "adapter")
    assert train_txt.startswith("HloModule")
    assert len(frag["trainable"]) == len(model.ADAPTER_ORDER)


def test_kernel_lowering():
    txt, frag = aot.lower_kernel(CFG)
    assert txt.startswith("HloModule")
    assert frag["artifact"] == "lora_kernel.hlo.txt"
    m, k = frag["shapes"]["x"]
    assert (m, k) == (64, CFG.d_model)


def test_base_weights_roundtrip(tmp_path):
    base = model.init_base(CFG, jax.random.PRNGKey(3))
    path = str(tmp_path / "base.bin")
    n = pretrain.save_base(base, path)
    assert n == sum(
        int(np.prod(model.base_shapes(CFG)[k])) for k in model.BASE_ORDER
    ) * 4
    loaded = pretrain.load_base(CFG, path)
    for k in model.BASE_ORDER:
        np.testing.assert_array_equal(np.asarray(loaded[k]),
                                      np.asarray(base[k]))


def test_pretrain_reduces_mlm_loss():
    base = pretrain.pretrain_base(CFG, steps=30, batch=8, log_every=0)
    # Smoke: returned params are finite and shaped.
    for k in model.BASE_ORDER:
        assert bool(np.isfinite(np.asarray(base[k])).all()), k


@pytest.mark.skipif(
    not os.path.exists(
        os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")),
    reason="artifacts not built")
def test_built_manifest_consistent_with_model():
    root = os.path.join(os.path.dirname(__file__), "../../artifacts")
    with open(os.path.join(root, "manifest.json")) as f:
        man = json.load(f)
    cfg = configs.ModelConfig(**man["model"])
    assert [t["name"] for t in man["base"]] == model.BASE_ORDER
    shapes = model.base_shapes(cfg)
    for t in man["base"]:
        assert tuple(t["shape"]) == shapes[t["name"]], t["name"]
    size = os.path.getsize(os.path.join(root, "base_weights.bin"))
    assert size == man["base_bytes"]
    for fam in ("lora", "adapter"):
        art = man["families"][fam]["train"]["artifact"]
        head = open(os.path.join(root, art)).read(9)
        assert head == "HloModule"
