"""Pallas adapter kernel vs the pure-jnp oracle (hypothesis sweep)."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import adapter, ref


def _check(m, d, w, mask_frac, block_m, seed):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((m, d)).astype(np.float32)
    down = rng.standard_normal((d, w)).astype(np.float32) * 0.3
    up = rng.standard_normal((w, d)).astype(np.float32) * 0.3
    b = rng.standard_normal(w).astype(np.float32) * 0.1
    mask = (rng.random(w) < mask_frac).astype(np.float32)
    got = adapter.adapter_forward(x, down, up, b, jnp.asarray(mask),
                                  block_m=block_m)
    want = ref.adapter_ref(x, down, up, b, jnp.asarray(mask))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 80),
    d=st.integers(1, 48),
    w=st.integers(1, 32),
    mask_frac=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**31),
)
def test_adapter_kernel_matches_ref(m, d, w, mask_frac, seed):
    _check(m, d, w, mask_frac, 32, seed)


@settings(max_examples=8, deadline=None)
@given(block_m=st.sampled_from([4, 16, 64, 128]),
       seed=st.integers(0, 2**31))
def test_adapter_block_invariance(block_m, seed):
    _check(50, 24, 16, 0.7, block_m, seed)


def test_zero_width_is_identity():
    rng = np.random.default_rng(0)
    x = rng.standard_normal((8, 16)).astype(np.float32)
    down = rng.standard_normal((16, 8)).astype(np.float32)
    up = rng.standard_normal((8, 16)).astype(np.float32)
    b = rng.standard_normal(8).astype(np.float32)
    got = adapter.adapter_forward(x, down, up, b, jnp.zeros(8),
                                  block_m=8)
    np.testing.assert_allclose(np.asarray(got), x, rtol=1e-6, atol=1e-6)


def test_vmem_fits_budget():
    assert adapter.vmem_bytes(128, 128, 32) < 16 * 2**20
