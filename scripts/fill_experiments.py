#!/usr/bin/env python3
"""Fill EXPERIMENTS.md <!-- RESULTS --> from results/*.csv.

Build-tooling only (not part of the request path): summarizes each
experiment CSV into the paper-style rows quoted in EXPERIMENTS.md.
"""

import csv
import glob
import os
import sys


def load(path):
    runs = {}
    with open(path) as f:
        for row in csv.DictReader(f):
            key = (row["method"], row["task"])
            runs.setdefault(key, []).append(row)
    return runs


def summarize(path):
    runs = load(path)
    if not runs:
        return ""
    # shared target = min over methods of best acc, with tolerance
    best = {k: max(float(r["test_acc"]) for r in v)
            for k, v in runs.items()}
    target = min(best.values()) * 0.995
    lines = [f"### {os.path.basename(path)[:-4]} (target acc {target:.3f})",
             "",
             "| method | task | best acc | t→target | traffic→target | wait avg |",
             "|---|---|---|---|---|---|"]
    # reference time = slowest to target
    times = {}
    for k, v in runs.items():
        t = next((float(r["sim_time"]) for r in v
                  if float(r["test_acc"]) >= target), None)
        times[k] = t
    worst = max((t for t in times.values() if t), default=None)
    for k, v in sorted(runs.items()):
        t = times[k]
        traffic = 0
        tt = None
        for r in v:
            traffic += int(r["up_bytes"]) + int(r["down_bytes"])
            if tt is None and float(r["test_acc"]) >= target:
                tt = traffic
        wait = sum(float(r["avg_waiting"]) for r in v) / len(v)
        speed = f"{worst/t:.2f}×" if (t and worst) else "—"
        lines.append(
            f"| {k[0]} | {k[1]} | {best[k]:.3f} | "
            f"{f'{t:.0f}s ({speed})' if t else '—'} | "
            f"{f'{tt/1e6:.1f} MB' if tt else '—'} | {wait:.1f}s |")
    lines.append("")
    return "\n".join(lines)


def main():
    blocks = []
    for path in sorted(glob.glob("results/fig*.csv")):
        blocks.append(summarize(path))
    text = open("EXPERIMENTS.md").read()
    marker = "<!-- RESULTS -->"
    if marker not in text:
        print("marker missing", file=sys.stderr)
        sys.exit(1)
    text = text.replace(marker, "\n\n".join(blocks) or marker, 1)
    # e2e block if present
    e2e = "results/e2e_sst2.csv"
    if os.path.exists(e2e):
        text = text.replace("<!-- E2E -->", summarize(e2e), 1)
    open("EXPERIMENTS.md", "w").write(text)
    print(f"filled EXPERIMENTS.md with {len(blocks)} experiment blocks")


if __name__ == "__main__":
    main()
