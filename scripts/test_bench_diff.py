"""Unit tests for bench_diff.py (stdlib unittest only).

Run from the repo root:

    python3 -m unittest discover -s scripts -p 'test_*.py' -v

Covers the behaviours CI leans on: null-baseline leaves fail strict
runs with the distinct EXIT_UNMEASURED code, leaves the baseline
tracks but the run stopped reporting are regressions (except whole
sections omitted by a filtered bench run), and the rss_ratio /
savings_ratio hard bounds fire independently of the baseline.
"""

import importlib.util
import json
import os
import tempfile
import unittest

_HERE = os.path.dirname(os.path.abspath(__file__))
_SPEC = importlib.util.spec_from_file_location(
    "bench_diff", os.path.join(_HERE, "bench_diff.py"))
bench_diff = importlib.util.module_from_spec(_SPEC)
_SPEC.loader.exec_module(bench_diff)


class CompareTests(unittest.TestCase):
    def cmp(self, baseline, current, tolerance=0.5):
        return bench_diff.compare(baseline, current, tolerance)

    def test_null_baseline_leaf_is_unmeasured(self):
        reg, ok, unmeasured, missing = self.cmp(
            {"fold": {"single_ms": None}},
            {"fold": {"single_ms": 12.5}})
        self.assertEqual(reg, [])
        self.assertEqual(unmeasured, ["fold.single_ms"])
        self.assertEqual(missing, [])

    def test_measured_leaf_within_tolerance_is_ok(self):
        reg, ok, unmeasured, missing = self.cmp(
            {"fold": {"single_ms": 10.0}},
            {"fold": {"single_ms": 14.0}})
        self.assertEqual(reg, [])
        self.assertEqual(unmeasured, [])

    def test_measured_leaf_beyond_tolerance_regresses(self):
        reg, _, _, _ = self.cmp(
            {"fold": {"single_ms": 10.0}},
            {"fold": {"single_ms": 16.0}})
        self.assertEqual(reg, [("fold.single_ms", 10.0, 16.0)])

    def test_config_echo_must_match_exactly(self):
        reg, _, _, _ = self.cmp(
            {"fold": {"devices": 256}},
            {"fold": {"devices": 128}})
        self.assertEqual(reg, [("fold.devices", 256, 128)])

    def test_missing_current_leaf_is_flagged(self):
        # The baseline tracks par_ms but the run stopped reporting it.
        reg, ok, unmeasured, missing = self.cmp(
            {"fleets": [{"seq_ms": 10.0, "par_ms": 5.0}]},
            {"fleets": [{"seq_ms": 10.0}]})
        self.assertEqual(reg, [])
        self.assertEqual(missing, ["fleets[0].par_ms"])

    def test_null_baseline_leaf_missing_from_current_is_quiet(self):
        # Unmeasured AND unreported: nothing to compare, nothing lost.
        reg, ok, unmeasured, missing = self.cmp(
            {"fold": {"single_ms": None}}, {"fold": {}})
        self.assertEqual((reg, unmeasured, missing), ([], [], []))

    def test_rss_ratio_bound_fires_even_with_null_baseline(self):
        reg, _, unmeasured, _ = self.cmp(
            {"lazy": {"rss_ratio": None}},
            {"lazy": {"rss_ratio": 11.0}})
        self.assertEqual(
            reg, [("lazy.rss_ratio", bench_diff.RSS_RATIO_BOUND, 11.0)])
        self.assertEqual(unmeasured, [])

    def test_rss_ratio_within_bound_is_ok(self):
        reg, ok, _, _ = self.cmp(
            {"lazy": {"rss_ratio": None}},
            {"lazy": {"rss_ratio": 3.5}})
        self.assertEqual(reg, [])
        self.assertEqual(
            ok, [("lazy.rss_ratio", bench_diff.RSS_RATIO_BOUND, 3.5)])

    def test_filtered_out_section_is_not_missing(self):
        # `cargo bench -- engine_lazy` emits only its own section; the
        # other sections' numeric config echoes must not read as the
        # bench having silently stopped measuring them.
        reg, ok, unmeasured, missing = self.cmp(
            {"fleets": [{"devices": 8, "seq_ms": 10.0}],
             "lazy": {"rss_ratio": None}},
            {"lazy": {"rss_ratio": 3.5}})
        self.assertEqual((reg, unmeasured, missing), ([], [], []))

    def test_savings_ratio_bound_fires_even_with_null_baseline(self):
        reg, _, unmeasured, _ = self.cmp(
            {"codec": {"int8_savings_ratio": None}},
            {"codec": {"int8_savings_ratio": 0.20}})
        self.assertEqual(
            reg, [("codec.int8_savings_ratio",
                   bench_diff.SAVINGS_RATIO_BOUND, 0.20)])
        self.assertEqual(unmeasured, [])

    def test_savings_ratio_at_or_above_bound_is_ok(self):
        reg, ok, _, _ = self.cmp(
            {"codec": {"int8_savings_ratio": None}},
            {"codec": {"int8_savings_ratio": 0.37}})
        self.assertEqual(reg, [])
        self.assertEqual(
            ok, [("codec.int8_savings_ratio",
                  bench_diff.SAVINGS_RATIO_BOUND, 0.37)])

    def test_realloc_overhead_bound_fires_even_with_null_baseline(self):
        reg, _, unmeasured, _ = self.cmp(
            {"realloc": {"realloc_overhead_ratio": None}},
            {"realloc": {"realloc_overhead_ratio": 2.2}})
        self.assertEqual(
            reg, [("realloc.realloc_overhead_ratio",
                   bench_diff.REALLOC_OVERHEAD_BOUND, 2.2)])
        self.assertEqual(unmeasured, [])

    def test_realloc_overhead_within_bound_is_ok(self):
        reg, ok, _, _ = self.cmp(
            {"realloc": {"realloc_overhead_ratio": None}},
            {"realloc": {"realloc_overhead_ratio": 1.05}})
        self.assertEqual(reg, [])
        self.assertEqual(
            ok, [("realloc.realloc_overhead_ratio",
                  bench_diff.REALLOC_OVERHEAD_BOUND, 1.05)])

    def test_multijob_overhead_bound_fires_even_with_null_baseline(self):
        reg, _, unmeasured, _ = self.cmp(
            {"multijob": {"multijob_overhead_ratio": None}},
            {"multijob": {"multijob_overhead_ratio": 1.8}})
        self.assertEqual(
            reg, [("multijob.multijob_overhead_ratio",
                   bench_diff.MULTIJOB_OVERHEAD_BOUND, 1.8)])
        self.assertEqual(unmeasured, [])

    def test_multijob_overhead_within_bound_is_ok(self):
        reg, ok, _, _ = self.cmp(
            {"multijob": {"multijob_overhead_ratio": None}},
            {"multijob": {"multijob_overhead_ratio": 1.1}})
        self.assertEqual(reg, [])
        self.assertEqual(
            ok, [("multijob.multijob_overhead_ratio",
                  bench_diff.MULTIJOB_OVERHEAD_BOUND, 1.1)])

    def test_note_leaves_are_ignored(self):
        reg, ok, unmeasured, missing = self.cmp(
            {"note": "schema doc", "n": 1},
            {"note": "other doc", "n": 1})
        self.assertEqual((reg, unmeasured, missing), ([], [], []))


class MainExitCodeTests(unittest.TestCase):
    def run_main(self, baseline, current, *flags):
        with tempfile.TemporaryDirectory() as d:
            cur_path = os.path.join(d, "BENCH_engine.json")
            base_path = os.path.join(d, "BENCH_baseline.json")
            with open(cur_path, "w") as f:
                json.dump(current, f)
            with open(base_path, "w") as f:
                json.dump(baseline, f)
            return bench_diff.main(
                [cur_path, "--baseline", base_path, *flags])

    def test_strict_null_baseline_exits_unmeasured(self):
        code = self.run_main({"fold": {"single_ms": None}},
                             {"fold": {"single_ms": 12.5}}, "--strict")
        self.assertEqual(code, bench_diff.EXIT_UNMEASURED)

    def test_strict_regression_exits_regression(self):
        code = self.run_main({"fold": {"single_ms": 10.0}},
                             {"fold": {"single_ms": 100.0}}, "--strict")
        self.assertEqual(code, bench_diff.EXIT_REGRESSION)

    def test_strict_regression_outranks_unmeasured(self):
        code = self.run_main(
            {"fold": {"single_ms": 10.0, "sharded_ms": None}},
            {"fold": {"single_ms": 100.0, "sharded_ms": 2.0}},
            "--strict")
        self.assertEqual(code, bench_diff.EXIT_REGRESSION)

    def test_strict_missing_leaf_exits_regression(self):
        code = self.run_main({"fold": {"single_ms": 10.0}},
                             {"fold": {}}, "--strict")
        self.assertEqual(code, bench_diff.EXIT_REGRESSION)

    def test_strict_rss_bound_violation_exits_regression(self):
        code = self.run_main({"lazy": {"rss_ratio": None}},
                             {"lazy": {"rss_ratio": 50.0}}, "--strict")
        self.assertEqual(code, bench_diff.EXIT_REGRESSION)

    def test_strict_savings_bound_violation_exits_regression(self):
        code = self.run_main(
            {"codec": {"int8_savings_ratio": None}},
            {"codec": {"int8_savings_ratio": 0.1}}, "--strict")
        self.assertEqual(code, bench_diff.EXIT_REGRESSION)

    def test_strict_realloc_bound_violation_exits_regression(self):
        code = self.run_main(
            {"realloc": {"realloc_overhead_ratio": None}},
            {"realloc": {"realloc_overhead_ratio": 3.0}}, "--strict")
        self.assertEqual(code, bench_diff.EXIT_REGRESSION)

    def test_strict_multijob_bound_violation_exits_regression(self):
        code = self.run_main(
            {"multijob": {"multijob_overhead_ratio": None}},
            {"multijob": {"multijob_overhead_ratio": 2.4}}, "--strict")
        self.assertEqual(code, bench_diff.EXIT_REGRESSION)

    def test_strict_filtered_run_tolerates_absent_sections(self):
        # The scale-smoke job diffs an engine_lazy-only doc against the
        # full baseline: sections the filter skipped are not missing.
        code = self.run_main(
            {"fleets": [{"devices": 8, "seq_ms": 10.0}],
             "lazy": {"cohort": 1000, "lazy_round_ms": None}},
            {"lazy": {"cohort": 1000, "lazy_round_ms": 5.0}},
            "--strict")
        self.assertEqual(code, bench_diff.EXIT_UNMEASURED)

    def test_strict_clean_measured_run_exits_ok(self):
        code = self.run_main({"fold": {"single_ms": 10.0}},
                             {"fold": {"single_ms": 9.0}}, "--strict")
        self.assertEqual(code, bench_diff.EXIT_OK)

    def test_non_strict_never_fails_on_nulls_or_regressions(self):
        code = self.run_main(
            {"fold": {"single_ms": 10.0, "sharded_ms": None}},
            {"fold": {"single_ms": 100.0, "sharded_ms": 2.0}})
        self.assertEqual(code, bench_diff.EXIT_OK)

    def test_update_trims_measurement_onto_schema(self):
        with tempfile.TemporaryDirectory() as d:
            cur_path = os.path.join(d, "BENCH_engine.json")
            base_path = os.path.join(d, "BENCH_baseline.json")
            with open(cur_path, "w") as f:
                json.dump({"fold": {"single_ms": 12.5, "stray": 1}}, f)
            with open(base_path, "w") as f:
                json.dump({"note": "doc",
                           "fold": {"single_ms": None}}, f)
            code = bench_diff.main(
                [cur_path, "--baseline", base_path, "--update"])
            self.assertEqual(code, bench_diff.EXIT_OK)
            with open(base_path) as f:
                updated = json.load(f)
            # Measured value lands, note survives, stray key dropped.
            self.assertEqual(
                updated, {"note": "doc", "fold": {"single_ms": 12.5}})


if __name__ == "__main__":
    unittest.main()
