#!/usr/bin/env python3
"""Diff a fresh BENCH_engine.json against the committed baseline.

The engine bench (rust/benches/bench_main.rs) writes BENCH_engine.json
at the workspace root; BENCH_baseline.json is the committed reference.
This helper compares the two so a PR's bench run can be sanity-checked
without eyeballing raw JSON:

    python3 scripts/bench_diff.py BENCH_engine.json
    python3 scripts/bench_diff.py --strict --tolerance 0.5 BENCH_engine.json
    python3 scripts/bench_diff.py --update BENCH_engine.json

Semantics:
  * Numeric leaves are compared pairwise by JSON path. Wall-clock
    numbers are noisy across runners, so a regression is only flagged
    when the new value exceeds baseline * (1 + tolerance).
  * `rss_ratio` is special-cased as a hard bound: the lazy-fleet
    acceptance criterion is peak RSS within 10x of the eager-80 run,
    independent of runner speed.
  * `*savings_ratio` leaves are the mirror case, a hard LOWER bound:
    the codec acceptance criterion is int8+delta cutting total
    bytes-on-wire by at least 35% vs codec=none (docs/TRANSPORT.md),
    independent of what the baseline recorded.
  * `*realloc_overhead_ratio` is a hard UPPER bound: periodic LCD
    re-allocation (docs/ADAPTIVE.md) is an O(cohort) coordinator-side
    refit, so a run with --realloc-every 2 may cost at most 1.5x the
    static-plan run, independent of runner speed.
  * `*multijob_overhead_ratio` is the same kind of hard UPPER bound
    for the multi-job scheduler (docs/MULTIJOB.md): running 2 jobs
    through JobScheduler may cost at most 1.5x the two equivalent
    single-job engine runs back-to-back — partitioning and token
    buckets are bookkeeping, not a second training pass.
  * A null baseline leaf means the committed baseline is unmeasured at
    that path. It is reported with a clear message and, under --strict,
    fails with a DISTINCT exit code (2) so CI can tell "baseline was
    never populated" apart from "the code got slower" (exit 1). Fill
    baselines in from a CI artifact with --update, which trims the
    measurement doc onto the baseline schema (keys the baseline
    doesn't know are dropped).
  * A numeric baseline leaf that the current measurement no longer
    reports is a regression (the bench silently stopped measuring
    something the baseline tracks) — unless the leaf's whole top-level
    section is absent, which is how a filtered bench run
    (`cargo bench -- engine_lazy`) looks and is not a loss.
  * Exit code is non-zero only under --strict; the default mode is
    informational so local runs on slow machines don't fail.

Stdlib only — the container has no third-party Python packages.
"""

import argparse
import json
import sys

RSS_RATIO_BOUND = 10.0  # acceptance: lazy peak RSS <= 10x eager-80
SAVINGS_RATIO_BOUND = 0.35  # acceptance: codec saves >= 35% of bytes
REALLOC_OVERHEAD_BOUND = 1.5  # acceptance: realloc run <= 1.5x static
MULTIJOB_OVERHEAD_BOUND = 1.5  # acceptance: 2-job sched <= 1.5x serial

EXIT_OK = 0
EXIT_REGRESSION = 1  # a measured value regressed (or went missing)
EXIT_UNMEASURED = 2  # baseline has null leaves; populate with --update


def leaves(node, path=""):
    """Yield (json_path, value) for every scalar leaf."""
    if isinstance(node, dict):
        for k in sorted(node):
            yield from leaves(node[k], f"{path}.{k}" if path else k)
    elif isinstance(node, list):
        for i, v in enumerate(node):
            yield from leaves(v, f"{path}[{i}]")
    else:
        yield path, node


def compare(baseline, current, tolerance):
    """Return (regressions, improvements, unmeasured, missing)."""
    base = dict(leaves(baseline))
    cur_paths = set()
    regressions, improvements, unmeasured = [], [], []
    for path, cur in leaves(current):
        if path.endswith(".note") or path == "note":
            continue
        cur_paths.add(path)
        ref = base.get(path)
        if not isinstance(cur, (int, float)) or isinstance(cur, bool):
            continue
        if path.endswith("rss_ratio"):
            if cur > RSS_RATIO_BOUND:
                regressions.append((path, RSS_RATIO_BOUND, cur))
            else:
                improvements.append((path, RSS_RATIO_BOUND, cur))
            continue
        if path.endswith("savings_ratio"):
            if cur < SAVINGS_RATIO_BOUND:
                regressions.append((path, SAVINGS_RATIO_BOUND, cur))
            else:
                improvements.append((path, SAVINGS_RATIO_BOUND, cur))
            continue
        if path.endswith("realloc_overhead_ratio"):
            if cur > REALLOC_OVERHEAD_BOUND:
                regressions.append((path, REALLOC_OVERHEAD_BOUND, cur))
            else:
                improvements.append((path, REALLOC_OVERHEAD_BOUND, cur))
            continue
        if path.endswith("multijob_overhead_ratio"):
            if cur > MULTIJOB_OVERHEAD_BOUND:
                regressions.append((path, MULTIJOB_OVERHEAD_BOUND, cur))
            else:
                improvements.append((path, MULTIJOB_OVERHEAD_BOUND, cur))
            continue
        if ref is None or not isinstance(ref, (int, float)):
            unmeasured.append(path)
            continue
        # Counts/config echoes (devices, rounds, ...) must match exactly;
        # only *_ms / *_s / *_kb measurements get the noise tolerance.
        noisy = any(path.endswith(s)
                    for s in ("_ms", "_s", "_kb", "speedup"))
        if noisy:
            if cur > ref * (1.0 + tolerance):
                regressions.append((path, ref, cur))
            elif cur < ref:
                improvements.append((path, ref, cur))
        elif cur != ref:
            regressions.append((path, ref, cur))
    # Numeric baseline leaves the current run no longer reports: the
    # bench silently stopped measuring something the baseline tracks.
    # A top-level section wholly absent from the current doc is a
    # *filtered* bench run (`cargo bench -- engine_lazy` emits only its
    # own section), not a silent loss — only sections the run did emit
    # are held to this.
    emitted = set(current) if isinstance(current, dict) else set()

    def section(path):
        return path.split(".", 1)[0].split("[", 1)[0]

    missing = [
        path
        for path, ref in sorted(base.items())
        if isinstance(ref, (int, float)) and not isinstance(ref, bool)
        and not (path.endswith(".note") or path == "note")
        and path not in cur_paths
        and section(path) in emitted
    ]
    return regressions, improvements, unmeasured, missing


def trim_onto(schema, measured):
    """Copy measured values onto the baseline schema, keeping only the
    keys the schema already declares (the 'trimmed' baseline)."""
    if isinstance(schema, dict):
        out = {}
        for k, v in schema.items():
            if k == "note":
                out[k] = v
            elif isinstance(measured, dict) and k in measured:
                out[k] = trim_onto(v, measured[k])
            else:
                out[k] = v
        return out
    if isinstance(schema, list) and isinstance(measured, list):
        return [trim_onto(s, m) for s, m in zip(schema, measured)]
    return measured if measured is not None else schema


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("engine_json", nargs="?",
                    default="BENCH_engine.json",
                    help="fresh bench output (default: BENCH_engine.json)")
    ap.add_argument("--baseline", default="BENCH_baseline.json")
    ap.add_argument("--tolerance", type=float, default=0.5,
                    help="allowed relative slowdown for timings "
                         "(default 0.5 = 50%%)")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 on any regression, 2 on an "
                         "unmeasured (null) baseline leaf")
    ap.add_argument("--update", action="store_true",
                    help="trim the measurement onto the baseline "
                         "schema and rewrite it")
    args = ap.parse_args(argv)

    try:
        with open(args.engine_json) as f:
            current = json.load(f)
    except OSError as e:
        print(f"cannot read {args.engine_json}: {e}")
        return EXIT_REGRESSION
    try:
        with open(args.baseline) as f:
            baseline = json.load(f)
    except OSError as e:
        print(f"no baseline ({e}); nothing to diff against")
        return EXIT_OK

    if args.update:
        updated = trim_onto(baseline, current)
        with open(args.baseline, "w") as f:
            json.dump(updated, f, indent=2)
            f.write("\n")
        print(f"updated {args.baseline} from {args.engine_json}")
        return EXIT_OK

    regressions, improvements, unmeasured, missing = compare(
        baseline, current, args.tolerance)
    for path, ref, cur in improvements:
        print(f"  ok         {path}: {ref} -> {cur}")
    for path in unmeasured:
        print(f"  UNMEASURED {path}: baseline is null — populate it "
              f"via `bench_diff.py --update` from a CI bench artifact")
    for path in missing:
        print(f"  MISSING    {path}: baseline tracks this leaf but "
              f"the current run no longer reports it")
    for path, ref, cur in regressions:
        print(f"  REGRESSED  {path}: {ref} -> {cur}")
    print(f"{len(regressions)} regression(s), {len(missing)} missing, "
          f"{len(improvements)} ok, {len(unmeasured)} unmeasured")
    if args.strict and (regressions or missing):
        return EXIT_REGRESSION
    if args.strict and unmeasured:
        print(f"strict mode: {len(unmeasured)} unmeasured baseline "
              f"leaf/leaves (exit {EXIT_UNMEASURED})")
        return EXIT_UNMEASURED
    return EXIT_OK


if __name__ == "__main__":
    sys.exit(main())
