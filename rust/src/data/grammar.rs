//! Synthetic task generators — the rust implementation of the grammar
//! spec in `artifacts/vocab.json` (authored in python/compile/configs.py,
//! mirrored by python/compile/datagen.py for the pretraining corpus).
//!
//! Three grammar kinds (DESIGN.md §2):
//!  * `single` — CLS + shuffled mix of k label-bank words and
//!    background (80% filler / 20% noise) words;
//!  * `pair`   — CLS + filler premise + SEP + hypothesis carrying the
//!    label-bank words (forces attention across the separator);
//!  * `arith`  — CLS d1 + d2 + d3 SEP with label = Σdᵢ mod n_classes
//!    (gsm-syn: the model must actually add — slow convergence, like
//!    GSM-8K in the paper's Fig. 10).

use super::{Dataset, Example, Kind, Spec, TaskSpec};
use crate::util::rng::Rng;

fn bg_word(spec: &Spec, rng: &mut Rng) -> i32 {
    let (lo, hi) = if rng.bernoulli(0.8) { spec.filler } else { spec.noise };
    rng.range(lo, hi) as i32
}

fn sample_single(spec: &Spec, task: &TaskSpec, label: usize,
                 rng: &mut Rng) -> Vec<i32> {
    let len = rng.range_incl(task.len_range.0, task.len_range.1);
    let k = rng.range_incl(task.bank_words.0, task.bank_words.1);
    let (blo, bhi) = task.banks[label];
    let mut words: Vec<i32> = (0..k).map(|_| rng.range(blo, bhi) as i32)
        .collect();
    for _ in 0..len.saturating_sub(k) {
        words.push(bg_word(spec, rng));
    }
    rng.shuffle(&mut words);
    let mut toks = vec![spec.cls];
    toks.extend(words);
    toks
}

fn sample_pair(spec: &Spec, task: &TaskSpec, label: usize,
               rng: &mut Rng) -> Vec<i32> {
    let prem_len = rng.range_incl(task.len_range.0, task.len_range.1);
    let hyp_len = rng.range_incl(task.len_range.0, task.len_range.1);
    let k = rng.range_incl(task.bank_words.0, task.bank_words.1);
    let (blo, bhi) = task.banks[label];
    let mut hyp: Vec<i32> =
        (0..k).map(|_| rng.range(blo, bhi) as i32).collect();
    for _ in 0..hyp_len.saturating_sub(k) {
        hyp.push(bg_word(spec, rng));
    }
    rng.shuffle(&mut hyp);
    let mut toks = vec![spec.cls];
    for _ in 0..prem_len {
        toks.push(bg_word(spec, rng));
    }
    toks.push(spec.sep);
    toks.extend(hyp);
    toks
}

fn sample_arith(spec: &Spec, digits: &[usize], ops: &[usize],
                n_terms: usize, n_classes: usize,
                rng: &mut Rng) -> (Vec<i32>, usize) {
    let plus = ops[0] as i32;
    let mut toks = vec![spec.cls];
    let mut sum = 0usize;
    for i in 0..n_terms {
        if i > 0 {
            toks.push(plus);
        }
        let d = rng.range(0, 10);
        sum += d;
        toks.push(digits[0] as i32 + d as i32);
    }
    toks.push(spec.sep);
    (toks, sum % n_classes)
}

/// One (tokens, label) example, PADed/truncated to `spec.seq_len`.
pub fn sample_example(spec: &Spec, task: &TaskSpec,
                      rng: &mut Rng) -> Example {
    let (mut toks, mut label) = match &task.kind {
        Kind::Arith { digits, ops, n_terms } => {
            sample_arith(spec, digits, ops, *n_terms, task.n_classes, rng)
        }
        kind => {
            let label = rng.range(0, task.n_classes);
            let toks = match kind {
                Kind::Single => sample_single(spec, task, label, rng),
                Kind::Pair => sample_pair(spec, task, label, rng),
                Kind::Arith { .. } => unreachable!(),
            };
            (toks, label)
        }
    };
    if rng.bernoulli(task.label_noise) {
        label = rng.range(0, task.n_classes);
    }
    toks.truncate(spec.seq_len);
    while toks.len() < spec.seq_len {
        toks.push(spec.pad);
    }
    Example { tokens: toks, label: label as i32 }
}

/// One example with a *prescribed* label (before label noise) —
/// the sampler behind per-device Dirichlet mixtures, where the label
/// is drawn from the device's class distribution first and the tokens
/// must then realize it.
pub fn sample_labeled(spec: &Spec, task: &TaskSpec, label: usize,
                      rng: &mut Rng) -> Example {
    let mut label = label.min(task.n_classes.saturating_sub(1));
    let mut toks = match &task.kind {
        Kind::Single => sample_single(spec, task, label, rng),
        Kind::Pair => sample_pair(spec, task, label, rng),
        Kind::Arith { digits, ops, n_terms } => {
            // Free digits for all terms but the last; the last digit is
            // chosen so the sum lands in the requested class.
            let plus = ops[0] as i32;
            let mut toks = vec![spec.cls];
            let mut sum = 0usize;
            for i in 0..n_terms.saturating_sub(1) {
                if i > 0 {
                    toks.push(plus);
                }
                let d = rng.range(0, 10);
                sum += d;
                toks.push(digits[0] as i32 + d as i32);
            }
            let candidates: Vec<usize> = (0..10)
                .filter(|d| (sum + d) % task.n_classes == label)
                .collect();
            let d = if candidates.is_empty() {
                // Unreachable for n_classes ≤ 10; keep the draw valid.
                rng.range(0, 10)
            } else {
                *rng.choice(&candidates)
            };
            label = (sum + d) % task.n_classes;
            if *n_terms > 1 {
                toks.push(plus);
            }
            toks.push(digits[0] as i32 + d as i32);
            toks.push(spec.sep);
            toks
        }
    };
    if rng.bernoulli(task.label_noise) {
        label = rng.range(0, task.n_classes);
    }
    toks.truncate(spec.seq_len);
    while toks.len() < spec.seq_len {
        toks.push(spec.pad);
    }
    Example { tokens: toks, label: label as i32 }
}

/// Generate a labeled dataset of `n` examples for `task_name`.
pub fn generate(spec: &Spec, task_name: &str, n: usize,
                rng: &mut Rng) -> Result<Dataset, super::DataError> {
    let task = spec.task(task_name)?.clone();
    let examples = (0..n).map(|_| sample_example(spec, &task, rng)).collect();
    Ok(Dataset { examples })
}

/// Train/test split sizes per task, scaled from the paper's Table 2
/// (proportions preserved; absolute sizes scaled to the simulator).
pub fn paper_scaled_sizes(task: &str, scale: f64) -> (usize, usize) {
    let (train, test) = match task {
        "sst2" => (67_349, 1_821),
        "qnli" => (104_743, 5_463),
        "qqp" => (363_846, 40_430),
        "mnli" => (392_702, 9_815),
        "gsm" => (7_473, 1_319),
        "mmlu" => (20_000, 2_000),
        _ => (10_000, 1_000),
    };
    (
        ((train as f64 * scale) as usize).max(64),
        ((test as f64 * scale) as usize).max(64),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::tests::test_spec;

    #[test]
    fn examples_are_padded_and_in_vocab() {
        let spec = test_spec();
        let mut rng = Rng::new(1);
        let ds = generate(&spec, "sst2", 200, &mut rng).unwrap();
        assert_eq!(ds.len(), 200);
        for ex in &ds.examples {
            assert_eq!(ex.tokens.len(), spec.seq_len);
            assert_eq!(ex.tokens[0], spec.cls);
            assert!(ex
                .tokens
                .iter()
                .all(|&t| (t as usize) < spec.vocab_size));
            assert!((0..2).contains(&ex.label));
        }
    }

    #[test]
    fn single_examples_contain_bank_words_of_label() {
        let spec = test_spec();
        let task = spec.task("sst2").unwrap().clone();
        let mut rng = Rng::new(2);
        for _ in 0..100 {
            let ex = sample_example(&spec, &task, &mut rng);
            let (blo, bhi) = task.banks[ex.label as usize];
            let hits = ex
                .tokens
                .iter()
                .filter(|&&t| (t as usize) >= blo && (t as usize) < bhi)
                .count();
            assert!(hits >= 2, "expected ≥2 bank words, got {hits}");
        }
    }

    #[test]
    fn arith_label_is_sum_mod_classes() {
        let spec = test_spec();
        let task = spec.task("gsm").unwrap().clone();
        let mut rng = Rng::new(3);
        let d0 = match &task.kind {
            Kind::Arith { digits, .. } => digits[0] as i32,
            _ => unreachable!(),
        };
        for _ in 0..200 {
            let ex = sample_example(&spec, &task, &mut rng);
            let sum: i32 = ex
                .tokens
                .iter()
                .filter(|&&t| t >= d0 && t < d0 + 10)
                .map(|&t| t - d0)
                .sum();
            assert_eq!(ex.label, sum % task.n_classes as i32);
        }
    }

    #[test]
    fn sample_labeled_realizes_requested_label() {
        let spec = test_spec();
        let mut rng = Rng::new(5);
        for name in ["sst2", "gsm"] {
            let task = spec.task(name).unwrap().clone();
            for want in 0..task.n_classes {
                for _ in 0..50 {
                    let ex = sample_labeled(&spec, &task, want, &mut rng);
                    // label_noise is 0 in the test spec, so the label
                    // must come out exactly as requested.
                    assert_eq!(ex.label, want as i32, "task {name}");
                    assert_eq!(ex.tokens.len(), spec.seq_len);
                    assert_eq!(ex.tokens[0], spec.cls);
                }
            }
        }
    }

    #[test]
    fn sample_labeled_arith_sum_is_consistent() {
        // The forced last digit must keep the arith invariant: label
        // still equals the digit sum mod n_classes.
        let spec = test_spec();
        let task = spec.task("gsm").unwrap().clone();
        let d0 = match &task.kind {
            Kind::Arith { digits, .. } => digits[0] as i32,
            _ => unreachable!(),
        };
        let mut rng = Rng::new(6);
        for want in 0..task.n_classes {
            for _ in 0..100 {
                let ex = sample_labeled(&spec, &task, want, &mut rng);
                let sum: i32 = ex
                    .tokens
                    .iter()
                    .filter(|&&t| t >= d0 && t < d0 + 10)
                    .map(|&t| t - d0)
                    .sum();
                assert_eq!(ex.label, sum % task.n_classes as i32);
            }
        }
    }

    #[test]
    fn labels_roughly_balanced() {
        let spec = test_spec();
        let mut rng = Rng::new(4);
        let ds = generate(&spec, "sst2", 2000, &mut rng).unwrap();
        let h = ds.label_histogram(2);
        assert!(h[0] > 800 && h[1] > 800, "{h:?}");
    }

    #[test]
    fn scaled_sizes_preserve_ordering() {
        let (sst_tr, _) = paper_scaled_sizes("sst2", 0.01);
        let (qqp_tr, _) = paper_scaled_sizes("qqp", 0.01);
        assert!(qqp_tr > sst_tr);
    }
}
