//! Federated data partitioning.
//!
//! GLUE-style tasks are split non-iid across devices with a
//! per-device Dirichlet(α) over labels (α=10, following FedNLP and
//! the paper's Table 2); mmlu-syn / gsm-syn are split iid. The
//! partitioner guarantees every device gets at least `min_shard`
//! examples (a device with zero data cannot run its local epoch).

use super::Dataset;
use crate::util::rng::Rng;

/// How a dataset is split across devices.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Partition {
    /// Dirichlet(α) label-distribution skew per device.
    Dirichlet { alpha: f64 },
    /// Uniform shuffle-split.
    Iid,
}

/// Split `ds` into `n_devices` shards.
pub fn split(ds: &Dataset, n_devices: usize, how: Partition,
             n_classes: usize, min_shard: usize, rng: &mut Rng)
             -> Vec<Dataset> {
    assert!(n_devices > 0);
    match how {
        Partition::Iid => split_iid(ds, n_devices, rng),
        Partition::Dirichlet { alpha } => {
            split_dirichlet(ds, n_devices, alpha, n_classes, min_shard, rng)
        }
    }
}

fn split_iid(ds: &Dataset, n: usize, rng: &mut Rng) -> Vec<Dataset> {
    let shuffled = ds.shuffled(rng);
    let mut shards = vec![Dataset::default(); n];
    for (i, ex) in shuffled.examples.into_iter().enumerate() {
        shards[i % n].examples.push(ex);
    }
    shards
}

fn split_dirichlet(ds: &Dataset, n: usize, alpha: f64, n_classes: usize,
                   min_shard: usize, rng: &mut Rng) -> Vec<Dataset> {
    // Bucket indices by class.
    let mut by_class: Vec<Vec<usize>> = vec![Vec::new(); n_classes];
    for (i, ex) in ds.examples.iter().enumerate() {
        by_class[ex.label as usize].push(i);
    }
    for bucket in &mut by_class {
        rng.shuffle(bucket);
    }

    // Per-device class mixture ~ Dirichlet(alpha).
    let alphas = vec![alpha; n_classes];
    let mixtures: Vec<Vec<f64>> =
        (0..n).map(|_| rng.dirichlet(&alphas)).collect();

    // Deal each class's examples out proportionally to the mixtures
    // (largest-remainder rounding so all examples are assigned).
    let mut shards = vec![Dataset::default(); n];
    for (c, bucket) in by_class.iter().enumerate() {
        if bucket.is_empty() {
            continue;
        }
        let weights: Vec<f64> = mixtures.iter().map(|m| m[c]).collect();
        let total: f64 = weights.iter().sum::<f64>().max(1e-12);
        let mut cursor = 0usize;
        for (d, w) in weights.iter().enumerate() {
            let take = if d + 1 == n {
                bucket.len() - cursor
            } else {
                ((w / total) * bucket.len() as f64).round() as usize
            };
            let take = take.min(bucket.len() - cursor);
            for &idx in &bucket[cursor..cursor + take] {
                shards[d].examples.push(ds.examples[idx].clone());
            }
            cursor += take;
        }
    }

    // Re-balance: steal from the largest shards until everyone has
    // at least `min_shard` examples.
    rebalance_min(&mut shards, min_shard);
    for s in &mut shards {
        let mut ex = std::mem::take(&mut s.examples);
        rng.shuffle(&mut ex);
        s.examples = ex;
    }
    shards
}

fn rebalance_min(shards: &mut [Dataset], min_shard: usize) {
    loop {
        let Some(poor) = shards.iter().position(|s| s.len() < min_shard)
        else {
            return;
        };
        let rich = shards
            .iter()
            .enumerate()
            .max_by_key(|(_, s)| s.len())
            .map(|(i, _)| i)
            .unwrap();
        if rich == poor || shards[rich].len() <= min_shard {
            return; // nothing left to steal; dataset too small
        }
        let ex = shards[rich].examples.pop().unwrap();
        shards[poor].examples.push(ex);
    }
}

/// Kolmogorov–Smirnov-style skew statistic: mean over devices of the
/// total-variation distance between shard label distribution and the
/// global one. 0 = perfectly iid. Used in tests and `data --describe`.
pub fn label_skew(shards: &[Dataset], n_classes: usize) -> f64 {
    let mut global = vec![0f64; n_classes];
    let mut total = 0f64;
    for s in shards {
        for (c, k) in s.label_histogram(n_classes).iter().enumerate() {
            global[c] += *k as f64;
            total += *k as f64;
        }
    }
    for g in &mut global {
        *g /= total.max(1.0);
    }
    let mut acc = 0.0;
    for s in shards {
        let n = s.len().max(1) as f64;
        let h = s.label_histogram(n_classes);
        let tv: f64 = h
            .iter()
            .zip(&global)
            .map(|(k, g)| (*k as f64 / n - g).abs())
            .sum::<f64>()
            / 2.0;
        acc += tv;
    }
    acc / shards.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::grammar::generate;
    use crate::data::tests::test_spec;

    fn dataset(n: usize, seed: u64) -> Dataset {
        let spec = test_spec();
        let mut rng = Rng::new(seed);
        generate(&spec, "sst2", n, &mut rng).unwrap()
    }

    #[test]
    fn iid_split_conserves_examples() {
        let ds = dataset(503, 1);
        let mut rng = Rng::new(2);
        let shards = split(&ds, 10, Partition::Iid, 2, 1, &mut rng);
        assert_eq!(shards.iter().map(|s| s.len()).sum::<usize>(), 503);
        assert!(shards.iter().all(|s| s.len() >= 50));
    }

    #[test]
    fn dirichlet_split_conserves_examples() {
        let ds = dataset(1000, 3);
        let mut rng = Rng::new(4);
        let shards = split(
            &ds,
            8,
            Partition::Dirichlet { alpha: 10.0 },
            2,
            16,
            &mut rng,
        );
        assert_eq!(shards.iter().map(|s| s.len()).sum::<usize>(), 1000);
        assert!(shards.iter().all(|s| s.len() >= 16));
    }

    #[test]
    fn low_alpha_is_more_skewed_than_high_alpha() {
        let ds = dataset(4000, 5);
        let mut rng = Rng::new(6);
        let skew_low = label_skew(
            &split(&ds, 10, Partition::Dirichlet { alpha: 0.1 }, 2, 1,
                   &mut rng),
            2,
        );
        let skew_high = label_skew(
            &split(&ds, 10, Partition::Dirichlet { alpha: 100.0 }, 2, 1,
                   &mut rng),
            2,
        );
        assert!(
            skew_low > skew_high,
            "alpha=0.1 skew {skew_low} should exceed alpha=100 {skew_high}"
        );
    }

    #[test]
    fn iid_split_is_nearly_unskewed() {
        let ds = dataset(2000, 7);
        let mut rng = Rng::new(8);
        let shards = split(&ds, 10, Partition::Iid, 2, 1, &mut rng);
        assert!(label_skew(&shards, 2) < 0.1);
    }

    #[test]
    fn min_shard_enforced_even_with_extreme_skew() {
        let ds = dataset(300, 9);
        let mut rng = Rng::new(10);
        let shards = split(
            &ds,
            6,
            Partition::Dirichlet { alpha: 0.05 },
            2,
            20,
            &mut rng,
        );
        assert!(shards.iter().all(|s| s.len() >= 20), "{:?}",
                shards.iter().map(|s| s.len()).collect::<Vec<_>>());
    }
}
