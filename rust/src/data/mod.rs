//! Synthetic datasets, tokenization spec, and federated partitioning.
//!
//! The paper fine-tunes on GLUE (SST-2/QNLI/QQP/MNLI, non-iid
//! Dirichlet α=10), MMLU and GSM-8K (Table 2). Offline we substitute
//! spec-matched synthetic tasks (DESIGN.md §2): the grammar spec is
//! authored once in `python/compile/configs.py`, serialized to
//! `artifacts/vocab.json`, and consumed here so the pretraining corpus
//! and the federated fine-tuning data share one vocabulary layout.

pub mod grammar;
pub mod partition;

use crate::util::json::Value;
use crate::util::rng::Rng;

/// One labeled example: `seq_len` token ids + class label.
#[derive(Debug, Clone, PartialEq)]
pub struct Example {
    pub tokens: Vec<i32>,
    pub label: i32,
}

/// A labeled dataset (one device shard or the global test set).
#[derive(Debug, Clone, Default)]
pub struct Dataset {
    pub examples: Vec<Example>,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.examples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.examples.is_empty()
    }

    /// Iterate fixed-size batches, flattening tokens row-major and
    /// cycling from the start if `len` is not a multiple of `batch`
    /// (matches on-device epoch semantics: every sample seen once,
    /// tail batch padded by wraparound).
    pub fn batches(&self, batch: usize) -> Vec<(Vec<i32>, Vec<i32>)> {
        assert!(!self.is_empty(), "cannot batch an empty dataset");
        let n = self.examples.len();
        let n_batches = n.div_ceil(batch);
        let mut out = Vec::with_capacity(n_batches);
        for b in 0..n_batches {
            let mut toks = Vec::with_capacity(batch * self.seq_len());
            let mut labels = Vec::with_capacity(batch);
            for j in 0..batch {
                let ex = &self.examples[(b * batch + j) % n];
                toks.extend_from_slice(&ex.tokens);
                labels.push(ex.label);
            }
            out.push((toks, labels));
        }
        out
    }

    pub fn seq_len(&self) -> usize {
        self.examples.first().map(|e| e.tokens.len()).unwrap_or(0)
    }

    /// Class histogram (for partition skew tests / Table 2 printout).
    pub fn label_histogram(&self, n_classes: usize) -> Vec<usize> {
        let mut h = vec![0usize; n_classes];
        for e in &self.examples {
            h[e.label as usize] += 1;
        }
        h
    }

    pub fn shuffled(&self, rng: &mut Rng) -> Dataset {
        let mut ex = self.examples.clone();
        rng.shuffle(&mut ex);
        Dataset { examples: ex }
    }
}

/// Vocab / task-grammar spec loaded from artifacts/vocab.json.
#[derive(Debug, Clone)]
pub struct Spec {
    pub vocab_size: usize,
    pub seq_len: usize,
    pub pad: i32,
    pub cls: i32,
    pub sep: i32,
    pub filler: (usize, usize),
    pub noise: (usize, usize),
    pub tasks: Vec<TaskSpec>,
}

/// Grammar kind mirror of python `configs.task_spec()["tasks"][..]["kind"]`.
#[derive(Debug, Clone, PartialEq)]
pub enum Kind {
    Single,
    Pair,
    Arith { digits: Vec<usize>, ops: Vec<usize>, n_terms: usize },
}

#[derive(Debug, Clone)]
pub struct TaskSpec {
    pub name: String,
    pub kind: Kind,
    pub n_classes: usize,
    pub banks: Vec<(usize, usize)>,
    pub len_range: (usize, usize),
    pub bank_words: (usize, usize),
    pub label_noise: f64,
}

#[derive(Debug, thiserror::Error)]
pub enum DataError {
    #[error("vocab spec: {0}")]
    Spec(String),
    #[error("unknown task {0:?}")]
    UnknownTask(String),
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
    #[error("json: {0}")]
    Json(#[from] crate::util::json::ParseError),
}

impl Spec {
    pub fn load(path: &str) -> Result<Spec, DataError> {
        let text = std::fs::read_to_string(path)?;
        Spec::from_json(&Value::parse(&text)?)
    }

    pub fn from_json(v: &Value) -> Result<Spec, DataError> {
        let miss = |what: &str| DataError::Spec(format!("missing {what}"));
        let special = v.get("special");
        let mut tasks = Vec::new();
        let tobj = v
            .get("tasks")
            .as_obj()
            .ok_or_else(|| miss("tasks"))?;
        for (name, t) in tobj {
            let kind = match t.get("kind").as_str() {
                Some("single") => Kind::Single,
                Some("pair") => Kind::Pair,
                Some("arith") => Kind::Arith {
                    digits: t
                        .get("digits")
                        .as_usize_vec()
                        .ok_or_else(|| miss("digits"))?,
                    ops: t
                        .get("ops")
                        .as_usize_vec()
                        .ok_or_else(|| miss("ops"))?,
                    n_terms: t
                        .get("n_terms")
                        .as_usize()
                        .ok_or_else(|| miss("n_terms"))?,
                },
                other => {
                    return Err(DataError::Spec(format!(
                        "bad kind {other:?} for task {name}"
                    )))
                }
            };
            let banks = match t.get("banks") {
                Value::Arr(a) => a
                    .iter()
                    .map(|b| b.as_range().ok_or_else(|| miss("bank range")))
                    .collect::<Result<Vec<_>, _>>()?,
                _ => Vec::new(),
            };
            tasks.push(TaskSpec {
                name: name.clone(),
                kind,
                n_classes: t
                    .get("n_classes")
                    .as_usize()
                    .ok_or_else(|| miss("n_classes"))?,
                banks,
                len_range: t.get("len_range").as_range().unwrap_or((6, 14)),
                bank_words: t.get("bank_words").as_range().unwrap_or((2, 5)),
                label_noise: t.get("label_noise").as_f64().unwrap_or(0.0),
            });
        }
        Ok(Spec {
            vocab_size: v
                .get("vocab_size")
                .as_usize()
                .ok_or_else(|| miss("vocab_size"))?,
            seq_len: v
                .get("seq_len")
                .as_usize()
                .ok_or_else(|| miss("seq_len"))?,
            pad: special.get("pad").as_i64().unwrap_or(0) as i32,
            cls: special.get("cls").as_i64().unwrap_or(1) as i32,
            sep: special.get("sep").as_i64().unwrap_or(3) as i32,
            filler: v
                .get("filler")
                .as_range()
                .ok_or_else(|| miss("filler"))?,
            noise: v.get("noise").as_range().ok_or_else(|| miss("noise"))?,
            tasks,
        })
    }

    pub fn task(&self, name: &str) -> Result<&TaskSpec, DataError> {
        self.tasks
            .iter()
            .find(|t| t.name == name)
            .ok_or_else(|| DataError::UnknownTask(name.to_string()))
    }

    pub fn task_names(&self) -> Vec<&str> {
        self.tasks.iter().map(|t| t.name.as_str()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    pub(crate) fn test_spec() -> Spec {
        let json = r#"{
          "vocab_size": 256, "seq_len": 16,
          "special": {"pad": 0, "cls": 1, "mask": 2, "sep": 3},
          "filler": [4, 50], "noise": [200, 256],
          "tasks": {
            "sst2": {"kind": "single", "n_classes": 2,
                     "banks": [[50, 80], [80, 110]],
                     "len_range": [5, 10], "bank_words": [2, 4],
                     "label_noise": 0.0},
            "gsm": {"kind": "arith", "n_classes": 4,
                    "digits": [110, 111, 112, 113, 114, 115, 116, 117, 118, 119],
                    "ops": [120, 121, 122], "n_terms": 3,
                    "label_noise": 0.0}
          }
        }"#;
        Spec::from_json(&Value::parse(json).unwrap()).unwrap()
    }

    #[test]
    fn spec_parses() {
        let s = test_spec();
        assert_eq!(s.vocab_size, 256);
        assert_eq!(s.tasks.len(), 2);
        let sst = s.task("sst2").unwrap();
        assert_eq!(sst.kind, Kind::Single);
        assert_eq!(sst.banks, vec![(50, 80), (80, 110)]);
        assert!(s.task("nope").is_err());
    }

    #[test]
    fn real_artifact_spec_parses_if_present() {
        // Integration check against the actual build output when it
        // exists (make artifacts); skipped otherwise.
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/vocab.json");
        if std::path::Path::new(path).exists() {
            let s = Spec::load(path).unwrap();
            assert_eq!(s.tasks.len(), 6);
            assert!(s.task("sst2").is_ok());
            assert!(s.task("gsm").is_ok());
        }
    }

    #[test]
    fn batches_cycle_and_flatten() {
        let ds = Dataset {
            examples: (0..5)
                .map(|i| Example { tokens: vec![i; 4], label: i })
                .collect(),
        };
        let bs = ds.batches(2);
        assert_eq!(bs.len(), 3);
        assert_eq!(bs[0].0.len(), 8);
        assert_eq!(bs[2].1, vec![4, 0]); // wraparound
    }

    #[test]
    fn histogram_counts() {
        let ds = Dataset {
            examples: vec![
                Example { tokens: vec![0], label: 0 },
                Example { tokens: vec![0], label: 1 },
                Example { tokens: vec![0], label: 1 },
            ],
        };
        assert_eq!(ds.label_histogram(2), vec![1, 2]);
    }
}
