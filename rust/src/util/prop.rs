//! Miniature property-testing harness (no `proptest` offline).
//!
//! Runs a property over `n` randomly generated cases; on failure it
//! reports the seed + case index so the exact case replays with
//! `check_with_seed`. Used by the coordinator tests to fuzz LCD,
//! aggregation and assignment invariants (DESIGN.md §6).

use crate::util::rng::Rng;

/// Number of cases per property (kept modest: single-core CI).
pub const DEFAULT_CASES: usize = 256;

/// Run `prop(rng, case_idx)` for `cases` cases; panic with a
/// reproducible seed on the first failure.
pub fn check<F>(name: &str, cases: usize, mut prop: F)
where
    F: FnMut(&mut Rng, usize) -> Result<(), String>,
{
    check_with_seed(name, 0xC0FFEE, cases, &mut prop);
}

/// Same as [`check`] but with an explicit master seed (use to replay a
/// reported failure).
pub fn check_with_seed<F>(name: &str, seed: u64, cases: usize, prop: &mut F)
where
    F: FnMut(&mut Rng, usize) -> Result<(), String>,
{
    for case in 0..cases {
        let mut rng = Rng::new(seed ^ (case as u64).wrapping_mul(0x9E3779B9));
        if let Err(msg) = prop(&mut rng, case) {
            panic!(
                "property '{name}' failed at case {case} \
                 (replay: check_with_seed(\"{name}\", {seed:#x}, \
                 {n}, ..) with case {case}): {msg}",
                n = case + 1
            );
        }
    }
}

/// Assert helper producing `Result<(), String>` for use inside props.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("sum-commutes", 64, |rng, _| {
            let a = rng.uniform(-1e6, 1e6);
            let b = rng.uniform(-1e6, 1e6);
            prop_assert!(a + b == b + a, "{a} + {b}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn failing_property_panics_with_replay_info() {
        check("always-fails", 16, |_, _| Err("nope".to_string()));
    }
}
