//! Small self-contained substrates the crate builds on.
//!
//! The offline build environment only vendors the `xla` crate's own
//! dependency closure, so the usual ecosystem crates (serde, rand,
//! clap, criterion) are unavailable — these modules are the
//! from-scratch replacements (DESIGN.md §1 `util/`):
//!
//! * [`json`] — recursive-descent JSON parser + writer (manifest,
//!   vocab spec, metrics output).
//! * [`rng`] — deterministic xoshiro256++ PRNG with the distributions
//!   the simulator needs (normal, lognormal, gamma, Dirichlet).
//! * [`cli`] — flag/subcommand parser for the `legend` binary.
//! * [`prop`] — a tiny property-testing harness (random case
//!   generation + failure reporting) used by the coordinator tests.

pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
