//! Deterministic PRNG + distributions (no `rand` crate offline).
//!
//! xoshiro256++ seeded through splitmix64; every stochastic component
//! of the simulator (fleet modes, WiFi fading, data generation,
//! Dirichlet partitions) derives a child stream from a named seed so
//! experiments are exactly reproducible from the config seed.

/// xoshiro256++ PRNG (Blackman & Vigna, public domain reference).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal from Box-Muller.
    spare_normal: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Mix two words into one well-distributed word (golden-ratio multiply
/// + splitmix64 finalizer) — the combiner behind counter-based cell
/// streams.
fn mix64(a: u64, b: u64) -> u64 {
    let mut s = a ^ b.wrapping_mul(0x9E3779B97F4A7C15);
    splitmix64(&mut s)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Derive an independent child stream for a named component.
    pub fn child(&self, tag: &str) -> Rng {
        let mut h: u64 = 0xcbf29ce484222325; // FNV-1a
        for b in tag.as_bytes() {
            h ^= *b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        Rng::new(self.s[0] ^ h.rotate_left(17))
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53 bits of precision.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [lo, hi) (hi > lo).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo, "empty range {lo}..{hi}");
        lo + (self.next_u64() % (hi - lo) as u64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range_incl(&mut self, lo: usize, hi: usize) -> usize {
        self.range(lo, hi + 1)
    }

    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        let (u1, u2) = (self.f64().max(1e-300), self.f64());
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    pub fn normal_scaled(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Log-normal with given parameters of the underlying normal.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Gamma(shape, 1) via Marsaglia–Tsang; boost for shape < 1.
    pub fn gamma(&mut self, shape: f64) -> f64 {
        if shape < 1.0 {
            // Gamma(a) = Gamma(a+1) * U^(1/a)
            let g = self.gamma(shape + 1.0);
            return g * self.f64().max(1e-300).powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.f64();
            if u < 1.0 - 0.0331 * x.powi(4)
                || u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln())
            {
                return d * v;
            }
        }
    }

    /// Dirichlet(alpha) sample — used for the non-iid label partition
    /// (Table 2: Dirichlet α = 10 as in FedNLP).
    pub fn dirichlet(&mut self, alphas: &[f64]) -> Vec<f64> {
        let gs: Vec<f64> = alphas.iter().map(|&a| self.gamma(a)).collect();
        let sum: f64 = gs.iter().sum::<f64>().max(1e-300);
        gs.iter().map(|g| g / sum).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.range(0, i + 1);
            xs.swap(i, j);
        }
    }

    /// Uniformly pick one element.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range(0, xs.len())]
    }

    /// Sample an index from unnormalized weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut u = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Counter-based cell stream: an independent child stream addressed
    /// by `(tag, id, k)` instead of by draw order. Unlike [`Rng::child`]
    /// chains, a cell is O(1) to open no matter how many other cells
    /// exist — the foundation of the lazy fleet, where device `id`'s
    /// round-`k` state must be derivable without touching any other
    /// device. Pure in `(self seed, tag, id, k)`.
    pub fn cell(&self, tag: &str, id: u64, k: u64) -> Rng {
        let mut h: u64 = 0xcbf29ce484222325; // FNV-1a, as in `child`
        for b in tag.as_bytes() {
            h ^= *b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        Rng::new(mix64(mix64(self.s[0] ^ h.rotate_left(17), id), k))
    }
}

/// Deterministic pseudorandom permutation of `0..n` with O(1) queries —
/// a 4-round Feistel network over the smallest even-bit-width domain
/// `≥ n`, cycle-walking out-of-range values back into `0..n`. Lets the
/// lazy fleet assign exact per-class device counts (a shuffled class
/// layout) without materializing an n-element shuffle.
#[derive(Debug, Clone)]
pub struct IndexPerm {
    n: u64,
    half_bits: u32,
    mask: u64,
    keys: [u64; 4],
}

impl IndexPerm {
    pub fn new(n: usize, rng: &mut Rng) -> IndexPerm {
        let n = n as u64;
        let mut half_bits = 1u32;
        while (1u64 << (2 * half_bits)) < n {
            half_bits += 1;
        }
        IndexPerm {
            n: n.max(1),
            half_bits,
            mask: (1u64 << half_bits) - 1,
            keys: [
                rng.next_u64(),
                rng.next_u64(),
                rng.next_u64(),
                rng.next_u64(),
            ],
        }
    }

    fn feistel(&self, x: u64) -> u64 {
        let (mut l, mut r) = (x >> self.half_bits, x & self.mask);
        for key in self.keys {
            let mut s = r ^ key;
            let f = splitmix64(&mut s) & self.mask;
            (l, r) = (r, l ^ f);
        }
        (l << self.half_bits) | r
    }

    /// Image of `i` under the permutation (i < n ⇒ result < n).
    pub fn apply(&self, i: usize) -> usize {
        debug_assert!((i as u64) < self.n);
        let mut x = i as u64;
        loop {
            x = self.feistel(x);
            if x < self.n {
                return x as usize;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn child_streams_differ() {
        let root = Rng::new(1);
        let mut a = root.child("fleet");
        let mut b = root.child("data");
        let av: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let bv: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(av, bv);
        // Same tag → same stream.
        let mut a2 = root.child("fleet");
        assert_eq!(av[0], a2.next_u64());
    }

    #[test]
    fn uniform_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.uniform(2.0, 5.0);
            assert!((2.0..5.0).contains(&x));
            let i = r.range(10, 20);
            assert!((10..20).contains(&i));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(4);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn gamma_mean() {
        let mut r = Rng::new(5);
        for shape in [0.5, 1.0, 3.0, 10.0] {
            let n = 20_000;
            let m: f64 =
                (0..n).map(|_| r.gamma(shape)).sum::<f64>() / n as f64;
            assert!(
                (m - shape).abs() < 0.15 * shape.max(1.0),
                "gamma({shape}) mean {m}"
            );
        }
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Rng::new(6);
        for _ in 0..100 {
            let p = r.dirichlet(&[10.0, 10.0, 10.0]);
            let s: f64 = p.iter().sum();
            assert!((s - 1.0).abs() < 1e-9);
            assert!(p.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(7);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn cell_streams_are_independent_and_reproducible() {
        let root = Rng::new(42).child("fleet");
        let draws = |r: &mut Rng| (0..4).map(|_| r.next_u64()).collect::<Vec<_>>();
        let base = draws(&mut root.cell("fade", 7, 3));
        // Same address → same stream; any coordinate change → different.
        assert_eq!(base, draws(&mut root.cell("fade", 7, 3)));
        assert_ne!(base, draws(&mut root.cell("fade", 7, 4)));
        assert_ne!(base, draws(&mut root.cell("fade", 8, 3)));
        assert_ne!(base, draws(&mut root.cell("mode", 7, 3)));
        // Opening a cell does not disturb the parent (pure by &self).
        assert_eq!(base, draws(&mut root.cell("fade", 7, 3)));
    }

    #[test]
    fn index_perm_is_a_bijection() {
        for n in [1usize, 5, 80, 256, 1000] {
            let mut rng = Rng::new(9).child("perm");
            let perm = IndexPerm::new(n, &mut rng);
            let mut seen = vec![false; n];
            for i in 0..n {
                let j = perm.apply(i);
                assert!(j < n, "perm({i}) = {j} out of range for n={n}");
                assert!(!seen[j], "perm not injective at n={n}");
                seen[j] = true;
            }
        }
    }

    #[test]
    fn index_perm_deterministic_and_seed_sensitive() {
        let build = |seed: u64| {
            let mut rng = Rng::new(seed).child("perm");
            IndexPerm::new(80, &mut rng)
        };
        let (a, b, c) = (build(1), build(1), build(2));
        let image = |p: &IndexPerm| (0..80).map(|i| p.apply(i)).collect::<Vec<_>>();
        assert_eq!(image(&a), image(&b));
        assert_ne!(image(&a), image(&c));
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::new(8);
        let w = [1.0, 0.0, 9.0];
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > 8 * counts[0]);
    }
}
