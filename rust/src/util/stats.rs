//! Summary statistics for metrics and benches: mean/std, percentiles,
//! normal-approximation confidence intervals, and a Mann-Whitney-style
//! rank test used to assert orderings (e.g. "LEGEND's waiting time is
//! stochastically smaller than FedLoRA's") across seeds.

/// Basic moments of a sample.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
}

pub fn summarize(xs: &[f64]) -> Summary {
    assert!(!xs.is_empty(), "empty sample");
    let n = xs.len();
    let mean = xs.iter().sum::<f64>() / n as f64;
    let var = if n > 1 {
        xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>()
            / (n - 1) as f64
    } else {
        0.0
    };
    Summary {
        n,
        mean,
        std: var.sqrt(),
        min: xs.iter().cloned().fold(f64::MAX, f64::min),
        max: xs.iter().cloned().fold(f64::MIN, f64::max),
    }
}

/// p-th percentile (0..=100) by linear interpolation on sorted data.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let w = rank - lo as f64;
        v[lo] * (1.0 - w) + v[hi] * w
    }
}

/// 95% CI half-width under the normal approximation.
pub fn ci95_halfwidth(xs: &[f64]) -> f64 {
    let s = summarize(xs);
    if s.n < 2 {
        return f64::INFINITY;
    }
    1.96 * s.std / (s.n as f64).sqrt()
}

/// Fraction of (a_i, b_j) pairs with a_i < b_j (the Mann-Whitney U
/// statistic normalized to [0,1]; 0.5 = no ordering, → 1 = a smaller).
pub fn prob_smaller(a: &[f64], b: &[f64]) -> f64 {
    assert!(!a.is_empty() && !b.is_empty());
    let mut wins = 0usize;
    let mut ties = 0usize;
    for &x in a {
        for &y in b {
            if x < y {
                wins += 1;
            } else if x == y {
                ties += 1;
            }
        }
    }
    (wins as f64 + 0.5 * ties as f64) / (a.len() * b.len()) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_moments() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert!((s.std - (5.0f64 / 3.0).sqrt()).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
    }

    #[test]
    fn percentiles_interpolate() {
        let xs = [10.0, 20.0, 30.0, 40.0];
        assert_eq!(percentile(&xs, 0.0), 10.0);
        assert_eq!(percentile(&xs, 100.0), 40.0);
        assert!((percentile(&xs, 50.0) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn ci_shrinks_with_n() {
        let small = ci95_halfwidth(&[1.0, 2.0, 3.0]);
        let xs: Vec<f64> =
            (0..300).map(|i| 1.0 + (i % 3) as f64).collect();
        let big = ci95_halfwidth(&xs);
        assert!(big < small);
    }

    #[test]
    fn prob_smaller_detects_ordering() {
        let a = [1.0, 2.0, 3.0];
        let b = [10.0, 20.0];
        assert_eq!(prob_smaller(&a, &b), 1.0);
        assert_eq!(prob_smaller(&b, &a), 0.0);
        assert!((prob_smaller(&a, &a) - 0.5).abs() < 1e-12);
    }
}
