//! Tiny CLI argument parser (no `clap` offline).
//!
//! Grammar: `legend <subcommand> [--key value]* [--flag]* [positional]*`.
//! Flags are recognized as `--name` with an optional value; `--name=value`
//! also works. Unknown keys are an error (catches typos in experiment
//! invocations). Numeric engine knobs (`--threads`, `--agg-shards`,
//! `--window`, …) go through [`Args::get_parse`], so a malformed value
//! fails loudly instead of silently falling back to the default.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    kv: BTreeMap<String, String>,
    flags: Vec<String>,
    consumed: std::cell::RefCell<Vec<String>>,
}

#[derive(Debug, thiserror::Error)]
pub enum CliError {
    #[error("unknown argument(s): {0}")]
    Unknown(String),
    #[error("invalid value for --{key}: {value:?} ({why})")]
    BadValue { key: String, value: String, why: String },
}

impl Args {
    /// Parse from an iterator of raw args (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.kv.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.kv.insert(stripped.to_string(), v);
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    fn mark(&self, key: &str) {
        self.consumed.borrow_mut().push(key.to_string());
    }

    /// True if `--name` was given, bare or as `--name=true`. NOTE:
    /// `--name value` binds `value` to the key (the parser has no
    /// schema), so place bare flags after values or use `=`.
    pub fn flag(&self, name: &str) -> bool {
        self.mark(name);
        self.flags.iter().any(|f| f == name)
            || self.kv.get(name).map(|v| v == "true").unwrap_or(false)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.mark(name);
        self.kv.get(name).map(|s| s.as_str())
    }

    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn get_parse<T: std::str::FromStr>(
        &self,
        name: &str,
        default: T,
    ) -> Result<T, CliError>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse::<T>().map_err(|e| CliError::BadValue {
                key: name.to_string(),
                value: v.to_string(),
                why: e.to_string(),
            }),
        }
    }

    /// Like [`Args::get_or`], but the value must be one of `choices`
    /// (enum-style flags such as `--participation full|sample|deadline`).
    pub fn get_choice(&self, name: &str, default: &str,
                      choices: &[&str]) -> Result<String, CliError> {
        debug_assert!(choices.contains(&default));
        let v = self.get_or(name, default);
        if choices.contains(&v.as_str()) {
            Ok(v)
        } else {
            Err(CliError::BadValue {
                key: name.to_string(),
                value: v,
                why: format!("expected one of {}", choices.join("|")),
            })
        }
    }

    /// Error if any --key / --flag was never queried (typo protection).
    pub fn reject_unknown(&self) -> Result<(), CliError> {
        let seen = self.consumed.borrow();
        let unknown: Vec<String> = self
            .kv
            .keys()
            .chain(self.flags.iter())
            .filter(|k| !seen.contains(k))
            .cloned()
            .collect();
        if unknown.is_empty() {
            Ok(())
        } else {
            Err(CliError::Unknown(unknown.join(", ")))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|t| t.to_string()))
    }

    #[test]
    fn parses_subcommand_kv_flags() {
        let a = parse("exp extra --fig fig7 --rounds 40 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("exp"));
        assert_eq!(a.get("fig"), Some("fig7"));
        assert_eq!(a.get_parse("rounds", 0usize).unwrap(), 40);
        assert!(a.flag("verbose"));
        assert!(a.flag("quiet") == false);
        assert_eq!(a.positional, vec!["extra".to_string()]);
    }

    #[test]
    fn equals_form() {
        let a = parse("run --seed=9");
        assert_eq!(a.get_parse("seed", 0u64).unwrap(), 9);
    }

    #[test]
    fn defaults() {
        let a = parse("run");
        assert_eq!(a.get_parse("rounds", 17usize).unwrap(), 17);
        assert_eq!(a.get_or("task", "sst2"), "sst2");
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn unknown_rejected() {
        let a = parse("run --oops 3");
        let _ = a.get("fine");
        assert!(a.reject_unknown().is_err());
        let b = parse("run --ok 3");
        let _ = b.get("ok");
        assert!(b.reject_unknown().is_ok());
    }

    #[test]
    fn bad_value_errors() {
        let a = parse("run --rounds banana");
        assert!(a.get_parse("rounds", 1usize).is_err());
    }

    #[test]
    fn engine_knobs_parse_and_default() {
        // The `run` surface for the sharded fold + in-flight window.
        let a = parse("run --threads 4 --agg-shards 2 --window 16");
        assert_eq!(a.get_parse("threads", 0usize).unwrap(), 4);
        assert_eq!(a.get_parse("agg-shards", 1usize).unwrap(), 2);
        assert_eq!(a.get_parse("window", 0usize).unwrap(), 16);
        assert!(a.reject_unknown().is_ok());
        // Omitted knobs keep their defaults (inline fold, unbounded).
        let b = parse("run");
        assert_eq!(b.get_parse("agg-shards", 1usize).unwrap(), 1);
        assert_eq!(b.get_parse("window", 0usize).unwrap(), 0);
        let c = parse("run --window=-3");
        assert!(c.get_parse("window", 0usize).is_err());
    }

    #[test]
    fn async_knobs_parse_and_default() {
        // The `run` surface for the staleness-windowed async engine.
        let a = parse(
            "run --async --staleness-alpha 0.75 --max-staleness 3",
        );
        assert!(a.flag("async"));
        assert_eq!(a.get_parse("staleness-alpha", 0.5f64).unwrap(), 0.75);
        assert_eq!(a.get_parse("max-staleness", 2usize).unwrap(), 3);
        assert!(a.reject_unknown().is_ok());
        // Omitted: sync engine, default α/S.
        let b = parse("run");
        assert!(!b.flag("async"));
        assert_eq!(b.get_parse("staleness-alpha", 0.5f64).unwrap(), 0.5);
        assert_eq!(b.get_parse("max-staleness", 2usize).unwrap(), 2);
        // Malformed values fail loudly, mirroring --window.
        let c = parse("run --staleness-alpha banana");
        assert!(c.get_parse("staleness-alpha", 0.5f64).is_err());
        let d = parse("run --max-staleness=-1");
        assert!(d.get_parse("max-staleness", 2usize).is_err());
        let e = parse("run --max-staleness 1.5");
        assert!(e.get_parse("max-staleness", 2usize).is_err());
    }

    #[test]
    fn realloc_knobs_parse_and_default() {
        // The `run` surface for periodic LCD re-allocation.
        let a = parse(
            "run --realloc-every 5 --realloc-hysteresis 0.1",
        );
        assert_eq!(a.get_parse("realloc-every", 0usize).unwrap(), 5);
        assert_eq!(
            a.get_parse("realloc-hysteresis", 0.05f64).unwrap(),
            0.1
        );
        assert!(a.reject_unknown().is_ok());
        // Omitted: re-allocation off, default band — the static-plan
        // engine, bitwise.
        let b = parse("run");
        assert_eq!(b.get_parse("realloc-every", 0usize).unwrap(), 0);
        assert_eq!(
            b.get_parse("realloc-hysteresis", 0.05f64).unwrap(),
            0.05
        );
        // Malformed values fail loudly, mirroring --window.
        let c = parse("run --realloc-every 2.5");
        assert!(c.get_parse("realloc-every", 0usize).is_err());
        let d = parse("run --realloc-every=-1");
        assert!(d.get_parse("realloc-every", 0usize).is_err());
        let e = parse("run --realloc-hysteresis banana");
        assert!(e.get_parse("realloc-hysteresis", 0.05f64).is_err());
    }

    #[test]
    fn multi_job_knobs_parse_and_default() {
        // The `run` surface for the multi-job scheduler
        // (docs/MULTIJOB.md).
        let a = parse("run --jobs 3 --job-rate 16");
        assert_eq!(a.get_parse("jobs", 1usize).unwrap(), 3);
        assert_eq!(a.get_parse("job-rate", 0usize).unwrap(), 16);
        assert!(a.reject_unknown().is_ok());
        // Omitted: single-job mode with no ingest limit — today's
        // RoundEngine, bitwise.
        let b = parse("run");
        assert_eq!(b.get_parse("jobs", 1usize).unwrap(), 1);
        assert_eq!(b.get_parse("job-rate", 0usize).unwrap(), 0);
        // Malformed values fail loudly, mirroring --realloc-every.
        let c = parse("run --jobs 1.5");
        assert!(c.get_parse("jobs", 1usize).is_err());
        let d = parse("run --job-rate=-2");
        assert!(d.get_parse("job-rate", 0usize).is_err());
    }

    #[test]
    fn scale_knobs_parse_and_default() {
        // The `run` surface for the lazy fleet + edge-aggregation tier.
        let a = parse(
            "run --edge-aggregators 4 --participation count \
             --sample-count 1000 --lazy",
        );
        assert_eq!(a.get_parse("edge-aggregators", 1usize).unwrap(), 4);
        assert_eq!(a.get_parse("sample-count", 10usize).unwrap(), 1000);
        assert_eq!(
            a.get_choice("participation", "full",
                         &["full", "sample", "count", "deadline"])
                .unwrap(),
            "count"
        );
        assert!(a.flag("lazy"));
        assert!(a.reject_unknown().is_ok());
        // Omitted: flat fold, eager fleet.
        let b = parse("run");
        assert_eq!(b.get_parse("edge-aggregators", 1usize).unwrap(), 1);
        assert!(!b.flag("lazy"));
        // Malformed values fail loudly.
        let c = parse("run --edge-aggregators=-2");
        assert!(c.get_parse("edge-aggregators", 1usize).is_err());
        let d = parse("run --sample-count 1.5");
        assert!(d.get_parse("sample-count", 10usize).is_err());
    }

    #[test]
    fn codec_knob_parses_and_defaults() {
        // The `run` surface for the uplink update codec.
        let choices = ["none", "int8", "int4"];
        let a = parse("run --codec int8");
        assert_eq!(a.get_choice("codec", "none", &choices).unwrap(),
                   "int8");
        assert!(a.reject_unknown().is_ok());
        // Omitted: today's f32 wire.
        let b = parse("run");
        assert_eq!(b.get_choice("codec", "none", &choices).unwrap(),
                   "none");
        // Malformed values fail loudly, mirroring --participation.
        let c = parse("run --codec int16");
        assert!(c.get_choice("codec", "none", &choices).is_err());
    }

    #[test]
    fn choice_validates_against_set() {
        let a = parse("run --participation sample");
        let choices = ["full", "sample", "deadline"];
        assert_eq!(
            a.get_choice("participation", "full", &choices).unwrap(),
            "sample"
        );
        let b = parse("run --participation nope");
        assert!(b.get_choice("participation", "full", &choices).is_err());
        let c = parse("run");
        assert_eq!(
            c.get_choice("participation", "full", &choices).unwrap(),
            "full"
        );
    }
}
