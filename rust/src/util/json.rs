//! Minimal JSON: recursive-descent parser and writer.
//!
//! Covers the full JSON grammar (RFC 8259) minus extreme numeric edge
//! cases (numbers parse through `f64`). Used for `artifacts/
//! manifest.json`, `artifacts/vocab.json`, experiment configs and
//! metrics output. No external crates by design (DESIGN.md §1).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

/// Parse error with byte offset for diagnostics.
#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {offset}: {msg}")]
pub struct ParseError {
    pub offset: usize,
    pub msg: String,
}

impl Value {
    pub fn parse(text: &str) -> Result<Value, ParseError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["a"]["b"]`-style access; returns Null for missing keys.
    pub fn get(&self, key: &str) -> &Value {
        static NULL: Value = Value::Null;
        match self {
            Value::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn idx(&self, i: usize) -> &Value {
        static NULL: Value = Value::Null;
        match self {
            Value::Arr(a) => a.get(i).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Convenience: `[lo, hi]` pair → (lo, hi).
    pub fn as_range(&self) -> Option<(usize, usize)> {
        let a = self.as_arr()?;
        if a.len() != 2 {
            return None;
        }
        Some((a[0].as_usize()?, a[1].as_usize()?))
    }

    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    // ---- construction helpers -------------------------------------------

    pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
        Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Value {
        Value::Arr(xs.iter().map(|x| Value::Num(*x)).collect())
    }

    pub fn arr_usize(xs: &[usize]) -> Value {
        Value::Arr(xs.iter().map(|x| Value::Num(*x as f64)).collect())
    }
}

impl fmt::Display for Value {
    /// Compact JSON serialization (stable key order via BTreeMap).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Null => write!(f, "null"),
            Value::Bool(b) => write!(f, "{b}"),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Value::Str(s) => write_escaped(f, s),
            Value::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Value::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { offset: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Value) -> Result<Value, ParseError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(b'-' | b'0'..=b'9') => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Surrogate pairs.
                        let c = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\')
                                || self.bump() != Some(b'u')
                            {
                                return Err(self.err("lone surrogate"));
                            }
                            let lo = self.hex4()?;
                            let hi10 = cp - 0xD800;
                            let lo10 = lo.wrapping_sub(0xDC00);
                            char::from_u32(0x10000 + (hi10 << 10) + lo10)
                        } else {
                            char::from_u32(cp)
                        };
                        out.push(c.ok_or_else(|| self.err("bad codepoint"))?);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(b) if b < 0x20 => {
                    return Err(self.err("control char in string"))
                }
                Some(b) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    let start = self.pos - 1;
                    let len = utf8_len(b);
                    self.pos = start + len;
                    if self.pos > self.bytes.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    let s = std::str::from_utf8(&self.bytes[start..self.pos])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    out.push_str(s);
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let b = self.bump().ok_or_else(|| self.err("eof in \\u"))?;
            let d = (b as char)
                .to_digit(16)
                .ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Value::parse("null").unwrap(), Value::Null);
        assert_eq!(Value::parse("true").unwrap(), Value::Bool(true));
        assert_eq!(Value::parse("-1.5e2").unwrap(), Value::Num(-150.0));
        assert_eq!(
            Value::parse("\"a\\nb\"").unwrap(),
            Value::Str("a\nb".into())
        );
    }

    #[test]
    fn parses_nested() {
        let v = Value::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#)
            .unwrap();
        assert_eq!(v.get("a").idx(2).get("b").as_str(), Some("c"));
        assert_eq!(v.get("d"), &Value::Null);
        assert_eq!(v.get("missing"), &Value::Null);
    }

    #[test]
    fn parses_unicode_escapes() {
        let v = Value::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str(), Some("é😀"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Value::parse("{").is_err());
        assert!(Value::parse("[1,]").is_err());
        assert!(Value::parse("1 2").is_err());
        assert!(Value::parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrips() {
        let cases = [
            r#"{"a":[1,2.5,"x"],"b":{"c":true,"d":null}}"#,
            r#"[-1,0.125,1e300]"#,
            r#""quote\" backslash\\ tab\t""#,
        ];
        for c in cases {
            let v = Value::parse(c).unwrap();
            let printed = v.to_string();
            assert_eq!(Value::parse(&printed).unwrap(), v, "case {c}");
        }
    }

    #[test]
    fn range_helper() {
        let v = Value::parse("[3, 9]").unwrap();
        assert_eq!(v.as_range(), Some((3, 9)));
    }
}
