//! PJRT runtime: load the AOT artifacts and run them from rust.
//!
//! Pattern (from /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` (HLO **text**: jax ≥ 0.5 protos
//! carry 64-bit ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns them) → `client.compile` → `execute`.
//!
//! Hot-path layout: the frozen base (≈10 MB) lives as persistent
//! [`xla::Literal`]s; executable outputs come back as one tuple
//! literal which we decompose and keep as literals between local
//! steps — host round-trips to `Vec<f32>` happen only at PS
//! upload/download boundaries. NOTE: `execute_b` (device-resident
//! buffers) is avoided deliberately — in xla_extension 0.5.1 the
//! buffers it returns crash `to_literal_sync` with a fatal
//! `shape.IsArray()` check on tuple outputs; `execute` with literal
//! args is the supported path (see EXPERIMENTS.md §Perf).

pub mod literal;
pub mod session;

use std::collections::BTreeMap;

use anyhow::{anyhow, Context, Result};

use crate::data::Dataset;
use crate::model::state::TensorMap;
use crate::model::{Manifest, TensorSpec};
use literal::{lit_f32, lit_i32, lit_scalar_f32};
use session::SessionState;

/// Mask pair fed to every executable (DESIGN.md "masking trick").
#[derive(Debug, Clone, PartialEq)]
pub struct Masks {
    /// `[L * r_max]` row-major rank mask (or `[L * w_max]` width mask
    /// for the adapter family).
    pub rank_mask: Vec<f32>,
    /// `[L]` layer mask.
    pub layer_mask: Vec<f32>,
}

/// Scalar results of one train step.
#[derive(Debug, Clone, Copy)]
pub struct StepStats {
    pub loss: f32,
    pub correct: f32,
}

/// The compiled artifact set + persistent device state.
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    /// Frozen base as literals (built once).
    base_lits: Vec<xla::Literal>,
    /// Host copy of the base (for tests / inspection).
    base_host: Vec<Vec<f32>>,
    /// Keyed by artifact name. Ordered map: any future iteration
    /// (cache eviction, stats dumps) must be deterministically
    /// ordered, per the detlint unordered-collection rule.
    executables: BTreeMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Load manifest + base weights, compile train/eval executables
    /// for both families.
    pub fn load(artifacts_dir: &str) -> Result<Runtime> {
        let manifest = Manifest::load(artifacts_dir)
            .with_context(|| format!("loading manifest from {artifacts_dir}"))?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT CPU client: {e}"))?;

        let base_host = manifest.load_base_weights()?;
        let base_lits = base_host
            .iter()
            .zip(&manifest.base)
            .map(|(data, spec)| lit_f32(data, &spec.shape))
            .collect::<Result<Vec<_>>>()?;

        let mut rt = Runtime {
            client,
            manifest,
            base_lits,
            base_host,
            executables: BTreeMap::new(),
        };
        for family in ["lora", "adapter"] {
            let fam = rt.manifest.family(family).clone();
            rt.compile(&fam.train.artifact)?;
            rt.compile(&fam.eval.artifact)?;
        }
        Ok(rt)
    }

    /// Compile one HLO-text artifact and cache the executable.
    fn compile(&mut self, artifact: &str) -> Result<()> {
        if self.executables.contains_key(artifact) {
            return Ok(());
        }
        let path = self.manifest.artifact_path(artifact);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parsing {path}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {artifact}: {e}"))?;
        self.executables.insert(artifact.to_string(), exe);
        Ok(())
    }

    fn exe(&self, artifact: &str) -> &xla::PjRtLoadedExecutable {
        &self.executables[artifact]
    }

    pub fn base_host(&self) -> &[Vec<f32>] {
        &self.base_host
    }

    /// One AdamW train step for `family`. `state` is updated in place
    /// (kept as literals between steps). Returns loss + correct count.
    pub fn train_step(&self, family: &str, state: &mut SessionState,
                      masks: &Masks, tokens: &[i32], labels: &[i32],
                      lr: f32, step: f32) -> Result<StepStats> {
        let dim = &self.manifest.dim;
        let fam = self.manifest.family(family);
        assert_eq!(tokens.len(), dim.batch_size * dim.seq_len,
                   "train batch shape");
        assert_eq!(labels.len(), dim.batch_size);
        let n_state = state.trainable.len() + state.opt.len();
        assert_eq!(
            fam.train.inputs.len(),
            self.base_lits.len() + n_state + 6,
            "manifest IO drift"
        );

        let l = dim.n_layers;
        let r = masks.rank_mask.len() / l;
        // Per-call literals for masks + batch + scalars.
        let call_lits = vec![
            lit_f32(&masks.rank_mask, &[l, r])?,
            lit_f32(&masks.layer_mask, &[l])?,
            lit_i32(tokens, &[dim.batch_size, dim.seq_len])?,
            lit_i32(labels, &[dim.batch_size])?,
            lit_scalar_f32(lr),
            lit_scalar_f32(step),
        ];
        let args: Vec<&xla::Literal> = self
            .base_lits
            .iter()
            .chain(state.trainable.iter())
            .chain(state.opt.iter())
            .chain(call_lits.iter())
            .collect();
        let mut outs = self.run_tupled(&fam.train.artifact, &args)?;
        // outputs: trainable… opt… loss correct
        let nt = state.trainable.len();
        let no = state.opt.len();
        if outs.len() != nt + no + 2 {
            return Err(anyhow!(
                "train step returned {} outputs, expected {}",
                outs.len(),
                nt + no + 2
            ));
        }
        let correct = outs
            .pop()
            .unwrap()
            .to_vec::<f32>()
            .map_err(|e| anyhow!("{e}"))?[0];
        let loss = outs
            .pop()
            .unwrap()
            .to_vec::<f32>()
            .map_err(|e| anyhow!("{e}"))?[0];
        state.opt = outs.split_off(nt);
        state.trainable = outs;
        Ok(StepStats { loss, correct })
    }

    /// Evaluate `trainable` on `ds`; returns (mean_loss, accuracy).
    /// Processes ⌊n/eval_batch⌋ full batches (remainder dropped; the
    /// harnesses size test sets as multiples of the eval batch).
    pub fn evaluate(&self, family: &str, trainable: &TensorMap,
                    masks: &Masks, ds: &Dataset) -> Result<(f64, f64)> {
        let dim = &self.manifest.dim;
        let fam = self.manifest.family(family);
        let e = dim.eval_batch;
        let n_batches = ds.len() / e;
        assert!(n_batches > 0, "test set smaller than eval batch");

        let mut t_lits = session::map_to_literals(trainable)?;
        let l = dim.n_layers;
        let r = masks.rank_mask.len() / l;
        t_lits.push(lit_f32(&masks.rank_mask, &[l, r])?);
        t_lits.push(lit_f32(&masks.layer_mask, &[l])?);

        let (mut loss_sum, mut correct_sum) = (0f64, 0f64);
        for b in 0..n_batches {
            let mut toks = Vec::with_capacity(e * dim.seq_len);
            let mut labels = Vec::with_capacity(e);
            for j in 0..e {
                let ex = &ds.examples[b * e + j];
                toks.extend_from_slice(&ex.tokens);
                labels.push(ex.label);
            }
            let tok_lit = lit_i32(&toks, &[e, dim.seq_len])?;
            let lab_lit = lit_i32(&labels, &[e])?;
            let args: Vec<&xla::Literal> = self
                .base_lits
                .iter()
                .chain(t_lits.iter())
                .chain([&tok_lit, &lab_lit])
                .collect();
            let outs = self.run_tupled(&fam.eval.artifact, &args)?;
            // detlint-allow: float-accum eval batches reduce in fixed batch order on one thread
            loss_sum +=
                outs[0].to_vec::<f32>().map_err(|e| anyhow!("{e}"))?[0] as f64;
            // detlint-allow: float-accum eval batches reduce in fixed batch order on one thread
            correct_sum +=
                outs[1].to_vec::<f32>().map_err(|e| anyhow!("{e}"))?[0] as f64;
        }
        let n = (n_batches * e) as f64;
        Ok((loss_sum / n, correct_sum / n))
    }

    /// Run the standalone Pallas LoRA kernel artifact (quickstart /
    /// L1-compose proof). Shapes must match the manifest's `kernel`.
    pub fn run_kernel(&mut self, x: &[f32], w: &[f32], a: &[f32],
                      b: &[f32], mask: &[f32], scale: f32,
                      dims: &KernelDims) -> Result<Vec<f32>> {
        self.compile("lora_kernel.hlo.txt")?;
        let args = [
            lit_f32(x, &[dims.m, dims.k])?,
            lit_f32(w, &[dims.k, dims.n])?,
            lit_f32(a, &[dims.r, dims.k])?,
            lit_f32(b, &[dims.n, dims.r])?,
            lit_f32(mask, &[dims.r])?,
            lit_f32(&[scale], &[1])?,
        ];
        let exe = self.exe("lora_kernel.hlo.txt");
        let result = exe
            .execute::<xla::Literal>(&args)
            .map_err(|e| anyhow!("kernel execute: {e}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("kernel fetch: {e}"))?;
        let outs = result
            .to_tuple()
            .map_err(|e| anyhow!("kernel untuple: {e}"))?;
        outs[0].to_vec::<f32>().map_err(|e| anyhow!("{e}"))
    }

    // ---- internals --------------------------------------------------------

    fn run_tupled(&self, artifact: &str, args: &[&xla::Literal])
                  -> Result<Vec<xla::Literal>> {
        let exe = self.exe(artifact);
        let result = exe
            .execute(args)
            .map_err(|e| anyhow!("execute {artifact}: {e}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch {artifact}: {e}"))?;
        lit.to_tuple().map_err(|e| anyhow!("untuple {artifact}: {e}"))
    }

    /// Trainable specs of a family (convenience for state init).
    pub fn trainable_specs(&self, family: &str) -> &[TensorSpec] {
        &self.manifest.family(family).trainable
    }
}

/// Shapes of the standalone kernel artifact.
#[derive(Debug, Clone, Copy)]
pub struct KernelDims {
    pub m: usize,
    pub k: usize,
    pub n: usize,
    pub r: usize,
}

impl KernelDims {
    pub fn from_manifest(dir: &str) -> Result<KernelDims> {
        let text = std::fs::read_to_string(format!("{dir}/manifest.json"))?;
        let v = crate::util::json::Value::parse(&text)
            .map_err(|e| anyhow!("{e}"))?;
        let shapes = v.get("kernel").get("shapes");
        let get = |name: &str, idx: usize| -> Result<usize> {
            shapes
                .get(name)
                .idx(idx)
                .as_usize()
                .ok_or_else(|| anyhow!("kernel shape {name}[{idx}]"))
        };
        Ok(KernelDims {
            m: get("x", 0)?,
            k: get("x", 1)?,
            n: get("w", 1)?,
            r: get("a", 0)?,
        })
    }
}
