//! Device-local training session state.
//!
//! Between local steps a device's trainable + optimizer tensors stay
//! as [`xla::Literal`]s (no host `Vec<f32>` round-trip); conversion to
//! [`TensorMap`] happens only at the PS upload/download boundary.

use anyhow::{anyhow, Result};

use super::literal::lit_f32;
use crate::model::state::TensorMap;
use crate::model::TensorSpec;

/// Literal-form trainable + optimizer state for one device.
pub struct SessionState {
    pub trainable: Vec<xla::Literal>,
    pub opt: Vec<xla::Literal>,
    /// Specs mirroring `trainable` (manifest order).
    pub trainable_specs: Vec<TensorSpec>,
    pub opt_specs: Vec<TensorSpec>,
}

/// Convert a TensorMap to literals in its own order.
pub fn map_to_literals(map: &TensorMap) -> Result<Vec<xla::Literal>> {
    map.entries
        .iter()
        .map(|(spec, data)| lit_f32(data, &spec.shape))
        .collect()
}

/// Convert literals back to a TensorMap given matching specs.
pub fn literals_to_map(lits: &[xla::Literal], specs: &[TensorSpec])
                       -> Result<TensorMap> {
    if lits.len() != specs.len() {
        return Err(anyhow!(
            "literal count {} vs specs {}",
            lits.len(),
            specs.len()
        ));
    }
    let entries = lits
        .iter()
        .zip(specs)
        .map(|(lit, spec)| {
            let v = lit.to_vec::<f32>().map_err(|e| anyhow!("{e}"))?;
            if v.len() != spec.numel() {
                return Err(anyhow!(
                    "tensor {}: {} elems vs spec {}",
                    spec.name,
                    v.len(),
                    spec.numel()
                ));
            }
            Ok((spec.clone(), v))
        })
        .collect::<Result<Vec<_>>>()?;
    Ok(TensorMap { entries })
}

impl SessionState {
    /// Start a session from host-side state maps.
    pub fn from_maps(trainable: &TensorMap, opt: &TensorMap)
                     -> Result<SessionState> {
        Ok(SessionState {
            trainable: map_to_literals(trainable)?,
            opt: map_to_literals(opt)?,
            trainable_specs: trainable
                .entries
                .iter()
                .map(|(s, _)| s.clone())
                .collect(),
            opt_specs: opt.entries.iter().map(|(s, _)| s.clone()).collect(),
        })
    }

    /// Materialize back to host maps (upload boundary).
    pub fn to_maps(&self) -> Result<(TensorMap, TensorMap)> {
        Ok((
            literals_to_map(&self.trainable, &self.trainable_specs)?,
            literals_to_map(&self.opt, &self.opt_specs)?,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_map() -> TensorMap {
        TensorMap {
            entries: vec![
                (
                    TensorSpec { name: "a".into(), shape: vec![2, 2] },
                    vec![1.0, 2.0, 3.0, 4.0],
                ),
                (
                    TensorSpec { name: "b".into(), shape: vec![3] },
                    vec![-1.0, 0.5, 9.0],
                ),
            ],
        }
    }

    #[test]
    fn maps_roundtrip_through_literals() {
        let t = toy_map();
        let o = toy_map();
        let s = SessionState::from_maps(&t, &o).unwrap();
        let (t2, o2) = s.to_maps().unwrap();
        assert_eq!(t, t2);
        assert_eq!(o, o2);
    }

    #[test]
    fn mismatched_specs_rejected() {
        let t = toy_map();
        let lits = map_to_literals(&t).unwrap();
        let wrong = vec![TensorSpec { name: "a".into(), shape: vec![5] }];
        assert!(literals_to_map(&lits, &wrong).is_err());
    }
}
