//! Literal construction/extraction helpers for the `xla` crate.

use anyhow::{anyhow, Result};

/// f32 literal with explicit dims.
pub fn lit_f32(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product();
    if n != data.len() {
        return Err(anyhow!("lit_f32: {} elems vs dims {dims:?}", data.len()));
    }
    // f32 → raw little-endian bytes (host is LE; XLA expects host order).
    let bytes: &[u8] = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
    };
    xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::F32,
        dims,
        bytes,
    )
    .map_err(|e| anyhow!("create f32 literal {dims:?}: {e}"))
}

/// i32 literal with explicit dims.
pub fn lit_i32(data: &[i32], dims: &[usize]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product();
    if n != data.len() {
        return Err(anyhow!("lit_i32: {} elems vs dims {dims:?}", data.len()));
    }
    let bytes: &[u8] = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
    };
    xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::S32,
        dims,
        bytes,
    )
    .map_err(|e| anyhow!("create i32 literal {dims:?}: {e}"))
}

/// Rank-0 f32 scalar.
pub fn lit_scalar_f32(x: f32) -> xla::Literal {
    xla::Literal::scalar(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip() {
        let data = vec![1.5f32, -2.0, 3.25, 0.0, 7.0, -8.5];
        let lit = lit_f32(&data, &[2, 3]).unwrap();
        assert_eq!(lit.element_count(), 6);
        assert_eq!(lit.to_vec::<f32>().unwrap(), data);
    }

    #[test]
    fn i32_roundtrip() {
        let data = vec![1i32, -2, 3, 4];
        let lit = lit_i32(&data, &[4]).unwrap();
        assert_eq!(lit.to_vec::<i32>().unwrap(), data);
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(lit_f32(&[1.0, 2.0], &[3]).is_err());
        assert!(lit_i32(&[1, 2, 3], &[2, 2]).is_err());
    }

    #[test]
    fn scalar() {
        let lit = lit_scalar_f32(4.25);
        assert_eq!(lit.get_first_element::<f32>().unwrap(), 4.25);
    }
}
