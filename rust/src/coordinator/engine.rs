//! # RoundEngine — the parallel, streaming round loop
//!
//! Architecture. `server::run_federated` used to be a ~250-line
//! monolith that simulated every device *sequentially* and buffered
//! all `n` update TensorMaps before aggregating — O(n) wall-clock and
//! O(n) memory per round, against a paper whose whole point is
//! exploiting heterogeneity across large fleets. This module factors
//! the six phases (§3) into an engine with three independent axes:
//!
//! 1. **Execution** — phase ④ (local fine-tuning) is expressed as a
//!    vector of [`TrainJob`]s handed to [`Trainer::train_cohort`]
//!    together with [`ExecOpts`]. Backends whose per-device handles
//!    are `Send` (the mock; any future multi-client PJRT pool) run
//!    them on a scoped worker pool ([`train_parallel`]);
//!    non-thread-safe backends run them in device order
//!    ([`train_sequential`]). Either way outcomes reach the sink
//!    *re-serialized into device-index order* (the reorder buffer
//!    lives inside [`train_parallel`]), so every downstream effect —
//!    transport accounting, aggregation folds, loss bookkeeping — is
//!    identical at every thread count: same seed ⇒ bit-identical
//!    [`RunRecord`]. Backpressure: with `ExecOpts::window = W > 0`,
//!    workers pause before running a job more than `W` ahead of the
//!    fold cursor, so completed-but-unfolded outcomes never exceed
//!    `W` and per-round transient memory is O(model + W) instead of
//!    cohort-bounded under skew.
//!
//! 2. **Aggregation** — instead of buffering `Vec<DeviceUpdate>` and
//!    calling the one-shot `aggregate()`, the engine folds each update
//!    into a [`ShardedAggregator`] as it is re-serialized, then
//!    finalizes once per round. The fold itself is O(model size),
//!    independent of the fleet, and with `FedConfig::agg_shards > 1`
//!    it is partitioned per tensor across worker threads (disjoint
//!    element sets, merged in deterministic shard-index order), so the
//!    coordinator core stops being the fold bottleneck at large
//!    cohorts. The fold order (device index) makes the result
//!    bit-identical to the buffered eq. 17 path at every
//!    `threads × shards × window` setting.
//!
//! 3. **Participation** — cohort selection is delegated to a
//!    [`Participation`] policy with two hooks: `sample` picks which
//!    devices take part before configuration (full participation,
//!    uniform client sampling), and `admit` filters the configured
//!    cohort by predicted eq. 12 completion time (straggler-deadline
//!    drop). New FL scenarios plug in without touching this loop.
//!    Devices outside the cohort exchange no bytes this round: no
//!    status report, no assignment, no upload (Fig. 11 accounting
//!    stays honest under sampling).
//!
//! Since the multi-job coordinator landed (`coordinator/jobs.rs`,
//! docs/MULTIJOB.md), the round loop itself lives in
//! [`RoundLoopState`]: everything one job carries across rounds, with
//! `sample_cohort` + `step` as the per-round entry points.
//! [`RoundEngine::run`] is the degenerate single-job case — one state,
//! the full sampled cohort, no ingest cap — and is property-tested to
//! reproduce the pre-split loop bitwise.
//!
//! Determinism contract: all RNG draws (data, fleet observation,
//! participation) happen on the coordinator thread in a fixed order;
//! per-device training state is keyed by device id and derived from
//! the run seed, never from arrival order.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Condvar, Mutex};

use anyhow::{anyhow, Result};

use crate::data::{grammar, Dataset, Spec};
use crate::device::profile::calib;
use crate::device::FleetView;
use crate::metrics::{RoundRecord, RunRecord};
use crate::model::masks::LoraConfig;
use crate::model::state::TensorMap;
use crate::runtime::Masks;
use crate::sim::clock::{simulate_round, DeviceRound, VirtualClock};
use crate::util::rng::Rng;

use super::aggregation::EdgeAggregator;
use super::capacity::{CapacityEstimator, Reallocator};
use super::participation::Participation;
use super::serialize;
use super::server::{cosine_lr, FedConfig, ModelMeta};
use super::strategy::{Strategy, StrategyCtx};
use super::trainer::{CohortSink, DeviceTrainer, LocalOutcome, Trainer};
use super::transport::{Tally, Transport};

/// One device's phase-④ work item. Everything a worker thread needs,
/// by value or by shared reference: the assignment payload is read
/// straight from the global model (the in-process "wire" — transport
/// counts the active-slot bytes that would actually travel).
pub struct TrainJob<'a> {
    pub device_id: usize,
    pub init: &'a TensorMap,
    pub masks: Masks,
    pub shard: &'a Dataset,
    pub lr: f32,
    pub max_batches: usize,
}

/// Resolve a `threads` setting: 0 = one worker per available core.
pub fn effective_threads(threads: usize) -> usize {
    if threads == 0 {
        std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(1)
    } else {
        threads
    }
}

/// Phase-④ execution knobs, threaded from [`super::server::FedConfig`]
/// through [`Trainer::train_cohort`] to [`train_parallel`].
#[derive(Debug, Clone, Copy, Default)]
pub struct ExecOpts {
    /// Worker threads (0 = one per available core).
    pub threads: usize,
    /// In-flight window `W` (0 = unbounded): workers pause before
    /// running a job more than `W` ahead of the fold cursor, bounding
    /// completed-but-unfolded outcomes — and thus per-round transient
    /// memory — to O(W) instead of O(cohort) under skew. Purely a
    /// scheduling constraint: results are bit-identical at every `W`.
    pub window: usize,
}

/// Observability for the execution path (window/backpressure tests).
#[derive(Debug, Clone, Copy, Default)]
pub struct ExecStats {
    /// Peak size of the reorder buffer: outcomes that had completed
    /// but were not yet delivered to the sink. With `window = W > 0`
    /// this never exceeds `W`.
    pub max_pending: usize,
}

/// Drive `handles[i]` over `jobs[i]` in job order on the calling
/// thread. Works for any backend (handles need not be `Send`).
pub fn train_sequential<H: DeviceTrainer>(
    jobs: &[TrainJob<'_>], handles: &mut [H], sink: CohortSink<'_>,
) -> Result<ExecStats> {
    debug_assert_eq!(jobs.len(), handles.len());
    for (i, (job, h)) in jobs.iter().zip(handles.iter_mut()).enumerate() {
        let out = h.train_local(job)?;
        sink(i, out)?;
    }
    Ok(ExecStats::default())
}

/// Drive `handles[i]` over `jobs[i]` on up to `opts.threads` scoped
/// worker threads (0 = auto). Outcomes are delivered to `sink` on the
/// calling thread **in job-index order** — the reorder buffer lives
/// here, and with `opts.window = W > 0` workers pause before running a
/// job more than `W` ahead of the fold cursor, so the buffer never
/// holds more than `W` outcomes.
///
/// Each device's outcome is a pure function of `(job, handle)`, and
/// delivery order is fixed, so the sink sees an identical stream at
/// every `threads × window` setting; only the wall-clock varies.
pub fn train_parallel<H: DeviceTrainer + Send>(
    jobs: &[TrainJob<'_>], handles: &mut [H], opts: &ExecOpts,
    sink: CohortSink<'_>,
) -> Result<ExecStats> {
    debug_assert_eq!(jobs.len(), handles.len());
    let n = jobs.len();
    let workers = effective_threads(opts.threads).min(n.max(1));
    if workers <= 1 || n <= 1 {
        return train_sequential(jobs, handles, sink);
    }
    let window = if opts.window == 0 {
        usize::MAX
    } else {
        opts.window
    };

    // Work stealing off an atomic cursor; each handle is touched by
    // exactly one claim, the Mutex only proves that to the compiler.
    let cells: Vec<Mutex<&mut H>> =
        handles.iter_mut().map(Mutex::new).collect();
    let next = AtomicUsize::new(0);
    // First failure aborts the round: workers stop claiming new jobs
    // instead of training the rest of the cohort to completion.
    let abort = AtomicBool::new(false);
    // Fold cursor: the lowest job index not yet delivered to the
    // sink. A worker holding claim `i` parks until `i < cursor + W`;
    // the receiver advances the cursor under the mutex and signals
    // the condvar after each in-order delivery (and on abort, so
    // parked workers can exit).
    let cursor = Mutex::new(0usize);
    let unblock = Condvar::new();
    let (tx, rx) = mpsc::channel::<(usize, Result<LocalOutcome>)>();
    std::thread::scope(|s| {
        for _ in 0..workers {
            let tx = tx.clone();
            let (cells, next, abort) = (&cells, &next, &abort);
            let (cursor, unblock) = (&cursor, &unblock);
            s.spawn(move || loop {
                if abort.load(Ordering::Relaxed) {
                    break;
                }
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                {
                    // In-flight window: park until job i is within W
                    // of the fold cursor (or the round aborted).
                    let mut c = cursor.lock().expect("cursor poisoned");
                    while i >= (*c).saturating_add(window) {
                        if abort.load(Ordering::Relaxed) {
                            return;
                        }
                        c = unblock.wait(c).expect("cursor poisoned");
                    }
                }
                let out = cells[i]
                    .lock()
                    .expect("job cell poisoned")
                    .train_local(&jobs[i]);
                if out.is_err() {
                    abort.store(true, Ordering::Relaxed);
                }
                if tx.send((i, out)).is_err() {
                    break; // receiver gone: the round was aborted
                }
            });
        }
        drop(tx);
        // Drain until the channel closes (all workers exited) so no
        // sender blocks; on abort the tail of the cohort is simply
        // never claimed. Outcomes are re-serialized into job-index
        // order through the reorder buffer before reaching the sink.
        // A sink (fold/accounting) failure outranks training failures
        // — it fired first and is deterministic; among training
        // failures, surface the lowest job index (best-effort
        // determinism — which jobs ran at all depends on abort
        // timing).
        let mut pending: BTreeMap<usize, LocalOutcome> = BTreeMap::new();
        let mut stats = ExecStats::default();
        let mut next_k = 0usize;
        let mut sink_err: Option<anyhow::Error> = None;
        let mut train_err: Option<(usize, anyhow::Error)> = None;
        // Set abort under the cursor lock so a worker that checked
        // the flag just before parking cannot miss the wake-up.
        let fail = |flag: &AtomicBool| {
            let _c = cursor.lock().expect("cursor poisoned");
            flag.store(true, Ordering::Relaxed);
            unblock.notify_all();
        };
        while let Ok((i, res)) = rx.recv() {
            match res {
                Ok(out)
                    if sink_err.is_none() && train_err.is_none() =>
                {
                    pending.insert(i, out);
                    stats.max_pending =
                        stats.max_pending.max(pending.len());
                    while let Some(out) = pending.remove(&next_k) {
                        if let Err(e) = sink(next_k, out) {
                            sink_err = Some(e);
                            fail(&abort);
                            break;
                        }
                        next_k += 1;
                        *cursor.lock().expect("cursor poisoned") = next_k;
                        unblock.notify_all();
                    }
                }
                Ok(_) => {}
                Err(e) => {
                    if train_err
                        .as_ref()
                        .map_or(true, |(j, _)| i < *j)
                    {
                        train_err =
                            Some((i, e.context(format!("job {i}"))));
                    }
                    fail(&abort);
                }
            }
        }
        match (sink_err, train_err) {
            (Some(e), _) => Err(e),
            (None, Some((_, e))) => Err(e),
            (None, None) => {
                debug_assert_eq!(next_k, n, "missing device outcomes");
                Ok(stats)
            }
        }
    })
}

/// The round-loop engine. Owns nothing across runs; all persistent
/// state (estimator, clock, transport tallies) lives for one `run`.
pub struct RoundEngine<'a> {
    cfg: &'a FedConfig,
    meta: &'a ModelMeta,
}

impl<'a> RoundEngine<'a> {
    pub fn new(cfg: &'a FedConfig, meta: &'a ModelMeta) -> Self {
        RoundEngine { cfg, meta }
    }

    /// Run one full federated fine-tuning experiment.
    ///
    /// This is the degenerate single-job case of the multi-job
    /// scheduler (`coordinator/jobs.rs`): one [`RoundLoopState`], a
    /// private [`CapacityEstimator`], the full sampled cohort and no
    /// ingest cap every round — property-tested to reproduce the
    /// pre-split monolithic loop bitwise.
    pub fn run(&self, fleet: &mut dyn FleetView,
               strategy: &mut dyn Strategy,
               trainer: &mut dyn Trainer, spec: &Spec,
               mut global: TensorMap,
               participation: &mut dyn Participation)
               -> Result<RunRecord> {
        let cfg = self.cfg;
        let n = fleet.len();
        let mut estimator = CapacityEstimator::paper(n);
        let mut state = RoundLoopState::new(cfg, self.meta, strategy,
                                            trainer, spec, n,
                                            &*participation)?;
        for h in 1..=cfg.rounds {
            if h > 1 {
                fleet.advance_round();
            }
            let cohort = state.sample_cohort(participation, h);
            state.step(cfg, self.meta, fleet, strategy, trainer, spec,
                       &mut global, participation, &mut estimator, h,
                       &cohort, usize::MAX)?;
        }
        Ok(state.finish())
    }
}

/// What one [`RoundLoopState::step`] did: how many updates folded and
/// the round's transport tally. The multi-job scheduler deducts
/// `folded` from the job's token bucket and merges the tallies into
/// its fleet-wide traffic total.
pub(crate) struct StepReport {
    pub folded: usize,
    pub tally: Tally,
}

/// Everything one job's round loop carries **across** rounds, split
/// out of [`RoundEngine::run`] so the multi-job scheduler
/// (`coordinator/jobs.rs`) can interleave many jobs over a shared
/// fleet one round at a time. The capacity estimator is deliberately
/// NOT part of this state: device capacity is a property of the
/// fleet, not of any job, so the caller owns it (the single-job
/// engine makes a private one; the scheduler shares one across all
/// of its jobs).
///
/// Per-round protocol: `sample_cohort` (participation sampling — the
/// only RNG this state owns) and then `step` (the six §3 phases over
/// a caller-chosen cohort, which the scheduler may have rewritten to
/// resolve cross-job contention). `RoundEngine::run` is exactly
/// sample + step with the untouched cohort and `ingest_cap =
/// usize::MAX`.
pub(crate) struct RoundLoopState {
    realloc: Reallocator,
    transport: Transport,
    clock: VirtualClock,
    record: RunRecord,
    part_rng: Rng,
    /// (round recorded, loss) per device that has ever trained —
    /// sparse, so state is O(devices seen), not O(fleet). A device
    /// re-entering a sampled cohort after sitting out must not have
    /// a many-rounds-old loss surfaced to strategies as "last
    /// round": only an entry from round h−1 reads as fresh.
    loss_log: BTreeMap<usize, (usize, f64)>,
    last_round_time: f64,
    last_acc: f64,
    last_test_loss: f64,
    /// Only the shared test set is materialized up front; training
    /// shards are derived per cohort member per round (a pure
    /// function of `(seed, device_id)`), so data memory is
    /// O(cohort), never O(fleet).
    test: Dataset,
    batch: usize,
    rank_dim: usize,
    unit_bytes: usize,
    n: usize,
}

impl RoundLoopState {
    pub(crate) fn new(cfg: &FedConfig, meta: &ModelMeta,
                      strategy: &dyn Strategy, trainer: &dyn Trainer,
                      spec: &Spec, n: usize,
                      participation: &dyn Participation)
                      -> Result<Self> {
        participation
            .validate(n)
            .map_err(|e| anyhow!("participation: {e}"))?;
        let family = trainer.family();
        Ok(RoundLoopState {
            realloc: Reallocator::new(cfg.realloc_every,
                                      cfg.realloc_hysteresis),
            transport: Transport::new(),
            clock: VirtualClock::new(),
            record: RunRecord::new(&strategy.name(), &cfg.task),
            part_rng: Rng::new(cfg.seed).child("participation"),
            loss_log: BTreeMap::new(),
            last_round_time: 0.0,
            last_acc: 0.0,
            last_test_loss: 0.0,
            test: test_data(cfg, spec)?,
            batch: trainer.batch_size(),
            rank_dim: meta.rank_dim(family),
            unit_bytes: meta.unit_bytes(family),
            n,
        })
    }

    /// ①a cohort sampling (pre-configuration). An empty or
    /// out-of-range sample keeps the round minimal (device 0 only)
    /// rather than silently reverting to full participation —
    /// mirroring the admit() fallback inside `step`.
    pub(crate) fn sample_cohort(&mut self,
                                participation: &mut dyn Participation,
                                h: usize) -> Vec<usize> {
        sanitize(participation.sample(h, self.n, &mut self.part_rng),
                 self.n)
            .unwrap_or_else(|| vec![0])
    }

    /// Latest evaluated test accuracy (0.0 before the first eval).
    pub(crate) fn latest_accuracy(&self) -> f64 {
        self.last_acc
    }

    /// Seal the run: stamp the final plan-epoch count and hand back
    /// the per-job [`RunRecord`].
    pub(crate) fn finish(mut self) -> RunRecord {
        self.record.rank_realloc_epochs = self.realloc.epoch();
        self.record
    }

    /// One global round for this job over `cohort` — sorted, deduped,
    /// in-range and non-empty (`sample_cohort` output, possibly with
    /// contested devices swapped out by the multi-job scheduler).
    /// `ingest_cap` bounds how many updates the coordinator folds
    /// this round (the job's token-bucket grant); `usize::MAX` =
    /// unlimited, which is bitwise a no-op.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn step(&mut self, cfg: &FedConfig, meta: &ModelMeta,
                       fleet: &mut dyn FleetView,
                       strategy: &mut dyn Strategy,
                       trainer: &mut dyn Trainer, spec: &Spec,
                       global: &mut TensorMap,
                       participation: &mut dyn Participation,
                       estimator: &mut CapacityEstimator, h: usize,
                       cohort: &[usize], ingest_cap: usize)
                       -> Result<StepReport> {
        let Self {
            realloc,
            transport,
            clock,
            record,
            loss_log,
            last_round_time,
            last_acc,
            last_test_loss,
            test,
            batch,
            rank_dim,
            unit_bytes,
            n,
            ..
        } = self;
        let (batch, rank_dim, unit_bytes, n) =
            (*batch, *rank_dim, *unit_bytes, *n);
        transport.begin_round();

        // ⓪ materialize exactly the cohort's shards for this round —
        // each a pure function of `(seed, device_id)`, so non-cohort
        // devices cost nothing.
        let shards: BTreeMap<usize, Dataset> = cohort
            .iter()
            .map(|&i| Ok((i, device_shard(cfg, spec, i, n, batch)?)))
            .collect::<Result<_>>()?;

        // ①b status reports → capacity estimation (eq. 8–9) → the
        // round's *plan* capacities. Only sampled devices report: a
        // skipped device costs zero bytes this round, STATUS_BYTES
        // included. With `--realloc-every K > 0` the live EWMA
        // estimates are frozen between refit rounds (hysteresis keeps
        // an unchanged fit bitwise), so the LCD plan is a per-round
        // value under an explicit epoch; K = 0 passes the live
        // estimates straight through — today's engine, bitwise. The
        // epoch is resolved before any message is logged so every
        // exchange names the plan it belongs to.
        let live: Vec<_> = cohort
            .iter()
            .map(|&i| {
                let (mu_hat, beta_hat) = fleet.observe(i, unit_bytes);
                estimator.update(i, mu_hat, beta_hat);
                estimator.get(i).expect("cohort reported")
            })
            .collect();
        let estimates = realloc.plan_estimates(h, cohort, &live);
        let epoch = realloc.epoch();
        for &i in cohort {
            transport.recv_status(h, epoch, i);
        }
        let n_batches: Vec<usize> = cohort
            .iter()
            .map(|&i| {
                shards[&i].len().div_ceil(batch).min(cfg.max_batches)
            })
            .collect();

        // ② LoRA configuration (§4.4) over the cohort.
        let fwd_times: Vec<f64> = estimates
            .iter()
            .map(|c| calib::FWD_FRAC * c.mu * meta.n_layers as f64)
            .collect();
        let ctx = StrategyCtx {
            round: h,
            n_layers: meta.n_layers,
            rank_dim,
            fwd_times: fwd_times.clone(),
            estimates: estimates.clone(),
            n_batches: n_batches.clone(),
            unit_rank_bytes: unit_bytes,
            compute_budgets: vec![f64::MAX; cohort.len()],
            comm_budgets: vec![usize::MAX; cohort.len()],
            last_losses: cohort
                .iter()
                .map(|&i| {
                    // Only a loss recorded in the immediately
                    // previous round is "last round"; anything
                    // older surfaces as 0 (round-1 semantics).
                    match loss_log.get(&i) {
                        Some(&(r, loss)) if r + 1 == h => loss,
                        _ => 0.0,
                    }
                })
                .collect(),
            last_round_time: *last_round_time,
            device_ids: cohort.to_vec(),
            staleness: cohort
                .iter()
                .map(|&i| {
                    // Rounds since the device's loss was recorded:
                    // 0 = fresh (immediately previous round),
                    // usize::MAX = never trained.
                    match loss_log.get(&i) {
                        Some(&(r, _)) => (h - 1).saturating_sub(r),
                        None => usize::MAX,
                    }
                })
                .collect(),
        };
        let plan = strategy.configure(&ctx);
        debug_assert_eq!(plan.device_configs.len(), cohort.len());

        // ①c deadline admission: predicted eq. 12 completion from
        // the PS-side *estimates* (the true parameters are not
        // observable at the server). Same DeviceRound math as phase
        // ⑥, just fed with estimates instead of truth.
        let predicted: Vec<f64> = (0..cohort.len())
            .map(|j| {
                device_round(meta, unit_bytes, cohort[j],
                             estimates[j].mu, estimates[j].beta,
                             fwd_times[j],
                             &plan.device_configs[j],
                             n_batches[j])
                    .completion_time()
            })
            .collect();
        let admitted =
            admitted_cohort(participation, h, cohort, &predicted, n);
        // Per-job ingest rate limit (multi-job token bucket): fold at
        // most `ingest_cap` updates this round, preferring the
        // fastest-predicted devices. usize::MAX leaves the admitted
        // cohort untouched.
        let admitted =
            rate_limited(admitted, cohort, &predicted, ingest_cap);
        // Cohort positions of the admitted devices.
        let admitted_pos: Vec<usize> = admitted
            .iter()
            .map(|i| cohort.binary_search(i).unwrap())
            .collect();

        // ③ assignment + download accounting (§4.6), ④ local
        // fine-tuning, ⑤ streaming upload accounting + layer-wise
        // aggregation (eq. 17).
        let lr = cosine_lr(cfg.lr0, h, cfg.rounds) as f32;
        // Shared view of the global for the assignment/fold phase;
        // the unique reborrow for `agg.finish` happens after the jobs
        // (and the sink's wire reads) are done with it.
        let global_ro: &TensorMap = &*global;
        let jobs: Vec<TrainJob<'_>> = admitted_pos
            .iter()
            .map(|&j| {
                let i = cohort[j];
                let config = &plan.device_configs[j];
                transport.send_assignment(h, epoch, i, global_ro,
                                          config, meta.n_layers,
                                          rank_dim);
                TrainJob {
                    device_id: i,
                    init: global_ro,
                    masks: Masks {
                        rank_mask: config
                            .rank_mask(meta.n_layers, rank_dim),
                        layer_mask: config.layer_mask(meta.n_layers),
                    },
                    shard: &shards[&i],
                    lr,
                    max_batches: cfg.max_batches,
                }
            })
            .collect();

        // Shard fold queues inherit the window: with W set, at most W
        // updates sit in a lagging shard's queue before push()
        // back-pressures, keeping transient memory O(model + W) end
        // to end. The edge tier slices the admitted cohort across
        // `edge_aggregators` concurrent folds; fixed-point
        // accumulation keeps the root merge bit-identical to the flat
        // fold at every edge count.
        let shard_cap = if cfg.window > 0 { cfg.window } else { 8 };
        let mut agg = EdgeAggregator::new(
            global_ro, meta.n_layers, rank_dim, cfg.edge_aggregators,
            cfg.agg_shards, shard_cap, admitted.len(),
        );
        let mut loss_sum = 0f64;
        {
            // Outcomes arrive in device-index order (the reorder
            // buffer lives in train_parallel), so accounting and
            // eq. 17 folds are bit-stable at every threads × shards ×
            // window × edge setting.
            let transport = &*transport;
            let plan = &plan;
            let (cohort_r, admitted_pos_r) = (&cohort, &admitted_pos);
            let (agg_r, loss_log_r, loss_sum_r) =
                (&mut agg, &mut *loss_log, &mut loss_sum);
            // The device side encodes its update under the run's
            // codec (delta vs the assigned global it trained on); the
            // coordinator dequantizes exactly once here, before the
            // fold, and the tally records the real bytes-on-wire.
            // codec=none is a bitwise pass-through.
            let global_r = global_ro;
            let mut sink = |k: usize, out: LocalOutcome| {
                let j = admitted_pos_r[k];
                let i = cohort_r[j];
                let config = &plan.device_configs[j];
                let (wire, restored) = serialize::through_wire(
                    cfg.codec, out.trainable, global_r, config,
                    meta.n_layers, rank_dim)?;
                transport.recv_update(h, epoch, i, wire);
                loss_log_r.insert(i, (h, out.mean_loss));
                // detlint-allow: float-accum coordinator-thread fold in job-index order
                *loss_sum_r += out.mean_loss;
                agg_r.push(restored, config, 1.0)
            };
            let opts = ExecOpts {
                threads: cfg.threads,
                window: cfg.window,
            };
            trainer.train_cohort(&jobs, &opts, &mut sink)?;
        }
        drop(jobs);
        let tally = transport.round_tally();
        agg.finish(&mut *global)?;

        // ⑥ timing (eq. 12/13) with TRUE device parameters, over the
        // devices that actually took part.
        let rounds_t: Vec<DeviceRound> = admitted_pos
            .iter()
            .map(|&j| {
                let i = cohort[j];
                device_round(meta, unit_bytes, i, fleet.true_mu(i),
                             fleet.true_beta(i, unit_bytes),
                             fleet.forward_time(i, meta.n_layers),
                             &plan.device_configs[j], n_batches[j])
            })
            .collect();
        let timing = simulate_round(&rounds_t);
        clock.advance(&timing);
        *last_round_time = timing.round_time;

        // Evaluation of the aggregated global model.
        if h % cfg.eval_every == 0 || h == cfg.rounds {
            let eval_masks = Masks {
                rank_mask: plan
                    .eval_config
                    .rank_mask(meta.n_layers, rank_dim),
                layer_mask: plan.eval_config.layer_mask(meta.n_layers),
            };
            let (tl, ta) =
                trainer.evaluate(global, &eval_masks, test)?;
            *last_acc = ta;
            *last_test_loss = tl;
        }

        let depths: Vec<usize> = admitted_pos
            .iter()
            .map(|&j| plan.device_configs[j].depth(meta.n_layers))
            .collect();
        let mean_depth = mean_depth_of(&depths);
        record.rounds.push(RoundRecord {
            round: h,
            sim_time: clock.elapsed,
            round_time: timing.round_time,
            avg_waiting: timing.avg_waiting,
            up_bytes: tally.uplink,
            down_bytes: tally.downlink,
            train_loss: loss_sum / admitted.len().max(1) as f64,
            test_acc: *last_acc,
            test_loss: *last_test_loss,
            mean_depth,
            plan_epoch: epoch,
            participants: admitted.len(),
            dropped: cohort.len() - admitted.len(),
        });
        if cfg.verbose {
            println!(
                "[{}/{}] {} t={:.0}s acc={:.3} loss={:.3} \
                 depth={:.1} epoch={} wait={:.1}s part={}/{}",
                h,
                cfg.rounds,
                strategy.name(),
                clock.elapsed,
                *last_acc,
                loss_sum / admitted.len().max(1) as f64,
                mean_depth,
                epoch,
                timing.avg_waiting,
                admitted.len(),
                n,
            );
        }
        Ok(StepReport {
            folded: admitted.len(),
            tally,
        })
    }
}

/// Mean assigned LoRA depth over the updates that actually folded
/// this round. Now that the plan is a per-round value, both engines
/// must derive the depth diagnostic (and the round log line) from the
/// configs the folded updates *trained under* — the sync engine's
/// current plan, the async engine's per-update `InFlight` configs —
/// never from a run-start plan snapshot. One helper so the two can't
/// drift.
pub(crate) fn mean_depth_of(depths: &[usize]) -> f64 {
    depths.iter().map(|&d| d as f64).sum::<f64>()
        / depths.len().max(1) as f64
}

/// Eq. 12 inputs for one device. Shared by deadline admission (fed
/// with PS-side *estimates*) and phase ⑥ timing (fed with TRUE device
/// parameters) so the two can never drift apart. `pub(crate)` because
/// the async engine builds the identical prediction/timing inputs.
#[allow(clippy::too_many_arguments)]
pub(crate) fn device_round(meta: &ModelMeta, unit_bytes: usize,
                           device_id: usize, mu: f64, beta: f64,
                           fwd_time_per_batch: f64, config: &LoraConfig,
                           n_batches: usize) -> DeviceRound {
    DeviceRound {
        device_id,
        fwd_time_per_batch,
        mu,
        beta,
        depth: config.backprop_depth(meta.n_layers),
        ranks: config.active_ranks(meta.n_layers),
        n_batches,
        extra_upload_s: beta
            * (meta.head_bytes as f64 / unit_bytes.max(1) as f64),
    }
}

/// Phase-⓪ shared test set, generated from a dedicated child of the
/// run seed's "data" stream. Both engines consume it identically —
/// the async engine's sync-degeneracy oracle depends on that, so it
/// lives in exactly one place.
pub(crate) fn test_data(cfg: &FedConfig, spec: &Spec)
                        -> Result<Dataset> {
    let mut rng = Rng::new(cfg.seed).child("data").child("test");
    let test_size = (cfg.test_size / 64).max(1) * 64;
    Ok(grammar::generate(spec, &cfg.task, test_size, &mut rng)?)
}

/// Device `i`'s non-iid training shard, derived on demand from a
/// counter-based cell of the "data" stream — a pure function of
/// `(seed, device_id)`. A round materializes exactly its cohort's
/// shards; the other `n − |cohort|` devices (of possibly millions)
/// cost nothing, and the result never depends on which devices were
/// sampled before.
///
/// Non-iid skew follows the same model as `partition::split`: with
/// `alpha > 0` the device draws a Dirichlet(α) class mixture and
/// samples each example's label from it (`grammar::sample_labeled`
/// realizes the label in tokens); `alpha = 0` is the iid split. The
/// shard holds the device's largest-remainder share of
/// `cfg.train_size`, floored at one batch so a local epoch can always
/// run.
pub(crate) fn device_shard(cfg: &FedConfig, spec: &Spec, i: usize,
                           n: usize, batch: usize) -> Result<Dataset> {
    let n = n.max(1);
    let task = spec.task(&cfg.task)?.clone();
    let mut rng =
        Rng::new(cfg.seed).child("data").cell("shard", i as u64, 0);
    let size = (cfg.train_size / n
        + usize::from(i < cfg.train_size % n))
        .max(batch.max(1));
    let examples = if cfg.alpha > 0.0 {
        let mixture = rng.dirichlet(&vec![cfg.alpha; task.n_classes]);
        (0..size)
            .map(|_| {
                let label = rng.weighted(&mixture);
                grammar::sample_labeled(spec, &task, label, &mut rng)
            })
            .collect()
    } else {
        (0..size)
            .map(|_| grammar::sample_example(spec, &task, &mut rng))
            .collect()
    };
    Ok(Dataset { examples })
}

/// ①c deadline admission with the well-formed-round fallback, shared
/// by both engines. A policy that admits nobody (or out-of-cohort ids)
/// still gets a well-formed round: keep the single fastest-predicted
/// device — honoring the drop intent — rather than silently reverting
/// to full participation (eq. 12/13 need ≥ 1 participant).
pub(crate) fn admitted_cohort(participation: &mut dyn Participation,
                              h: usize, cohort: &[usize],
                              predicted: &[f64], n: usize)
                              -> Vec<usize> {
    let a = sanitize(participation.admit(h, cohort, predicted), n);
    match a {
        Some(a)
            if a.iter().all(|i| cohort.binary_search(i).is_ok()) =>
        {
            a
        }
        _ => {
            let j_min = predicted
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.total_cmp(b.1))
                .map(|(j, _)| j)
                .unwrap_or(0);
            vec![cohort[j_min]]
        }
    }
}

/// Truncate an admitted cohort to the token-bucket grant `cap`,
/// keeping the fastest-predicted devices (ties by id) and restoring
/// ascending-id order. `cap` is floored at 1 — the round loop needs
/// ≥ 1 participant, and the scheduler idles a job instead of stepping
/// it when its bucket is empty. A cap ≥ the cohort size is a no-op,
/// so the single-job engine (`usize::MAX`) is bitwise unaffected.
pub(crate) fn rate_limited(admitted: Vec<usize>, cohort: &[usize],
                           predicted: &[f64], cap: usize)
                           -> Vec<usize> {
    let cap = cap.max(1);
    if admitted.len() <= cap {
        return admitted;
    }
    let mut by_speed = admitted;
    by_speed.sort_by(|a, b| {
        let pa = predicted[cohort
            .binary_search(a)
            .expect("admitted device not in cohort")];
        let pb = predicted[cohort
            .binary_search(b)
            .expect("admitted device not in cohort")];
        pa.total_cmp(&pb).then(a.cmp(b))
    });
    by_speed.truncate(cap);
    by_speed.sort_unstable();
    by_speed
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sanitize_sorts_dedups_bounds() {
        assert_eq!(sanitize(vec![3, 1, 3, 9], 5), Some(vec![1, 3]));
        assert_eq!(sanitize(vec![9, 10], 5), None);
        assert_eq!(sanitize(vec![], 5), None);
    }

    #[test]
    fn effective_threads_resolves_auto() {
        assert!(effective_threads(0) >= 1);
        assert_eq!(effective_threads(3), 3);
    }

    #[test]
    fn mean_depth_reads_the_folded_configs() {
        // Regression (per-round plans): the diagnostic is the mean of
        // exactly the depths handed in — the folded updates' own
        // configs — not any earlier round's plan.
        assert_eq!(mean_depth_of(&[4, 8, 12]), 8.0);
        assert_eq!(mean_depth_of(&[7]), 7.0);
        // An empty fold (async window with nothing landing) reads 0,
        // not NaN.
        assert_eq!(mean_depth_of(&[]), 0.0);
    }

    #[test]
    fn rate_limited_keeps_fastest_and_restores_id_order() {
        let cohort = vec![2, 5, 7, 9];
        // predicted completion per cohort position: device 7 fastest,
        // then 2, then 9, then 5.
        let predicted = vec![3.0, 9.0, 1.0, 5.0];
        let all = vec![2, 5, 7, 9];
        assert_eq!(rate_limited(all.clone(), &cohort, &predicted, 2),
                   vec![2, 7]);
        assert_eq!(rate_limited(all.clone(), &cohort, &predicted, 3),
                   vec![2, 7, 9]);
        // cap >= len is a no-op (the single-job engine's path).
        assert_eq!(
            rate_limited(all.clone(), &cohort, &predicted, usize::MAX),
            all
        );
        // cap 0 is floored at 1: the loop needs a participant.
        assert_eq!(rate_limited(all, &cohort, &predicted, 0), vec![7]);
    }

    #[test]
    fn rate_limited_breaks_prediction_ties_by_id() {
        let cohort = vec![1, 2, 3];
        let predicted = vec![4.0, 4.0, 4.0];
        assert_eq!(rate_limited(vec![1, 2, 3], &cohort, &predicted, 2),
                   vec![1, 2]);
    }
}
