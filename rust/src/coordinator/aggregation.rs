//! LoRA aggregation (§4.5, eq. 17) — adaptive layer-wise averaging.
//!
//! Devices return heterogeneous-depth (and, for HetLoRA,
//! heterogeneous-rank) updates. The PS averages each transformer
//! layer's LoRA over exactly the devices holding that layer,
//! `θ_l = (1/n_l) Σ_i θ_{i,l}`; we implement it at rank-slot
//! granularity so HetLoRA's zero-padded mismatched ranks aggregate
//! correctly too. Slots no device holds this round keep their previous
//! global value.
//!
//! Three implementations of the same eq. 17 math:
//! * [`aggregate`] — the buffered one-shot reference over a
//!   `&[DeviceUpdate]` (kept for tests/benches and as the oracle the
//!   property suite compares against);
//! * [`StreamingAggregator`] — folds updates one at a time as they
//!   arrive from the round engine, holding only the running weighted
//!   sums: O(model size) memory, independent of the fleet size. Folded
//!   in the same order, it is bit-identical to the buffered path.
//! * [`ShardedAggregator`] — the same streaming fold partitioned *per
//!   tensor* across worker threads. Each shard owns a disjoint subset
//!   of the global tensors with its own `(acc, wsum)` pair, folds the
//!   stream of updates in arrival order, and the shards merge into the
//!   global in deterministic shard-index order at `finish` — so the
//!   result is bit-identical to the single-thread fold at every shard
//!   count (element sums never cross a shard boundary). This is the
//!   10⁵-device path: at large cohorts the fold itself saturates one
//!   coordinator core, and sharding splits it ~evenly by element
//!   count.
//!
//! On top of these, [`EdgeAggregator`] arranges `E` sharded folds as an
//! edge tier — each edge owns a contiguous slice of the cohort's update
//! stream — with a root merge in ascending edge-index order.
//!
//! All paths share [`fold_tensor`], the per-tensor inner loop, so the
//! eq. 17 arithmetic literally cannot drift between them. The running
//! sums accumulate in **64.60 fixed point** (`i128`, scale 2⁶⁰): each
//! contribution is quantized once, and from there on every fold is an
//! integer add — exactly associative — so *any* partition of the update
//! stream (shards by tensor, edges by device) merges back to the same
//! bits as the flat fold. Quantization error is ~2⁻⁶⁰ relative, far
//! below the f32 output precision.

use std::borrow::Cow;
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use crate::model::masks::LoraConfig;
use crate::model::state::TensorMap;

use super::layout::{self, classify, Pattern};

/// One device's returned update + the configuration it trained under.
#[derive(Debug, Clone)]
pub struct DeviceUpdate {
    pub trainable: TensorMap,
    pub config: LoraConfig,
    /// Aggregation weight (1.0 = the paper's uniform 1/n_l; harnesses
    /// may weight by shard size for FedAvg-style averaging).
    pub weight: f64,
}

/// Fixed-point scale of the fold accumulators: 2⁶⁰. Headroom: f32
/// magnitudes (< 3.4e38 but in practice O(1)) times 10⁵-device cohorts
/// stay far inside i128's ±1.7e38 range at this scale.
const FP_SCALE: f64 = (1u64 << 60) as f64;

/// Quantize one f64 contribution to 64.60 fixed point. `as i128`
/// saturates and maps NaN → 0, both deterministically.
#[inline]
fn quantize(v: f64) -> i128 {
    (v * FP_SCALE).round() as i128
}

/// Fold one device's tensor `x` (under `mask`, scaled by `w`) into the
/// running per-element sums. The single source of eq. 17 arithmetic
/// shared by the buffered, streaming, sharded, and edge aggregators.
/// Each contribution is quantized once; the accumulation itself is
/// integer, so it is exactly associative across any stream partition.
fn fold_tensor(pat: Pattern, n_layers: usize, x: &[f32], mask: &[f32],
               w: f64, acc: &mut [i128], wsum: &mut [i128]) {
    match pat {
        Pattern::Full => {
            let qw = quantize(w);
            for (e, &v) in x.iter().enumerate() {
                acc[e] += quantize(w * v as f64);
                wsum[e] += qw;
            }
        }
        Pattern::Rows { r, inner } => {
            for l in 0..n_layers {
                for j in 0..r {
                    let m = mask[l * r + j] as f64 * w;
                    if m == 0.0 {
                        continue;
                    }
                    let qm = quantize(m);
                    let off = (l * r + j) * inner;
                    for e in off..off + inner {
                        acc[e] += quantize(m * x[e] as f64);
                        wsum[e] += qm;
                    }
                }
            }
        }
        Pattern::Cols { r, inner } => {
            for l in 0..n_layers {
                for j in 0..r {
                    let m = mask[l * r + j] as f64 * w;
                    if m == 0.0 {
                        continue;
                    }
                    let qm = quantize(m);
                    let base = l * inner * r + j;
                    for i in 0..inner {
                        let e = base + i * r;
                        acc[e] += quantize(m * x[e] as f64);
                        wsum[e] += qm;
                    }
                }
            }
        }
    }
}

/// Bring one update tensor to the global element count `n`,
/// zero-padding smaller-rank storage through the single padding rule
/// ([`layout::pad_to_rank`]) — how every fold path accepts an update a
/// device trimmed to its own max rank (`serialize::trim_to_rank`).
/// Full-size tensors borrow without copying; a size that cannot pad to
/// exactly `n` is shape drift and panics like a missing tensor would.
fn at_full_rank<'a>(pat: Pattern, n_layers: usize, x: &'a [f32],
                    n: usize, name: &str) -> Cow<'a, [f32]> {
    if x.len() == n {
        return Cow::Borrowed(x);
    }
    match layout::pad_to_rank(pat, n_layers, x.to_vec()) {
        Some(p) if p.len() == n => Cow::Owned(p),
        _ => panic!("shape drift in {name}: {} elems cannot pad to {n}",
                    x.len()),
    }
}

/// Aggregate `updates` into `global` in place.
///
/// `rank_dim` is r_max for the lora family / w_max for adapters.
pub fn aggregate(global: &mut TensorMap, updates: &[DeviceUpdate],
                 n_layers: usize, rank_dim: usize) {
    if updates.is_empty() {
        return;
    }
    // Precompute each device's [L*rank_dim] slot mask.
    let slot_masks: Vec<Vec<f32>> = updates
        .iter()
        .map(|u| u.config.rank_mask(n_layers, rank_dim))
        .collect();

    for ti in 0..global.entries.len() {
        let (spec, g) = &mut global.entries[ti];
        let pat = classify(spec, n_layers, rank_dim);
        let n = g.len();
        let mut acc = vec![0i128; n];
        let mut wsum = vec![0i128; n];

        for (u, mask) in updates.iter().zip(&slot_masks) {
            let x = u
                .trainable
                .get(&spec.name)
                .expect("device update missing tensor");
            let x = at_full_rank(pat, n_layers, x, n, &spec.name);
            fold_tensor(pat, n_layers, &x, mask, u.weight, &mut acc,
                        &mut wsum);
        }

        for e in 0..n {
            if wsum[e] > 0 {
                g[e] = (acc[e] as f64 / wsum[e] as f64) as f32;
            } // else: keep previous global value (n_l = 0 this round)
        }
    }
}

/// The raw eq. 17 running sums of one fold, detached from the
/// aggregator that produced them. Because the sums are fixed-point
/// integers, [`FoldSums::absorb`] is exactly associative: partial folds
/// over disjoint subsets of the update stream merge back to the same
/// bits as the flat fold under any grouping — the property the edge
/// tier's root merge rests on.
#[derive(Debug, Clone)]
pub struct FoldSums {
    /// Per global tensor (in `TensorMap::entries` order): per-element
    /// weighted value / weight sums at scale 2⁶⁰.
    acc: Vec<Vec<i128>>,
    wsum: Vec<Vec<i128>>,
    n_updates: usize,
}

impl FoldSums {
    pub fn n_updates(&self) -> usize {
        self.n_updates
    }

    /// Merge another partial fold into this one (integer adds — order
    /// and grouping cannot change the result).
    pub fn absorb(&mut self, other: FoldSums) {
        debug_assert_eq!(self.acc.len(), other.acc.len(),
                         "fold layout drift");
        for (a, o) in self.acc.iter_mut().zip(other.acc) {
            for (x, y) in a.iter_mut().zip(o) {
                *x += y;
            }
        }
        for (a, o) in self.wsum.iter_mut().zip(other.wsum) {
            for (x, y) in a.iter_mut().zip(o) {
                *x += y;
            }
        }
        self.n_updates += other.n_updates;
    }

    /// Write the layer-wise averages into `global`. Slots no device
    /// held keep their previous global value; with zero updates this is
    /// a no-op (matches [`aggregate`] on `&[]`).
    pub fn write(&self, global: &mut TensorMap) {
        if self.n_updates == 0 {
            return;
        }
        for (ti, (_, g)) in global.entries.iter_mut().enumerate() {
            let (acc, wsum) = (&self.acc[ti], &self.wsum[ti]);
            for e in 0..g.len() {
                if wsum[e] > 0 {
                    g[e] = (acc[e] as f64 / wsum[e] as f64) as f32;
                }
            }
        }
    }
}

/// Streaming eq. 17: fold updates into running per-element weighted
/// sums as they arrive, then write the averages back once per round.
///
/// ```text
/// let mut agg = StreamingAggregator::new(&global, l, r);
/// for each arriving update { agg.push(&update.trainable, &cfg, w); }
/// agg.finish(&mut global);
/// ```
#[derive(Debug, Clone)]
pub struct StreamingAggregator {
    n_layers: usize,
    rank_dim: usize,
    /// Per global tensor: (name, pattern, element count).
    layout: Vec<(String, Pattern, usize)>,
    acc: Vec<Vec<i128>>,
    wsum: Vec<Vec<i128>>,
    n_updates: usize,
    /// Minimum acceptable model version for [`Self::push_versioned`]
    /// (the async engine's staleness cutoff); 0 accepts everything.
    watermark: usize,
}

impl StreamingAggregator {
    /// Capture the global model's tensor layout; no data is copied.
    pub fn new(global: &TensorMap, n_layers: usize, rank_dim: usize)
               -> Self {
        let layout: Vec<(String, Pattern, usize)> = global
            .entries
            .iter()
            .map(|(spec, g)| {
                (
                    spec.name.clone(),
                    classify(spec, n_layers, rank_dim),
                    g.len(),
                )
            })
            .collect();
        let acc = layout.iter().map(|&(_, _, n)| vec![0i128; n]).collect();
        let wsum =
            layout.iter().map(|&(_, _, n)| vec![0i128; n]).collect();
        StreamingAggregator {
            n_layers,
            rank_dim,
            layout,
            acc,
            wsum,
            n_updates: 0,
            watermark: 0,
        }
    }

    /// Set the version watermark: subsequent [`Self::push_versioned`]
    /// calls whose `version` is below `v` are rejected. The async
    /// engine sets this to `current_version − max_staleness` each
    /// commit window, so an update trained on a model older than the
    /// staleness cutoff can never fold.
    pub fn set_watermark(&mut self, v: usize) {
        self.watermark = v;
    }

    /// Weighted fold gated by the version watermark: folds the update
    /// (exactly like [`Self::push`]) and returns `true`, or — when
    /// `version` is below the watermark — folds nothing and returns
    /// `false`.
    pub fn push_versioned(&mut self, trainable: &TensorMap,
                          config: &LoraConfig, weight: f64,
                          version: usize) -> bool {
        if version < self.watermark {
            return false;
        }
        self.push(trainable, config, weight);
        true
    }

    /// Fold one device's update into the running sums (O(model size);
    /// the update can be dropped immediately afterwards).
    pub fn push(&mut self, trainable: &TensorMap, config: &LoraConfig,
                weight: f64) {
        let mask = config.rank_mask(self.n_layers, self.rank_dim);
        for (ti, (name, pat, n)) in self.layout.iter().enumerate() {
            let x = trainable
                .get(name)
                .expect("device update missing tensor");
            let x = at_full_rank(*pat, self.n_layers, x, *n, name);
            fold_tensor(*pat, self.n_layers, &x, &mask, weight,
                        &mut self.acc[ti], &mut self.wsum[ti]);
        }
        self.n_updates += 1;
    }

    /// Number of updates folded so far.
    pub fn n_updates(&self) -> usize {
        self.n_updates
    }

    /// Write the layer-wise averages into `global`. Slots no device
    /// held this round keep their previous global value; with zero
    /// updates this is a no-op (matches [`aggregate`] on `&[]`).
    pub fn finish(self, global: &mut TensorMap) {
        self.into_sums().write(global);
    }

    /// Detach the running sums (the streaming path's contribution to a
    /// hierarchical merge).
    pub fn into_sums(self) -> FoldSums {
        FoldSums {
            acc: self.acc,
            wsum: self.wsum,
            n_updates: self.n_updates,
        }
    }
}

/// One fold job broadcast to every shard: the device's full update,
/// its precomputed `[L·rank_dim]` slot mask, and the aggregation
/// weight. Shards read disjoint tensors out of the shared map, so a
/// single `Arc` serves all of them and the update's memory is freed as
/// soon as the last shard has folded it.
type FoldMsg = Arc<(TensorMap, Vec<f32>, f64)>;

/// One shard's owned state: a disjoint subset of the global tensors
/// (by index into `global.entries`) plus their running sums.
struct ShardState {
    n_layers: usize,
    /// (global tensor index, name, pattern, element count).
    tensors: Vec<(usize, String, Pattern, usize)>,
    acc: Vec<Vec<i128>>,
    wsum: Vec<Vec<i128>>,
}

fn shard_worker(mut st: ShardState, rx: mpsc::Receiver<FoldMsg>)
                -> ShardState {
    while let Ok(msg) = rx.recv() {
        let (trainable, mask, weight) = &*msg;
        for (k, (_, name, pat, n)) in st.tensors.iter().enumerate() {
            let x = trainable
                .get(name)
                .expect("device update missing tensor");
            debug_assert_eq!(x.len(), *n, "shape drift in {name}");
            fold_tensor(*pat, st.n_layers, x, mask, *weight,
                        &mut st.acc[k], &mut st.wsum[k]);
        }
    }
    st
}

/// Deterministic tensor→shard assignment: walk tensors in index order,
/// placing each on the currently-lightest shard by element count (ties
/// break toward the lowest shard index). Purely a function of the
/// layout, never of timing.
fn shard_layout(sizes: &[usize], shards: usize) -> Vec<usize> {
    let shards = shards.max(1);
    let mut load = vec![0usize; shards];
    sizes
        .iter()
        .map(|&n| {
            let s = load
                .iter()
                .enumerate()
                .min_by_key(|&(_, &l)| l)
                .map(|(s, _)| s)
                .unwrap_or(0);
            load[s] += n;
            s
        })
        .collect()
}

enum ShardMode {
    /// `shards <= 1`: fold inline on the caller's thread — exactly the
    /// [`StreamingAggregator`] path, no channels, no copies.
    Inline(StreamingAggregator),
    Workers {
        txs: Vec<mpsc::SyncSender<FoldMsg>>,
        handles: Vec<JoinHandle<ShardState>>,
    },
}

/// Eq. 17 streaming fold sharded per tensor across worker threads.
///
/// Bit-identity: every model element belongs to exactly one shard, and
/// each shard folds the update stream in the order [`Self::push`] was
/// called — so each element's `(acc, wsum)` accumulates in exactly the
/// same sequence as the single-thread [`StreamingAggregator`], and
/// [`Self::finish`] writes shards back in shard-index order. Same
/// pushes ⇒ bit-identical global at every shard count.
///
/// Memory: the fold channels are bounded (`queue_cap` updates per
/// shard), so a slow shard back-pressures [`Self::push`] instead of
/// queueing the cohort; in-flight updates stay O(queue_cap), not
/// O(cohort).
pub struct ShardedAggregator {
    n_layers: usize,
    rank_dim: usize,
    /// Global tensor layout: (name, pattern, element count). Worker
    /// mode pads trimmed-rank updates against this ONCE per push, so
    /// the shards share a single full-size copy behind the `Arc`.
    layout: Vec<(String, Pattern, usize)>,
    mode: ShardMode,
    n_updates: usize,
    /// Minimum acceptable model version for [`Self::push_versioned`].
    watermark: usize,
}

impl ShardedAggregator {
    /// `shards`: 0 = one per available core, 1 = inline single-thread
    /// fold; capped at the number of global tensors (a shard without
    /// tensors would idle).
    pub fn new(global: &TensorMap, n_layers: usize, rank_dim: usize,
               shards: usize, queue_cap: usize) -> Self {
        let want = if shards == 0 {
            super::engine::effective_threads(0)
        } else {
            shards
        };
        let shards = want.min(global.entries.len().max(1));
        let layout: Vec<(String, Pattern, usize)> = global
            .entries
            .iter()
            .map(|(spec, g)| {
                (
                    spec.name.clone(),
                    classify(spec, n_layers, rank_dim),
                    g.len(),
                )
            })
            .collect();
        if shards <= 1 {
            return ShardedAggregator {
                n_layers,
                rank_dim,
                layout,
                mode: ShardMode::Inline(StreamingAggregator::new(
                    global, n_layers, rank_dim,
                )),
                n_updates: 0,
                watermark: 0,
            };
        }

        let sizes: Vec<usize> =
            global.entries.iter().map(|(_, g)| g.len()).collect();
        let owner = shard_layout(&sizes, shards);
        let mut txs = Vec::with_capacity(shards);
        let mut handles = Vec::with_capacity(shards);
        for s in 0..shards {
            let tensors: Vec<(usize, String, Pattern, usize)> = global
                .entries
                .iter()
                .enumerate()
                .filter(|&(ti, _)| owner[ti] == s)
                .map(|(ti, (spec, g))| {
                    (
                        ti,
                        spec.name.clone(),
                        classify(spec, n_layers, rank_dim),
                        g.len(),
                    )
                })
                .collect();
            let st = ShardState {
                n_layers,
                acc: tensors
                    .iter()
                    .map(|&(_, _, _, n)| vec![0i128; n])
                    .collect(),
                wsum: tensors
                    .iter()
                    .map(|&(_, _, _, n)| vec![0i128; n])
                    .collect(),
                tensors,
            };
            let (tx, rx) = mpsc::sync_channel::<FoldMsg>(queue_cap.max(1));
            txs.push(tx);
            handles.push(std::thread::spawn(move || shard_worker(st, rx)));
        }
        ShardedAggregator {
            n_layers,
            rank_dim,
            layout,
            mode: ShardMode::Workers { txs, handles },
            n_updates: 0,
            watermark: 0,
        }
    }

    /// Set the version watermark (see
    /// [`StreamingAggregator::set_watermark`]).
    pub fn set_watermark(&mut self, v: usize) {
        self.watermark = v;
    }

    /// Weighted fold gated by the version watermark: folds the update
    /// and returns `Ok(true)`, or — when `version` is below the
    /// watermark — folds nothing and returns `Ok(false)`.
    pub fn push_versioned(&mut self, trainable: TensorMap,
                          config: &LoraConfig, weight: f64,
                          version: usize) -> Result<bool> {
        if version < self.watermark {
            return Ok(false);
        }
        self.push(trainable, config, weight)?;
        Ok(true)
    }

    /// Fold one device's update. Takes the map by value: in sharded
    /// mode it is handed to the workers behind one `Arc` and freed as
    /// soon as the last shard is done with it.
    pub fn push(&mut self, trainable: TensorMap, config: &LoraConfig,
                weight: f64) -> Result<()> {
        match &mut self.mode {
            ShardMode::Inline(agg) => {
                agg.push(&trainable, config, weight);
            }
            ShardMode::Workers { txs, .. } => {
                let mask = config.rank_mask(self.n_layers, self.rank_dim);
                // Pad trimmed-rank tensors once, before the broadcast:
                // every shard then reads the same full-size copy.
                let mut trainable = trainable;
                for (name, pat, n) in &self.layout {
                    let Some(v) = trainable.get_mut(name) else {
                        continue; // missing tensor: the worker panics
                    };
                    if v.len() != *n {
                        let x = std::mem::take(v);
                        *v = at_full_rank(*pat, self.n_layers, &x, *n,
                                          name)
                            .into_owned();
                    }
                }
                let msg: FoldMsg = Arc::new((trainable, mask, weight));
                for tx in txs.iter() {
                    tx.send(msg.clone()).map_err(|_| {
                        anyhow!("aggregation shard exited early")
                    })?;
                }
            }
        }
        self.n_updates += 1;
        Ok(())
    }

    /// Number of updates folded so far.
    pub fn n_updates(&self) -> usize {
        self.n_updates
    }

    /// Merge the shards into `global` in shard-index order. With zero
    /// updates this is a no-op (matches [`StreamingAggregator`]).
    pub fn finish(self, global: &mut TensorMap) -> Result<()> {
        self.into_sums()?.write(global);
        Ok(())
    }

    /// Join the workers (if any) and reassemble their disjoint tensor
    /// subsets into dense [`FoldSums`] in global tensor order.
    pub fn into_sums(self) -> Result<FoldSums> {
        match self.mode {
            ShardMode::Inline(agg) => Ok(agg.into_sums()),
            ShardMode::Workers { txs, handles } => {
                drop(txs); // close the channels: workers drain and exit
                let mut states = Vec::with_capacity(handles.len());
                for h in handles {
                    states.push(h.join().map_err(|_| {
                        anyhow!("aggregation shard panicked")
                    })?);
                }
                let n_tensors = self.layout.len();
                let mut acc: Vec<Vec<i128>> = vec![Vec::new(); n_tensors];
                let mut wsum: Vec<Vec<i128>> = vec![Vec::new(); n_tensors];
                for mut st in states {
                    for (k, &(ti, ..)) in st.tensors.iter().enumerate() {
                        acc[ti] = std::mem::take(&mut st.acc[k]);
                        wsum[ti] = std::mem::take(&mut st.wsum[k]);
                    }
                }
                Ok(FoldSums { acc, wsum, n_updates: self.n_updates })
            }
        }
    }
}

/// Hierarchical eq. 17 fold — the edge-aggregation tier. The expected
/// update stream (`n_expected` pushes) is partitioned into `n_edges`
/// contiguous, deterministic slices; each edge folds its slice with its
/// own [`ShardedAggregator`] (so edge folds and their shard workers run
/// concurrently), and [`Self::finish`] merges the edge partials into
/// the root in ascending edge-index order. Fixed-point accumulation
/// makes the merged result bit-identical to the flat fold at every edge
/// count.
pub struct EdgeAggregator {
    edges: Vec<ShardedAggregator>,
    /// Slice bounds: accepted push `k` routes to the edge `e` with
    /// `bounds[e] <= k < bounds[e+1]` (len = edges + 1).
    bounds: Vec<usize>,
    n_pushed: usize,
    n_updates: usize,
    /// Minimum acceptable model version for [`Self::push_versioned`].
    /// Gated here — a rejected update must not consume a slice slot.
    watermark: usize,
}

impl EdgeAggregator {
    /// `n_edges` is clamped to `[1, n_expected]` (an edge with no slice
    /// would idle); `shards`/`queue_cap` configure each edge's inner
    /// sharded fold exactly as in [`ShardedAggregator::new`].
    pub fn new(global: &TensorMap, n_layers: usize, rank_dim: usize,
               n_edges: usize, shards: usize, queue_cap: usize,
               n_expected: usize) -> Self {
        let e = n_edges.max(1).min(n_expected.max(1));
        let edges: Vec<ShardedAggregator> = (0..e)
            .map(|_| {
                ShardedAggregator::new(global, n_layers, rank_dim, shards,
                                       queue_cap)
            })
            .collect();
        let bounds: Vec<usize> =
            (0..=e).map(|k| n_expected * k / e).collect();
        EdgeAggregator {
            edges,
            bounds,
            n_pushed: 0,
            n_updates: 0,
            watermark: 0,
        }
    }

    /// Set the version watermark (see
    /// [`StreamingAggregator::set_watermark`]).
    pub fn set_watermark(&mut self, v: usize) {
        self.watermark = v;
    }

    /// Edge owning the next accepted push. Pushes beyond `n_expected`
    /// (possible only if the caller under-estimated) land on the last
    /// edge.
    fn route(&self) -> usize {
        let k = self.n_pushed;
        let e = self.bounds[1..].partition_point(|&b| b <= k);
        e.min(self.edges.len() - 1)
    }

    /// Fold one device's update into its slice's edge.
    pub fn push(&mut self, trainable: TensorMap, config: &LoraConfig,
                weight: f64) -> Result<()> {
        let e = self.route();
        self.edges[e].push(trainable, config, weight)?;
        self.n_pushed += 1;
        self.n_updates += 1;
        Ok(())
    }

    /// Weighted fold gated by the version watermark: folds the update
    /// and returns `Ok(true)`, or — when `version` is below the
    /// watermark — folds nothing (and advances no slice slot) and
    /// returns `Ok(false)`.
    pub fn push_versioned(&mut self, trainable: TensorMap,
                          config: &LoraConfig, weight: f64,
                          version: usize) -> Result<bool> {
        if version < self.watermark {
            return Ok(false);
        }
        self.push(trainable, config, weight)?;
        Ok(true)
    }

    /// Number of updates folded so far.
    pub fn n_updates(&self) -> usize {
        self.n_updates
    }

    /// Root merge: absorb the edge partials in ascending edge-index
    /// order, then write the averages into `global`. With zero updates
    /// this is a no-op.
    pub fn finish(self, global: &mut TensorMap) -> Result<()> {
        let mut it = self.edges.into_iter();
        let mut root = match it.next() {
            Some(edge) => edge.into_sums()?,
            None => return Ok(()),
        };
        for edge in it {
            root.absorb(edge.into_sums()?);
        }
        root.write(global);
        Ok(())
    }
}

/// Number of devices contributing to each layer (n_l diagnostics).
pub fn contributors_per_layer(updates: &[DeviceUpdate], n_layers: usize)
                              -> Vec<usize> {
    let mut n = vec![0usize; n_layers];
    for u in updates {
        for l in u.config.layers.indices(n_layers) {
            n[l] += 1;
        }
    }
    n
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::masks::LayerSet;
    use crate::model::TensorSpec;

    const L: usize = 4;
    const R: usize = 3;
    const D: usize = 2;

    fn specs() -> Vec<TensorSpec> {
        vec![
            TensorSpec { name: "aq".into(), shape: vec![L, R, D] },
            TensorSpec { name: "bq".into(), shape: vec![L, D, R] },
            TensorSpec { name: "head_w".into(), shape: vec![D, 2] },
        ]
    }

    fn filled(val: f32) -> TensorMap {
        let mut t = TensorMap::zeros(&specs());
        for (_, v) in &mut t.entries {
            v.iter_mut().for_each(|x| *x = val);
        }
        t
    }

    fn update(val: f32, depth: usize, ranks: Vec<usize>) -> DeviceUpdate {
        DeviceUpdate {
            trainable: filled(val),
            config: LoraConfig { layers: LayerSet::Depth(depth), ranks },
            weight: 1.0,
        }
    }

    #[test]
    fn uniform_depth_is_plain_average() {
        let mut g = filled(0.0);
        let ups = vec![
            update(1.0, L, vec![R; L]),
            update(3.0, L, vec![R; L]),
        ];
        aggregate(&mut g, &ups, L, R);
        for (_, v) in &g.entries {
            assert!(v.iter().all(|&x| (x - 2.0).abs() < 1e-6));
        }
    }

    #[test]
    fn layerwise_counts_only_contributors() {
        let mut g = filled(-1.0);
        // Device A trains all 4 layers, device B only the deepest 1.
        let ups = vec![
            update(2.0, L, vec![R; L]),
            update(4.0, 1, vec![R; L]),
        ];
        aggregate(&mut g, &ups, L, R);
        let aq = g.get("aq").unwrap();
        // Layers 0..3 (shallow): only A → 2.0.
        assert!(aq[..3 * R * D].iter().all(|&x| (x - 2.0).abs() < 1e-6));
        // Layer 3 (deepest): (2+4)/2 = 3.0.
        assert!(aq[3 * R * D..].iter().all(|&x| (x - 3.0).abs() < 1e-6));
        // Head: all devices → 3.0.
        assert!(g
            .get("head_w")
            .unwrap()
            .iter()
            .all(|&x| (x - 3.0).abs() < 1e-6));
    }

    #[test]
    fn hetlora_rank_mismatch_aggregates_per_slot() {
        let mut g = filled(0.0);
        // A has rank 3 everywhere, B rank 1 everywhere (zero-padded).
        let ups = vec![
            update(2.0, L, vec![3; L]),
            update(6.0, L, vec![1; L]),
        ];
        aggregate(&mut g, &ups, L, R);
        let aq = g.get("aq").unwrap();
        // slot 0: both → 4.0; slots 1,2: only A → 2.0.
        for l in 0..L {
            let base = l * R * D;
            assert!((aq[base] - 4.0).abs() < 1e-6);
            assert!((aq[base + D] - 2.0).abs() < 1e-6);
            assert!((aq[base + 2 * D] - 2.0).abs() < 1e-6);
        }
        // Cols layout too (bq: [L, D, R]).
        let bq = g.get("bq").unwrap();
        for l in 0..L {
            for i in 0..D {
                let base = l * D * R + i * R;
                assert!((bq[base] - 4.0).abs() < 1e-6);
                assert!((bq[base + 1] - 2.0).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn untouched_slots_keep_previous_global() {
        let mut g = filled(9.0);
        let ups = vec![update(1.0, 1, vec![R; L])]; // only deepest layer
        aggregate(&mut g, &ups, L, R);
        let aq = g.get("aq").unwrap();
        assert!(aq[..3 * R * D].iter().all(|&x| x == 9.0));
        assert!(aq[3 * R * D..].iter().all(|&x| (x - 1.0).abs() < 1e-6));
    }

    #[test]
    fn weights_scale_contributions() {
        let mut g = filled(0.0);
        let mut a = update(1.0, L, vec![R; L]);
        a.weight = 3.0;
        let b = update(5.0, L, vec![R; L]);
        aggregate(&mut g, &[a, b], L, R);
        // (3·1 + 1·5)/4 = 2.0
        assert!(g
            .get("aq")
            .unwrap()
            .iter()
            .all(|&x| (x - 2.0).abs() < 1e-6));
    }

    #[test]
    fn contributor_counts() {
        let ups = vec![
            update(0.0, L, vec![R; L]),
            update(0.0, 2, vec![R; L]),
            update(0.0, 1, vec![R; L]),
        ];
        assert_eq!(contributors_per_layer(&ups, L), vec![1, 1, 2, 3]);
    }

    #[test]
    fn empty_update_set_is_noop() {
        let mut g = filled(5.0);
        aggregate(&mut g, &[], L, R);
        assert!(g.get("aq").unwrap().iter().all(|&x| x == 5.0));
    }

    #[test]
    fn streaming_matches_buffered_bitwise() {
        let ups = vec![
            update(2.0, L, vec![3; L]),
            update(6.0, 1, vec![1; L]),
            update(-1.5, 2, vec![2; L]),
        ];
        let mut buffered = filled(9.0);
        aggregate(&mut buffered, &ups, L, R);

        let mut streamed = filled(9.0);
        let mut agg = StreamingAggregator::new(&streamed, L, R);
        for u in &ups {
            agg.push(&u.trainable, &u.config, u.weight);
        }
        assert_eq!(agg.n_updates(), 3);
        agg.finish(&mut streamed);
        assert_eq!(buffered, streamed, "streaming must be bit-identical");
    }

    #[test]
    fn streaming_empty_is_noop() {
        let mut g = filled(5.0);
        StreamingAggregator::new(&g, L, R).finish(&mut g);
        assert!(g.get("aq").unwrap().iter().all(|&x| x == 5.0));
    }

    // `classify`'s unit tests (square-tensor disambiguation included)
    // moved to `coordinator/layout.rs` with the classifier itself.

    #[test]
    fn square_b_tensor_aggregates_along_last_axis() {
        // End-to-end regression for the square case: a rank-1 device
        // must touch slot 0 of every row of a square bq, i.e. elements
        // e with e % R == 0 — the Cols layout — not the first R
        // elements of each layer (the Rows layout).
        let specs = vec![TensorSpec {
            name: "bq".into(),
            shape: vec![L, R, R],
        }];
        let mut g = TensorMap::zeros(&specs);
        let mut t = TensorMap::zeros(&specs);
        for (_, v) in &mut t.entries {
            v.iter_mut().for_each(|x| *x = 7.0);
        }
        let ups = vec![DeviceUpdate {
            trainable: t,
            config: LoraConfig {
                layers: LayerSet::Depth(L),
                ranks: vec![1; L],
            },
            weight: 1.0,
        }];
        aggregate(&mut g, &ups, L, R);
        let bq = g.get("bq").unwrap();
        for (e, &v) in bq.iter().enumerate() {
            let want = if e % R == 0 { 7.0 } else { 0.0 };
            assert_eq!(v, want, "bq[{e}]");
        }
    }

    #[test]
    fn shard_layout_is_deterministic_and_balanced() {
        let sizes = [100, 1, 100, 1, 50, 50];
        let owner = shard_layout(&sizes, 2);
        assert_eq!(owner.len(), sizes.len());
        assert_eq!(owner, shard_layout(&sizes, 2), "deterministic");
        let load: Vec<usize> = (0..2)
            .map(|s| {
                sizes
                    .iter()
                    .zip(&owner)
                    .filter(|&(_, &o)| o == s)
                    .map(|(n, _)| n)
                    .sum()
            })
            .collect();
        assert!(load[0] > 0 && load[1] > 0, "both shards used: {load:?}");
        // One shard per tensor degenerates to the identity-ish case.
        assert_eq!(shard_layout(&[5], 4), vec![0]);
    }

    #[test]
    fn sharded_matches_streaming_bitwise() {
        let ups = vec![
            update(2.0, L, vec![3; L]),
            update(6.0, 1, vec![1; L]),
            update(-1.5, 2, vec![2; L]),
        ];
        let mut streamed = filled(9.0);
        let mut agg = StreamingAggregator::new(&streamed, L, R);
        for u in &ups {
            agg.push(&u.trainable, &u.config, u.weight);
        }
        agg.finish(&mut streamed);

        for shards in [1usize, 2, 3, 8] {
            let mut sharded = filled(9.0);
            let mut agg =
                ShardedAggregator::new(&sharded, L, R, shards, 4);
            for u in &ups {
                agg.push(u.trainable.clone(), &u.config, u.weight)
                    .unwrap();
            }
            assert_eq!(agg.n_updates(), 3);
            agg.finish(&mut sharded).unwrap();
            assert_eq!(streamed, sharded,
                       "{shards} shards must be bit-identical");
        }
    }

    #[test]
    fn watermark_gates_streaming_folds() {
        let ups = vec![
            update(2.0, L, vec![R; L]),
            update(6.0, L, vec![R; L]),
        ];
        // Reference: only the fresh update folds.
        let mut want = filled(0.0);
        let mut agg = StreamingAggregator::new(&want, L, R);
        agg.push(&ups[1].trainable, &ups[1].config, 1.0);
        agg.finish(&mut want);

        let mut got = filled(0.0);
        let mut agg = StreamingAggregator::new(&got, L, R);
        agg.set_watermark(5);
        // version 4 < watermark 5: rejected, nothing folds.
        assert!(!agg.push_versioned(&ups[0].trainable, &ups[0].config,
                                    1.0, 4));
        assert_eq!(agg.n_updates(), 0);
        // version == watermark: accepted.
        assert!(agg.push_versioned(&ups[1].trainable, &ups[1].config,
                                   1.0, 5));
        assert_eq!(agg.n_updates(), 1);
        agg.finish(&mut got);
        assert_eq!(got, want, "rejected update must leave no trace");
    }

    #[test]
    fn watermark_gates_sharded_folds() {
        for shards in [1usize, 3] {
            let ups = vec![
                update(2.0, L, vec![R; L]),
                update(6.0, L, vec![R; L]),
            ];
            let mut want = filled(0.0);
            let mut agg = ShardedAggregator::new(&want, L, R, shards, 2);
            agg.push(ups[1].trainable.clone(), &ups[1].config, 1.0)
                .unwrap();
            agg.finish(&mut want).unwrap();

            let mut got = filled(0.0);
            let mut agg = ShardedAggregator::new(&got, L, R, shards, 2);
            agg.set_watermark(3);
            assert!(!agg
                .push_versioned(ups[0].trainable.clone(), &ups[0].config,
                                1.0, 2)
                .unwrap());
            assert_eq!(agg.n_updates(), 0);
            assert!(agg
                .push_versioned(ups[1].trainable.clone(), &ups[1].config,
                                1.0, 7)
                .unwrap());
            assert_eq!(agg.n_updates(), 1);
            agg.finish(&mut got).unwrap();
            assert_eq!(got, want, "{shards} shards: stale fold leaked");
        }
    }

    #[test]
    fn sharded_empty_is_noop() {
        for shards in [1usize, 3] {
            let mut g = filled(5.0);
            ShardedAggregator::new(&g, L, R, shards, 2)
                .finish(&mut g)
                .unwrap();
            assert!(g.get("aq").unwrap().iter().all(|&x| x == 5.0));
        }
    }

    fn mixed_updates() -> Vec<DeviceUpdate> {
        vec![
            update(2.0, L, vec![3; L]),
            update(6.0, 1, vec![1; L]),
            update(-1.5, 2, vec![2; L]),
            update(0.25, 3, vec![3; L]),
            update(4.0, L, vec![2; L]),
        ]
    }

    #[test]
    fn trimmed_rank_updates_fold_identically_on_every_path() {
        // Heterogeneous-rank folding: devices store their updates at
        // their own max rank (serialize::trim_to_rank), every
        // aggregator pads them back through layout::pad_to_rank, and
        // the result is bit-identical to folding the full-rank
        // originals — buffered, streaming, sharded, and the edge tier.
        use super::super::serialize::trim_to_rank;
        let ups = mixed_updates();
        let trimmed: Vec<DeviceUpdate> = ups
            .iter()
            .map(|u| DeviceUpdate {
                trainable: trim_to_rank(&u.trainable, &u.config, L, R),
                config: u.config.clone(),
                weight: u.weight,
            })
            .collect();
        assert!(
            trimmed
                .iter()
                .zip(&ups)
                .any(|(t, u)| t.trainable.numel() < u.trainable.numel()),
            "fixture must exercise a real rank mismatch"
        );

        let mut want = filled(9.0);
        aggregate(&mut want, &ups, L, R);

        let mut buffered = filled(9.0);
        aggregate(&mut buffered, &trimmed, L, R);
        assert_eq!(buffered, want, "buffered fold drifted");

        let mut streamed = filled(9.0);
        let mut agg = StreamingAggregator::new(&streamed, L, R);
        for u in &trimmed {
            agg.push(&u.trainable, &u.config, u.weight);
        }
        agg.finish(&mut streamed);
        assert_eq!(streamed, want, "streaming fold drifted");

        for (edges, shards) in [(1usize, 2usize), (2, 1), (3, 2)] {
            let mut tiered = filled(9.0);
            let mut agg = EdgeAggregator::new(&tiered, L, R, edges,
                                              shards, 4, trimmed.len());
            for u in &trimmed {
                agg.push(u.trainable.clone(), &u.config, u.weight)
                    .unwrap();
            }
            agg.finish(&mut tiered).unwrap();
            assert_eq!(tiered, want,
                       "{edges} edges × {shards} shards drifted on \
                        trimmed ranks");
        }
    }

    #[test]
    fn edge_tier_matches_flat_fold_bitwise() {
        let ups = mixed_updates();
        let mut flat = filled(9.0);
        let mut agg = StreamingAggregator::new(&flat, L, R);
        for u in &ups {
            agg.push(&u.trainable, &u.config, u.weight);
        }
        agg.finish(&mut flat);

        for edges in [1usize, 2, 3, 4, 8] {
            for shards in [1usize, 2] {
                let mut tiered = filled(9.0);
                let mut agg = EdgeAggregator::new(&tiered, L, R, edges,
                                                  shards, 4, ups.len());
                for u in &ups {
                    agg.push(u.trainable.clone(), &u.config, u.weight)
                        .unwrap();
                }
                assert_eq!(agg.n_updates(), ups.len());
                agg.finish(&mut tiered).unwrap();
                assert_eq!(flat, tiered,
                           "{edges} edges × {shards} shards must be \
                            bit-identical to the flat fold");
            }
        }
    }

    #[test]
    fn edge_tier_watermark_gates_before_routing() {
        // A stale (rejected) update must not consume a slice slot: the
        // accepted stream routes exactly as if the stale push never
        // happened, so the result still matches the flat fold of the
        // accepted updates only.
        let ups = mixed_updates();
        let mut want = filled(0.0);
        let mut agg = StreamingAggregator::new(&want, L, R);
        for u in &ups[1..] {
            agg.push(&u.trainable, &u.config, u.weight);
        }
        agg.finish(&mut want);

        let mut got = filled(0.0);
        let mut agg =
            EdgeAggregator::new(&got, L, R, 2, 1, 4, ups.len() - 1);
        agg.set_watermark(5);
        assert!(!agg
            .push_versioned(ups[0].trainable.clone(), &ups[0].config,
                            ups[0].weight, 4)
            .unwrap());
        assert_eq!(agg.n_updates(), 0);
        for u in &ups[1..] {
            assert!(agg
                .push_versioned(u.trainable.clone(), &u.config, u.weight, 5)
                .unwrap());
        }
        agg.finish(&mut got).unwrap();
        assert_eq!(got, want, "stale push must leave routing untouched");
    }

    #[test]
    fn edge_tier_empty_is_noop() {
        for edges in [1usize, 4] {
            let mut g = filled(5.0);
            EdgeAggregator::new(&g, L, R, edges, 2, 2, 0)
                .finish(&mut g)
                .unwrap();
            assert!(g.get("aq").unwrap().iter().all(|&x| x == 5.0));
        }
    }

    #[test]
    fn edge_tier_survives_more_pushes_than_expected() {
        // Under-estimated n_expected: the overflow lands on the last
        // edge and the result still matches the flat fold bitwise.
        let ups = mixed_updates();
        let mut flat = filled(0.0);
        let mut agg = StreamingAggregator::new(&flat, L, R);
        for u in &ups {
            agg.push(&u.trainable, &u.config, u.weight);
        }
        agg.finish(&mut flat);

        let mut tiered = filled(0.0);
        let mut agg = EdgeAggregator::new(&tiered, L, R, 2, 1, 4, 2);
        for u in &ups {
            agg.push(u.trainable.clone(), &u.config, u.weight).unwrap();
        }
        agg.finish(&mut tiered).unwrap();
        assert_eq!(flat, tiered);
    }

    #[test]
    fn fold_sums_absorb_is_exact_across_any_split() {
        // Quantized integer sums: splitting the stream at any point and
        // absorbing the partials reproduces the unsplit sums exactly.
        let ups = mixed_updates();
        let g = filled(0.0);
        let whole = {
            let mut a = StreamingAggregator::new(&g, L, R);
            for u in &ups {
                a.push(&u.trainable, &u.config, u.weight);
            }
            let mut out = filled(0.0);
            a.finish(&mut out);
            out
        };
        for split in 0..=ups.len() {
            let mut left = StreamingAggregator::new(&g, L, R);
            let mut right = StreamingAggregator::new(&g, L, R);
            for u in &ups[..split] {
                left.push(&u.trainable, &u.config, u.weight);
            }
            for u in &ups[split..] {
                right.push(&u.trainable, &u.config, u.weight);
            }
            let mut sums = left.into_sums();
            sums.absorb(right.into_sums());
            assert_eq!(sums.n_updates(), ups.len());
            let mut out = filled(0.0);
            sums.write(&mut out);
            assert_eq!(out, whole, "split at {split} diverged");
        }
    }

    #[test]
    fn quantize_maps_nan_to_zero_and_saturates_infinities() {
        assert_eq!(quantize(f64::NAN), 0);
        assert_eq!(quantize(-f64::NAN), 0);
        assert_eq!(quantize(f64::INFINITY), i128::MAX);
        assert_eq!(quantize(f64::NEG_INFINITY), i128::MIN);
    }

    #[test]
    fn quantize_saturates_exactly_at_the_q60_boundary() {
        // i128::MAX as f64 rounds up to 2^127, so the first input the
        // cast clamps is 2^127 / 2^60 = 2^67.
        let edge = (1u128 << 67) as f64;
        assert_eq!(quantize(edge), i128::MAX);
        assert_eq!(quantize(edge * 4.0), i128::MAX);
        assert_eq!(quantize(-edge), i128::MIN);
        assert_eq!(quantize(-edge * 4.0), i128::MIN);
        // One binade below the boundary is exact, not clamped.
        assert_eq!(quantize((1u128 << 66) as f64), 1i128 << 126);
    }

    #[test]
    fn quantize_rounds_half_away_from_zero() {
        assert_eq!(quantize(0.5 / FP_SCALE), 1);
        assert_eq!(quantize(-0.5 / FP_SCALE), -1);
        assert_eq!(quantize(0.49 / FP_SCALE), 0);
        assert_eq!(quantize(1.0), 1i128 << 60);
    }

    #[test]
    fn quantize_is_sign_symmetric_in_range() {
        for v in [0.0, 1e-12, 0.5, 1.5, 12345.678, 1e18,
                  (1u128 << 66) as f64] {
            assert_eq!(quantize(-v), -quantize(v), "v = {v}");
        }
        // Only at full saturation does the two's-complement
        // asymmetry show: MIN = −MAX − 1.
        assert_eq!(quantize(f64::NEG_INFINITY), -i128::MAX - 1);
    }

    #[test]
    fn nan_contributions_fold_deterministically_to_zero() {
        // A NaN element quantizes to 0, so it acts as "no signal"
        // instead of poisoning the fold, and the result is identical
        // wherever the NaN update sits in the stream.
        let g = filled(0.0);
        let mut bad = update(1.0, L, vec![R; L]);
        for (_, v) in &mut bad.trainable.entries {
            v[0] = f32::NAN;
        }
        let good = update(3.0, L, vec![R; L]);
        let fold = |ups: &[&DeviceUpdate]| {
            let mut a = StreamingAggregator::new(&g, L, R);
            for u in ups {
                a.push(&u.trainable, &u.config, u.weight);
            }
            let mut out = filled(0.0);
            a.finish(&mut out);
            out
        };
        let ab = fold(&[&bad, &good]);
        let ba = fold(&[&good, &bad]);
        assert_eq!(ab, ba);
        for (_, v) in &ab.entries {
            assert!(v.iter().all(|x| x.is_finite()));
        }
    }
}
