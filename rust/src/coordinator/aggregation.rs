//! LoRA aggregation (§4.5, eq. 17) — adaptive layer-wise averaging.
//!
//! Devices return heterogeneous-depth (and, for HetLoRA,
//! heterogeneous-rank) updates. The PS averages each transformer
//! layer's LoRA over exactly the devices holding that layer,
//! `θ_l = (1/n_l) Σ_i θ_{i,l}`; we implement it at rank-slot
//! granularity so HetLoRA's zero-padded mismatched ranks aggregate
//! correctly too. Slots no device holds this round keep their previous
//! global value.
//!
//! Two implementations of the same eq. 17 math:
//! * [`aggregate`] — the buffered one-shot reference over a
//!   `&[DeviceUpdate]` (kept for tests/benches and as the oracle the
//!   property suite compares against);
//! * [`StreamingAggregator`] — folds updates one at a time as they
//!   arrive from the round engine, holding only the running weighted
//!   sums: O(model size) memory, independent of the fleet size. Folded
//!   in the same order, it is bit-identical to the buffered path.

use crate::model::masks::LoraConfig;
use crate::model::state::TensorMap;

/// One device's returned update + the configuration it trained under.
#[derive(Debug, Clone)]
pub struct DeviceUpdate {
    pub trainable: TensorMap,
    pub config: LoraConfig,
    /// Aggregation weight (1.0 = the paper's uniform 1/n_l; harnesses
    /// may weight by shard size for FedAvg-style averaging).
    pub weight: f64,
}

/// How a tensor's elements map to (layer, rank-slot) cells.
#[derive(Debug, Clone, Copy, PartialEq)]
enum Pattern {
    /// `[L, r, inner]` — slot index varies along axis 1.
    Rows { r: usize, inner: usize },
    /// `[L, inner, r]` — slot index varies along axis 2.
    Cols { r: usize, inner: usize },
    /// No (layer, slot) structure: averaged over ALL devices (head).
    Full,
}

fn classify(shape: &[usize], n_layers: usize, rank_dim: usize) -> Pattern {
    match shape {
        [l, a, b] if *l == n_layers && *a == rank_dim => {
            Pattern::Rows { r: rank_dim, inner: *b }
        }
        [l, a, b] if *l == n_layers && *b == rank_dim => {
            Pattern::Cols { r: rank_dim, inner: *a }
        }
        [l, a] if *l == n_layers && *a == rank_dim => {
            Pattern::Rows { r: rank_dim, inner: 1 }
        }
        _ => Pattern::Full,
    }
}

/// Aggregate `updates` into `global` in place.
///
/// `rank_dim` is r_max for the lora family / w_max for adapters.
pub fn aggregate(global: &mut TensorMap, updates: &[DeviceUpdate],
                 n_layers: usize, rank_dim: usize) {
    if updates.is_empty() {
        return;
    }
    // Precompute each device's [L*rank_dim] slot mask.
    let slot_masks: Vec<Vec<f32>> = updates
        .iter()
        .map(|u| u.config.rank_mask(n_layers, rank_dim))
        .collect();

    for ti in 0..global.entries.len() {
        let (spec, g) = &mut global.entries[ti];
        let pat = classify(&spec.shape, n_layers, rank_dim);
        let n = g.len();
        let mut acc = vec![0f64; n];
        let mut wsum = vec![0f64; n];

        for (u, mask) in updates.iter().zip(&slot_masks) {
            let x = u
                .trainable
                .get(&spec.name)
                .expect("device update missing tensor");
            debug_assert_eq!(x.len(), n, "shape drift in {}", spec.name);
            let w = u.weight;
            match pat {
                Pattern::Full => {
                    for (e, &v) in x.iter().enumerate() {
                        acc[e] += w * v as f64;
                        wsum[e] += w;
                    }
                }
                Pattern::Rows { r, inner } => {
                    for l in 0..n_layers {
                        for j in 0..r {
                            let m = mask[l * r + j] as f64 * w;
                            if m == 0.0 {
                                continue;
                            }
                            let off = (l * r + j) * inner;
                            for e in off..off + inner {
                                acc[e] += m * x[e] as f64;
                                wsum[e] += m;
                            }
                        }
                    }
                }
                Pattern::Cols { r, inner } => {
                    for l in 0..n_layers {
                        for j in 0..r {
                            let m = mask[l * r + j] as f64 * w;
                            if m == 0.0 {
                                continue;
                            }
                            let base = l * inner * r + j;
                            for i in 0..inner {
                                let e = base + i * r;
                                acc[e] += m * x[e] as f64;
                                wsum[e] += m;
                            }
                        }
                    }
                }
            }
        }

        for e in 0..n {
            if wsum[e] > 0.0 {
                g[e] = (acc[e] / wsum[e]) as f32;
            } // else: keep previous global value (n_l = 0 this round)
        }
    }
}

/// Streaming eq. 17: fold updates into running per-element weighted
/// sums as they arrive, then write the averages back once per round.
///
/// ```text
/// let mut agg = StreamingAggregator::new(&global, l, r);
/// for each arriving update { agg.push(&update.trainable, &cfg, w); }
/// agg.finish(&mut global);
/// ```
#[derive(Debug, Clone)]
pub struct StreamingAggregator {
    n_layers: usize,
    rank_dim: usize,
    /// Per global tensor: (name, pattern, element count).
    layout: Vec<(String, Pattern, usize)>,
    acc: Vec<Vec<f64>>,
    wsum: Vec<Vec<f64>>,
    n_updates: usize,
}

impl StreamingAggregator {
    /// Capture the global model's tensor layout; no data is copied.
    pub fn new(global: &TensorMap, n_layers: usize, rank_dim: usize)
               -> Self {
        let layout: Vec<(String, Pattern, usize)> = global
            .entries
            .iter()
            .map(|(spec, g)| {
                (
                    spec.name.clone(),
                    classify(&spec.shape, n_layers, rank_dim),
                    g.len(),
                )
            })
            .collect();
        let acc = layout.iter().map(|&(_, _, n)| vec![0f64; n]).collect();
        let wsum =
            layout.iter().map(|&(_, _, n)| vec![0f64; n]).collect();
        StreamingAggregator {
            n_layers,
            rank_dim,
            layout,
            acc,
            wsum,
            n_updates: 0,
        }
    }

    /// Fold one device's update into the running sums (O(model size);
    /// the update can be dropped immediately afterwards).
    pub fn push(&mut self, trainable: &TensorMap, config: &LoraConfig,
                weight: f64) {
        let mask = config.rank_mask(self.n_layers, self.rank_dim);
        for (ti, (name, pat, n)) in self.layout.iter().enumerate() {
            let x = trainable
                .get(name)
                .expect("device update missing tensor");
            debug_assert_eq!(x.len(), *n, "shape drift in {name}");
            let (acc, wsum) = (&mut self.acc[ti], &mut self.wsum[ti]);
            let w = weight;
            match *pat {
                Pattern::Full => {
                    for (e, &v) in x.iter().enumerate() {
                        acc[e] += w * v as f64;
                        wsum[e] += w;
                    }
                }
                Pattern::Rows { r, inner } => {
                    for l in 0..self.n_layers {
                        for j in 0..r {
                            let m = mask[l * r + j] as f64 * w;
                            if m == 0.0 {
                                continue;
                            }
                            let off = (l * r + j) * inner;
                            for e in off..off + inner {
                                acc[e] += m * x[e] as f64;
                                wsum[e] += m;
                            }
                        }
                    }
                }
                Pattern::Cols { r, inner } => {
                    for l in 0..self.n_layers {
                        for j in 0..r {
                            let m = mask[l * r + j] as f64 * w;
                            if m == 0.0 {
                                continue;
                            }
                            let base = l * inner * r + j;
                            for i in 0..inner {
                                let e = base + i * r;
                                acc[e] += m * x[e] as f64;
                                wsum[e] += m;
                            }
                        }
                    }
                }
            }
        }
        self.n_updates += 1;
    }

    /// Number of updates folded so far.
    pub fn n_updates(&self) -> usize {
        self.n_updates
    }

    /// Write the layer-wise averages into `global`. Slots no device
    /// held this round keep their previous global value; with zero
    /// updates this is a no-op (matches [`aggregate`] on `&[]`).
    pub fn finish(self, global: &mut TensorMap) {
        if self.n_updates == 0 {
            return;
        }
        for (ti, (spec, g)) in global.entries.iter_mut().enumerate() {
            debug_assert_eq!(spec.name, self.layout[ti].0,
                             "global layout drift");
            let (acc, wsum) = (&self.acc[ti], &self.wsum[ti]);
            for e in 0..g.len() {
                if wsum[e] > 0.0 {
                    g[e] = (acc[e] / wsum[e]) as f32;
                }
            }
        }
    }
}

/// Number of devices contributing to each layer (n_l diagnostics).
pub fn contributors_per_layer(updates: &[DeviceUpdate], n_layers: usize)
                              -> Vec<usize> {
    let mut n = vec![0usize; n_layers];
    for u in updates {
        for l in u.config.layers.indices(n_layers) {
            n[l] += 1;
        }
    }
    n
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::masks::LayerSet;
    use crate::model::TensorSpec;

    const L: usize = 4;
    const R: usize = 3;
    const D: usize = 2;

    fn specs() -> Vec<TensorSpec> {
        vec![
            TensorSpec { name: "aq".into(), shape: vec![L, R, D] },
            TensorSpec { name: "bq".into(), shape: vec![L, D, R] },
            TensorSpec { name: "head_w".into(), shape: vec![D, 2] },
        ]
    }

    fn filled(val: f32) -> TensorMap {
        let mut t = TensorMap::zeros(&specs());
        for (_, v) in &mut t.entries {
            v.iter_mut().for_each(|x| *x = val);
        }
        t
    }

    fn update(val: f32, depth: usize, ranks: Vec<usize>) -> DeviceUpdate {
        DeviceUpdate {
            trainable: filled(val),
            config: LoraConfig { layers: LayerSet::Depth(depth), ranks },
            weight: 1.0,
        }
    }

    #[test]
    fn uniform_depth_is_plain_average() {
        let mut g = filled(0.0);
        let ups = vec![
            update(1.0, L, vec![R; L]),
            update(3.0, L, vec![R; L]),
        ];
        aggregate(&mut g, &ups, L, R);
        for (_, v) in &g.entries {
            assert!(v.iter().all(|&x| (x - 2.0).abs() < 1e-6));
        }
    }

    #[test]
    fn layerwise_counts_only_contributors() {
        let mut g = filled(-1.0);
        // Device A trains all 4 layers, device B only the deepest 1.
        let ups = vec![
            update(2.0, L, vec![R; L]),
            update(4.0, 1, vec![R; L]),
        ];
        aggregate(&mut g, &ups, L, R);
        let aq = g.get("aq").unwrap();
        // Layers 0..3 (shallow): only A → 2.0.
        assert!(aq[..3 * R * D].iter().all(|&x| (x - 2.0).abs() < 1e-6));
        // Layer 3 (deepest): (2+4)/2 = 3.0.
        assert!(aq[3 * R * D..].iter().all(|&x| (x - 3.0).abs() < 1e-6));
        // Head: all devices → 3.0.
        assert!(g
            .get("head_w")
            .unwrap()
            .iter()
            .all(|&x| (x - 3.0).abs() < 1e-6));
    }

    #[test]
    fn hetlora_rank_mismatch_aggregates_per_slot() {
        let mut g = filled(0.0);
        // A has rank 3 everywhere, B rank 1 everywhere (zero-padded).
        let ups = vec![
            update(2.0, L, vec![3; L]),
            update(6.0, L, vec![1; L]),
        ];
        aggregate(&mut g, &ups, L, R);
        let aq = g.get("aq").unwrap();
        // slot 0: both → 4.0; slots 1,2: only A → 2.0.
        for l in 0..L {
            let base = l * R * D;
            assert!((aq[base] - 4.0).abs() < 1e-6);
            assert!((aq[base + D] - 2.0).abs() < 1e-6);
            assert!((aq[base + 2 * D] - 2.0).abs() < 1e-6);
        }
        // Cols layout too (bq: [L, D, R]).
        let bq = g.get("bq").unwrap();
        for l in 0..L {
            for i in 0..D {
                let base = l * D * R + i * R;
                assert!((bq[base] - 4.0).abs() < 1e-6);
                assert!((bq[base + 1] - 2.0).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn untouched_slots_keep_previous_global() {
        let mut g = filled(9.0);
        let ups = vec![update(1.0, 1, vec![R; L])]; // only deepest layer
        aggregate(&mut g, &ups, L, R);
        let aq = g.get("aq").unwrap();
        assert!(aq[..3 * R * D].iter().all(|&x| x == 9.0));
        assert!(aq[3 * R * D..].iter().all(|&x| (x - 1.0).abs() < 1e-6));
    }

    #[test]
    fn weights_scale_contributions() {
        let mut g = filled(0.0);
        let mut a = update(1.0, L, vec![R; L]);
        a.weight = 3.0;
        let b = update(5.0, L, vec![R; L]);
        aggregate(&mut g, &[a, b], L, R);
        // (3·1 + 1·5)/4 = 2.0
        assert!(g
            .get("aq")
            .unwrap()
            .iter()
            .all(|&x| (x - 2.0).abs() < 1e-6));
    }

    #[test]
    fn contributor_counts() {
        let ups = vec![
            update(0.0, L, vec![R; L]),
            update(0.0, 2, vec![R; L]),
            update(0.0, 1, vec![R; L]),
        ];
        assert_eq!(contributors_per_layer(&ups, L), vec![1, 1, 2, 3]);
    }

    #[test]
    fn empty_update_set_is_noop() {
        let mut g = filled(5.0);
        aggregate(&mut g, &[], L, R);
        assert!(g.get("aq").unwrap().iter().all(|&x| x == 5.0));
    }

    #[test]
    fn streaming_matches_buffered_bitwise() {
        let ups = vec![
            update(2.0, L, vec![3; L]),
            update(6.0, 1, vec![1; L]),
            update(-1.5, 2, vec![2; L]),
        ];
        let mut buffered = filled(9.0);
        aggregate(&mut buffered, &ups, L, R);

        let mut streamed = filled(9.0);
        let mut agg = StreamingAggregator::new(&streamed, L, R);
        for u in &ups {
            agg.push(&u.trainable, &u.config, u.weight);
        }
        assert_eq!(agg.n_updates(), 3);
        agg.finish(&mut streamed);
        assert_eq!(buffered, streamed, "streaming must be bit-identical");
    }

    #[test]
    fn streaming_empty_is_noop() {
        let mut g = filled(5.0);
        StreamingAggregator::new(&g, L, R).finish(&mut g);
        assert!(g.get("aq").unwrap().iter().all(|&x| x == 5.0));
    }
}
