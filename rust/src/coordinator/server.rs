//! The parameter-server round loop (§3's six modules wired together).
//!
//! Per round: ① devices report status → capacity EMA (§4.3);
//! ② strategy picks per-device LoRA configurations (§4.4, LCD for
//! LEGEND); ③ LoRA assignment + download accounting (§4.6); ④ local
//! fine-tuning through the Trainer backend (§4.2 — real gradients via
//! PJRT); ⑤ upload accounting + adaptive layer-wise aggregation
//! (§4.5); ⑥ virtual-clock timing via eq. (12)/(13) and global-model
//! evaluation. Produces a [`RunRecord`] with everything Figs. 7–13
//! need.

use anyhow::Result;

use crate::data::{grammar, partition, Dataset, Spec};
use crate::device::profile::calib;
use crate::device::Fleet;
use crate::metrics::{RoundRecord, RunRecord};
use crate::model::state::TensorMap;
use crate::model::Manifest;
use crate::runtime::Masks;
use crate::sim::clock::{simulate_round, DeviceRound, VirtualClock};
use crate::util::rng::Rng;

use super::aggregation::{aggregate, DeviceUpdate};
use super::capacity::CapacityEstimator;
use super::transport::Transport;
use super::strategy::{Strategy, StrategyCtx};
use super::trainer::Trainer;

/// Federated-run configuration.
#[derive(Debug, Clone)]
pub struct FedConfig {
    pub task: String,
    pub rounds: usize,
    pub eval_every: usize,
    pub lr0: f64,
    pub seed: u64,
    pub train_size: usize,
    pub test_size: usize,
    /// Dirichlet α for the non-iid label partition; ≤ 0 → iid
    /// (Table 2: GLUE tasks α = 10, mmlu/gsm iid).
    pub alpha: f64,
    /// Cap on local batches per round (keeps single-core wall-clock
    /// sane; the timing model uses the same cap, so virtual time stays
    /// consistent).
    pub max_batches: usize,
    /// Target accuracy for the completion-time metric (Fig. 8).
    pub target_acc: f64,
    pub verbose: bool,
}

impl Default for FedConfig {
    fn default() -> Self {
        FedConfig {
            task: "sst2".into(),
            rounds: 25,
            eval_every: 1,
            lr0: 5e-3,
            seed: 1,
            train_size: 2048,
            test_size: 256,
            alpha: 10.0,
            max_batches: 8,
            target_acc: 0.85,
            verbose: false,
        }
    }
}

/// Model metadata the server needs without holding a full Manifest
/// (lets Mock-backed tests/benches run without artifacts).
#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub n_layers: usize,
    pub r_max: usize,
    pub w_max: usize,
    pub unit_rank_bytes: usize,
    pub unit_width_bytes: usize,
    pub head_bytes: usize,
}

impl ModelMeta {
    pub fn from_manifest(m: &Manifest) -> Self {
        ModelMeta {
            n_layers: m.dim.n_layers,
            r_max: m.dim.r_max,
            w_max: m.dim.adapter_w_max,
            unit_rank_bytes: m.unit_rank_bytes(),
            unit_width_bytes: m.adapter_unit_width_bytes(),
            head_bytes: m.head_bytes(),
        }
    }

    /// Small synthetic meta for Mock-backed tests.
    pub fn synthetic(n_layers: usize, r_max: usize, w_max: usize) -> Self {
        ModelMeta {
            n_layers,
            r_max,
            w_max,
            unit_rank_bytes: 1024,
            unit_width_bytes: 512,
            head_bytes: 2048,
        }
    }

    pub fn rank_dim(&self, family: &str) -> usize {
        match family {
            "adapter" => self.w_max,
            _ => self.r_max,
        }
    }

    pub fn unit_bytes(&self, family: &str) -> usize {
        match family {
            "adapter" => self.unit_width_bytes,
            _ => self.unit_rank_bytes,
        }
    }
}

/// Cosine learning-rate schedule with a 10% floor (§6.1: lr 0.002,
/// cosine decay).
pub fn cosine_lr(lr0: f64, round: usize, total: usize) -> f64 {
    let t = (round.saturating_sub(1)) as f64 / total.max(1) as f64;
    lr0 * (0.1 + 0.9 * 0.5 * (1.0 + (std::f64::consts::PI * t).cos()))
}

/// Run one full federated fine-tuning experiment.
pub fn run_federated(cfg: &FedConfig, fleet: &mut Fleet,
                     strategy: &mut dyn Strategy,
                     trainer: &mut dyn Trainer, meta: &ModelMeta,
                     spec: &Spec, mut global: TensorMap)
                     -> Result<RunRecord> {
    let n = fleet.len();
    let family = trainer.family();
    let rank_dim = meta.rank_dim(family);
    let unit_bytes = meta.unit_bytes(family);

    // ---- data -------------------------------------------------------------
    let mut data_rng = Rng::new(cfg.seed).child("data");
    let task = spec.task(&cfg.task)?.clone();
    let train =
        grammar::generate(spec, &cfg.task, cfg.train_size, &mut data_rng)?;
    let test_size = (cfg.test_size / 64).max(1) * 64;
    let test =
        grammar::generate(spec, &cfg.task, test_size, &mut data_rng)?;
    let how = if cfg.alpha > 0.0 {
        partition::Partition::Dirichlet { alpha: cfg.alpha }
    } else {
        partition::Partition::Iid
    };
    let min_shard = trainer.batch_size();
    let shards = partition::split(&train, n, how, task.n_classes,
                                  min_shard, &mut data_rng);

    // ---- state ------------------------------------------------------------
    let mut estimator = CapacityEstimator::paper(n);
    let mut transport = Transport::new();
    let mut clock = VirtualClock::new();
    let mut record = RunRecord::new(&strategy.name(), &cfg.task);
    let mut last_losses = vec![0f64; n];
    let mut last_round_time = 0f64;
    let mut last_acc = 0f64;
    let mut last_test_loss = 0f64;
    let batch = trainer.batch_size();

    for h in 1..=cfg.rounds {
        if h > 1 {
            fleet.advance_round();
        }
        transport.begin_round(h);
        // ① status reports → capacity estimation (eq. 8–9).
        for i in 0..n {
            let (mu_hat, beta_hat) = fleet.observe(i, unit_bytes);
            transport.recv_status(i);
            estimator.update(i, mu_hat, beta_hat);
        }
        let estimates: Vec<_> =
            (0..n).map(|i| estimator.get(i).unwrap()).collect();
        let n_batches: Vec<usize> = shards
            .iter()
            .map(|s| s.len().div_ceil(batch).min(cfg.max_batches))
            .collect();

        // ② LoRA configuration (§4.4).
        let ctx = StrategyCtx {
            round: h,
            n_layers: meta.n_layers,
            rank_dim,
            fwd_times: estimates
                .iter()
                .map(|c| calib::FWD_FRAC * c.mu * meta.n_layers as f64)
                .collect(),
            estimates,
            n_batches: n_batches.clone(),
            unit_rank_bytes: unit_bytes,
            compute_budgets: vec![f64::MAX; n],
            comm_budgets: vec![usize::MAX; n],
            last_losses: last_losses.clone(),
            last_round_time,
        };
        let plan = strategy.configure(&ctx);
        debug_assert_eq!(plan.device_configs.len(), n);

        // ③–⑤ assignment, local fine-tuning, aggregation.
        let lr = cosine_lr(cfg.lr0, h, cfg.rounds) as f32;
        let mut updates: Vec<DeviceUpdate> = Vec::with_capacity(n);
        let mut loss_sum = 0f64;
        for (i, config) in plan.device_configs.iter().enumerate() {
            let masks = Masks {
                rank_mask: config.rank_mask(meta.n_layers, rank_dim),
                layer_mask: config.layer_mask(meta.n_layers),
            };
            // §4.6 assignment travels through the transport layer,
            // which counts the active-slot bytes it would put on the
            // wire (Fig. 11's quantity).
            let assigned = transport.send_assignment(
                i, &global, config, meta.n_layers, rank_dim);
            let outcome = trainer.train_local(
                i, &assigned, &masks, &shards[i], lr, cfg.max_batches,
            )?;
            transport.recv_update(i, &outcome.trainable, config,
                                  meta.n_layers, rank_dim);
            loss_sum += outcome.mean_loss;
            last_losses[i] = outcome.mean_loss;
            updates.push(DeviceUpdate {
                trainable: outcome.trainable,
                config: config.clone(),
                weight: 1.0,
            });
        }
        let tally = transport.round_tally();
        let (up_bytes, down_bytes) = (tally.uplink, tally.downlink);
        aggregate(&mut global, &updates, meta.n_layers, rank_dim);

        // ⑥ timing (eq. 12/13) with TRUE device parameters.
        let rounds_t: Vec<DeviceRound> = plan
            .device_configs
            .iter()
            .enumerate()
            .map(|(i, config)| {
                let d = &fleet.devices[i];
                let beta = d.true_beta(unit_bytes);
                DeviceRound {
                    device_id: i,
                    fwd_time_per_batch: d
                        .compute
                        .forward_time(meta.n_layers),
                    mu: d.true_mu(),
                    beta,
                    depth: config.backprop_depth(meta.n_layers),
                    ranks: config.active_ranks(meta.n_layers),
                    n_batches: n_batches[i],
                    extra_upload_s: beta
                        * (meta.head_bytes as f64
                            / unit_bytes.max(1) as f64),
                }
            })
            .collect();
        let timing = simulate_round(&rounds_t);
        clock.advance(&timing);
        last_round_time = timing.round_time;

        // Evaluation of the aggregated global model.
        if h % cfg.eval_every == 0 || h == cfg.rounds {
            let eval_masks = Masks {
                rank_mask: plan
                    .eval_config
                    .rank_mask(meta.n_layers, rank_dim),
                layer_mask: plan.eval_config.layer_mask(meta.n_layers),
            };
            let (tl, ta) =
                trainer.evaluate(&global, &eval_masks, &test)?;
            last_acc = ta;
            last_test_loss = tl;
        }

        let mean_depth = plan
            .device_configs
            .iter()
            .map(|c| c.depth(meta.n_layers) as f64)
            .sum::<f64>()
            / n as f64;
        record.rounds.push(RoundRecord {
            round: h,
            sim_time: clock.elapsed,
            round_time: timing.round_time,
            avg_waiting: timing.avg_waiting,
            up_bytes,
            down_bytes,
            train_loss: loss_sum / n as f64,
            test_acc: last_acc,
            test_loss: last_test_loss,
            mean_depth,
        });
        if cfg.verbose {
            println!(
                "[{}/{}] {} t={:.0}s acc={:.3} loss={:.3} depth={:.1} \
                 wait={:.1}s",
                h,
                cfg.rounds,
                strategy.name(),
                clock.elapsed,
                last_acc,
                loss_sum / n as f64,
                mean_depth,
                timing.avg_waiting
            );
        }
    }
    Ok(record)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::strategy::{FedLora, Legend};
    use crate::coordinator::trainer::MockTrainer;
    use crate::device::FleetConfig;
    use crate::model::TensorSpec;

    fn toy_spec() -> Spec {
        let json = r#"{
          "vocab_size": 256, "seq_len": 16,
          "special": {"pad": 0, "cls": 1, "mask": 2, "sep": 3},
          "filler": [4, 50], "noise": [200, 256],
          "tasks": {
            "sst2": {"kind": "single", "n_classes": 2,
                     "banks": [[50, 80], [80, 110]],
                     "len_range": [5, 10], "bank_words": [2, 4],
                     "label_noise": 0.0}
          }
        }"#;
        Spec::from_json(&crate::util::json::Value::parse(json).unwrap())
            .unwrap()
    }

    fn toy_global(meta: &ModelMeta) -> TensorMap {
        TensorMap::zeros(&[
            TensorSpec {
                name: "aq".into(),
                shape: vec![meta.n_layers, meta.r_max, 4],
            },
            TensorSpec {
                name: "head_w".into(),
                shape: vec![4, 2],
            },
        ])
    }

    fn run(strategy: &mut dyn Strategy, rounds: usize) -> RunRecord {
        let meta = ModelMeta::synthetic(12, 16, 32);
        let mut fleet = Fleet::new(FleetConfig::pretest());
        let mut trainer = MockTrainer::new("lora");
        let cfg = FedConfig {
            rounds,
            train_size: 256,
            test_size: 64,
            ..Default::default()
        };
        run_federated(&cfg, &mut fleet, strategy, &mut trainer, &meta,
                      &toy_spec(), toy_global(&meta))
        .unwrap()
    }

    #[test]
    fn produces_full_record() {
        let mut s = Legend::paper(12, 16);
        let r = run(&mut s, 5);
        assert_eq!(r.rounds.len(), 5);
        assert_eq!(r.method, "LEGEND");
        // Virtual time strictly increases, traffic is positive.
        for w in r.rounds.windows(2) {
            assert!(w[1].sim_time > w[0].sim_time);
        }
        assert!(r.rounds.iter().all(|x| x.up_bytes > 0));
        assert!(r.final_accuracy() > 0.0);
    }

    #[test]
    fn legend_waits_less_than_fedlora() {
        let mut legend = Legend::paper(12, 16);
        let mut fedlora = FedLora { rank: 8 };
        let a = run(&mut legend, 8);
        let b = run(&mut fedlora, 8);
        assert!(
            a.mean_waiting() < b.mean_waiting(),
            "LEGEND {:.2}s vs FedLoRA {:.2}s",
            a.mean_waiting(),
            b.mean_waiting()
        );
        // And less traffic per round on average.
        assert!(a.total_traffic() < b.total_traffic());
    }

    #[test]
    fn legend_rounds_are_shorter() {
        let mut legend = Legend::paper(12, 16);
        let mut fedlora = FedLora { rank: 8 };
        let a = run(&mut legend, 6);
        let b = run(&mut fedlora, 6);
        assert!(a.total_time() < b.total_time());
    }

    #[test]
    fn cosine_schedule_decays_with_floor() {
        let lr0 = 2e-3;
        let first = cosine_lr(lr0, 1, 100);
        let mid = cosine_lr(lr0, 50, 100);
        let last = cosine_lr(lr0, 100, 100);
        assert!((first - lr0).abs() < 1e-9);
        assert!(mid < first && last < mid);
        assert!(last >= 0.1 * lr0 - 1e-12);
    }

    #[test]
    fn mean_depth_reflects_heterogeneity() {
        let mut s = Legend::paper(12, 16);
        let r = run(&mut s, 3);
        let d = r.rounds.last().unwrap().mean_depth;
        assert!(d > 1.0 && d <= 12.0, "mean depth {d}");
    }
}
