//! The parameter-server entry points (§3's six modules wired
//! together).
//!
//! Per round: ① devices report status → capacity EMA (§4.3);
//! ② strategy picks per-device LoRA configurations (§4.4, LCD for
//! LEGEND); ③ LoRA assignment + download accounting (§4.6); ④ local
//! fine-tuning through the Trainer backend (§4.2 — real gradients via
//! PJRT); ⑤ upload accounting + adaptive layer-wise aggregation
//! (§4.5); ⑥ virtual-clock timing via eq. (12)/(13) and global-model
//! evaluation. Produces a [`RunRecord`] with everything Figs. 7–13
//! need.
//!
//! The loop itself lives in [`super::engine::RoundEngine`]; this
//! module keeps the run configuration, model metadata, the LR
//! schedule, and the public [`run_federated`] /
//! [`run_federated_with`] entry points.

use anyhow::Result;

use crate::data::Spec;
use crate::device::FleetView;
use crate::metrics::RunRecord;
use crate::model::state::TensorMap;
use crate::model::Manifest;

use super::async_engine::AsyncEngine;
use super::engine::RoundEngine;
use super::participation::{Full, Participation};
use super::serialize::Codec;
use super::strategy::Strategy;
use super::trainer::Trainer;

/// Federated-run configuration.
#[derive(Debug, Clone)]
pub struct FedConfig {
    pub task: String,
    pub rounds: usize,
    pub eval_every: usize,
    pub lr0: f64,
    pub seed: u64,
    pub train_size: usize,
    pub test_size: usize,
    /// Dirichlet α for the non-iid label partition; ≤ 0 → iid
    /// (Table 2: GLUE tasks α = 10, mmlu/gsm iid).
    pub alpha: f64,
    /// Cap on local batches per round (keeps single-core wall-clock
    /// sane; the timing model uses the same cap, so virtual time stays
    /// consistent).
    pub max_batches: usize,
    /// Target accuracy for the completion-time metric (Fig. 8).
    pub target_acc: f64,
    /// Worker threads for phase ④ when the backend's device handles
    /// are `Send` (0 = one per available core). Results are
    /// bit-identical at every setting — see `coordinator/engine.rs`.
    pub threads: usize,
    /// Aggregation fold shards: the eq. 17 fold is partitioned per
    /// tensor across this many worker threads (0 = one per available
    /// core, 1 = fold inline on the coordinator thread). Bit-identical
    /// at every setting.
    pub agg_shards: usize,
    /// In-flight window W for phase ④ (0 = unbounded): workers pause
    /// before running a job more than W ahead of the fold cursor, so
    /// per-round transient memory is O(model + W) instead of
    /// cohort-bounded under skew. Bit-identical at every setting.
    pub window: usize,
    /// Run the staleness-windowed async engine
    /// (`coordinator/async_engine.rs`) instead of the eq. 12 barrier
    /// loop: devices run on their own cadence and fold whenever they
    /// finish, weighted by `1/(1+τ)^staleness_alpha`. With
    /// `max_staleness = 0` the async engine degenerates bitwise to the
    /// synchronous [`super::engine::RoundEngine`].
    pub async_mode: bool,
    /// Staleness-discount exponent α ≥ 0 for the async fold weight
    /// `w(τ) = 1/(1+τ)^α` (0 = no discount).
    pub staleness_alpha: f64,
    /// Hard staleness cutoff S: a commit window never closes while an
    /// update that would exceed S versions of staleness is still in
    /// flight, so every fold has τ ≤ S. 0 = synchronous barrier.
    pub max_staleness: usize,
    /// Edge-aggregation tier fan-in E: the admitted update stream is
    /// partitioned into E contiguous slices, each folded by its own
    /// sharded aggregator, with the root merging the edge partials in
    /// ascending edge-index order (1 = flat fold). Bit-identical at
    /// every setting — see `coordinator/aggregation.rs`.
    pub edge_aggregators: usize,
    /// Derive devices on demand (`LazyFleet`) instead of materializing
    /// the population: memory stays O(cohort) however large the fleet.
    /// Only consulted by entry points that build the fleet themselves
    /// (`exp::run_strategy_with`, `legend run --lazy`); bit-identical
    /// to the eager fleet for the same seed.
    pub lazy_fleet: bool,
    /// Periodic LCD re-allocation interval K (`--realloc-every`):
    /// the capacity snapshot the strategy plans from is re-fit from
    /// the live EWMA estimates every K commit rounds and *frozen*
    /// between refits, making the LoRA plan a per-round value with an
    /// explicit epoch (`coordinator/capacity.rs::Reallocator`). 0 =
    /// off — live estimates flow through every round, bitwise
    /// reproducing the pre-realloc engines.
    pub realloc_every: usize,
    /// Relative hysteresis band for refits
    /// (`--realloc-hysteresis`): a refit whose live μ and β all sit
    /// within this fraction of the frozen snapshot keeps the frozen
    /// values bitwise and does not bump the plan epoch — an
    /// unchanged fit is a no-op plan.
    pub realloc_hysteresis: f64,
    /// Uplink update codec (`--codec none|int8|int4`): quantized
    /// modes ship per-tensor affine-quantized deltas vs the assigned
    /// global and are dequantized exactly once before the eq. 17
    /// fold; `Codec::None` is today's raw-f32 wire, bitwise.
    /// Assignments (downlink) always travel f32 — see
    /// docs/TRANSPORT.md.
    pub codec: Codec,
    pub verbose: bool,
}

impl Default for FedConfig {
    fn default() -> Self {
        FedConfig {
            task: "sst2".into(),
            rounds: 25,
            eval_every: 1,
            lr0: 5e-3,
            seed: 1,
            train_size: 2048,
            test_size: 256,
            alpha: 10.0,
            max_batches: 8,
            target_acc: 0.85,
            threads: 0,
            agg_shards: 1,
            window: 0,
            async_mode: false,
            staleness_alpha: 0.5,
            max_staleness: 2,
            edge_aggregators: 1,
            lazy_fleet: false,
            realloc_every: 0,
            realloc_hysteresis: 0.05,
            codec: Codec::None,
            verbose: false,
        }
    }
}

/// Model metadata the server needs without holding a full Manifest
/// (lets Mock-backed tests/benches run without artifacts).
#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub n_layers: usize,
    pub r_max: usize,
    pub w_max: usize,
    pub unit_rank_bytes: usize,
    pub unit_width_bytes: usize,
    pub head_bytes: usize,
}

impl ModelMeta {
    pub fn from_manifest(m: &Manifest) -> Self {
        ModelMeta {
            n_layers: m.dim.n_layers,
            r_max: m.dim.r_max,
            w_max: m.dim.adapter_w_max,
            unit_rank_bytes: m.unit_rank_bytes(),
            unit_width_bytes: m.adapter_unit_width_bytes(),
            head_bytes: m.head_bytes(),
        }
    }

    /// Small synthetic meta for Mock-backed tests.
    pub fn synthetic(n_layers: usize, r_max: usize, w_max: usize) -> Self {
        ModelMeta {
            n_layers,
            r_max,
            w_max,
            unit_rank_bytes: 1024,
            unit_width_bytes: 512,
            head_bytes: 2048,
        }
    }

    pub fn rank_dim(&self, family: &str) -> usize {
        match family {
            "adapter" => self.w_max,
            _ => self.r_max,
        }
    }

    pub fn unit_bytes(&self, family: &str) -> usize {
        match family {
            "adapter" => self.unit_width_bytes,
            _ => self.unit_rank_bytes,
        }
    }
}

/// Cosine learning-rate schedule with a 10% floor (§6.1: lr 0.002,
/// cosine decay).
pub fn cosine_lr(lr0: f64, round: usize, total: usize) -> f64 {
    let t = (round.saturating_sub(1)) as f64 / total.max(1) as f64;
    lr0 * (0.1 + 0.9 * 0.5 * (1.0 + (std::f64::consts::PI * t).cos()))
}

/// Run one full federated fine-tuning experiment with full
/// participation (the paper's setting). Takes any [`FleetView`] — the
/// eager [`crate::device::Fleet`] or the O(cohort)
/// [`crate::device::LazyFleet`] — and produces bit-identical records
/// for either under the same seed.
pub fn run_federated(cfg: &FedConfig, fleet: &mut dyn FleetView,
                     strategy: &mut dyn Strategy,
                     trainer: &mut dyn Trainer, meta: &ModelMeta,
                     spec: &Spec, global: TensorMap)
                     -> Result<RunRecord> {
    run_federated_with(cfg, fleet, strategy, trainer, meta, spec, global,
                       &mut Full)
}

/// Same, with an explicit [`Participation`] policy (client sampling,
/// straggler deadlines, …).
#[allow(clippy::too_many_arguments)]
pub fn run_federated_with(cfg: &FedConfig, fleet: &mut dyn FleetView,
                          strategy: &mut dyn Strategy,
                          trainer: &mut dyn Trainer, meta: &ModelMeta,
                          spec: &Spec, global: TensorMap,
                          participation: &mut dyn Participation)
                          -> Result<RunRecord> {
    if cfg.async_mode {
        AsyncEngine::new(cfg, meta)
            .run(fleet, strategy, trainer, spec, global, participation)
    } else {
        RoundEngine::new(cfg, meta)
            .run(fleet, strategy, trainer, spec, global, participation)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::participation::{DeadlineDrop, UniformSample};
    use crate::coordinator::strategy::{FedLora, Legend};
    use crate::coordinator::trainer::MockTrainer;
    use crate::device::{Fleet, FleetConfig};
    use crate::model::TensorSpec;

    fn toy_spec() -> Spec {
        let json = r#"{
          "vocab_size": 256, "seq_len": 16,
          "special": {"pad": 0, "cls": 1, "mask": 2, "sep": 3},
          "filler": [4, 50], "noise": [200, 256],
          "tasks": {
            "sst2": {"kind": "single", "n_classes": 2,
                     "banks": [[50, 80], [80, 110]],
                     "len_range": [5, 10], "bank_words": [2, 4],
                     "label_noise": 0.0}
          }
        }"#;
        Spec::from_json(&crate::util::json::Value::parse(json).unwrap())
            .unwrap()
    }

    fn toy_global(meta: &ModelMeta) -> TensorMap {
        TensorMap::zeros(&[
            TensorSpec {
                name: "aq".into(),
                shape: vec![meta.n_layers, meta.r_max, 4],
            },
            TensorSpec {
                name: "head_w".into(),
                shape: vec![4, 2],
            },
        ])
    }

    fn run(strategy: &mut dyn Strategy, rounds: usize) -> RunRecord {
        let meta = ModelMeta::synthetic(12, 16, 32);
        let mut fleet = Fleet::new(FleetConfig::pretest());
        let mut trainer = MockTrainer::new("lora");
        let cfg = FedConfig {
            rounds,
            train_size: 256,
            test_size: 64,
            ..Default::default()
        };
        run_federated(&cfg, &mut fleet, strategy, &mut trainer, &meta,
                      &toy_spec(), toy_global(&meta))
        .unwrap()
    }

    #[test]
    fn produces_full_record() {
        let mut s = Legend::paper(12, 16);
        let r = run(&mut s, 5);
        assert_eq!(r.rounds.len(), 5);
        assert_eq!(r.method, "LEGEND");
        // Virtual time strictly increases, traffic is positive.
        for w in r.rounds.windows(2) {
            assert!(w[1].sim_time > w[0].sim_time);
        }
        assert!(r.rounds.iter().all(|x| x.up_bytes > 0));
        assert!(r.final_accuracy() > 0.0);
        // Full participation: everyone, every round.
        let n = FleetConfig::pretest().total();
        assert!(r.rounds.iter().all(|x| x.participants == n));
        assert!(r.rounds.iter().all(|x| x.dropped == 0));
    }

    #[test]
    fn legend_waits_less_than_fedlora() {
        let mut legend = Legend::paper(12, 16);
        let mut fedlora = FedLora { rank: 8 };
        let a = run(&mut legend, 8);
        let b = run(&mut fedlora, 8);
        assert!(
            a.mean_waiting() < b.mean_waiting(),
            "LEGEND {:.2}s vs FedLoRA {:.2}s",
            a.mean_waiting(),
            b.mean_waiting()
        );
        // And less traffic per round on average.
        assert!(a.total_traffic() < b.total_traffic());
    }

    #[test]
    fn legend_rounds_are_shorter() {
        let mut legend = Legend::paper(12, 16);
        let mut fedlora = FedLora { rank: 8 };
        let a = run(&mut legend, 6);
        let b = run(&mut fedlora, 6);
        assert!(a.total_time() < b.total_time());
    }

    #[test]
    fn cosine_schedule_decays_with_floor() {
        let lr0 = 2e-3;
        let first = cosine_lr(lr0, 1, 100);
        let mid = cosine_lr(lr0, 50, 100);
        let last = cosine_lr(lr0, 100, 100);
        assert!((first - lr0).abs() < 1e-9);
        assert!(mid < first && last < mid);
        assert!(last >= 0.1 * lr0 - 1e-12);
    }

    #[test]
    fn mean_depth_reflects_heterogeneity() {
        let mut s = Legend::paper(12, 16);
        let r = run(&mut s, 3);
        let d = r.rounds.last().unwrap().mean_depth;
        assert!(d > 1.0 && d <= 12.0, "mean depth {d}");
    }

    // FedLoRA keeps every device's config identical and independent of
    // the capacity estimates, so byte/time comparisons between
    // participation policies are exact, not statistical.
    fn run_with(participation: &mut dyn crate::coordinator::participation::Participation,
                rounds: usize) -> RunRecord {
        let meta = ModelMeta::synthetic(12, 16, 32);
        let mut fleet = Fleet::new(FleetConfig::pretest());
        let mut trainer = MockTrainer::new("lora");
        let mut s = FedLora { rank: 8 };
        let cfg = FedConfig {
            rounds,
            train_size: 256,
            test_size: 64,
            ..Default::default()
        };
        run_federated_with(&cfg, &mut fleet, &mut s, &mut trainer, &meta,
                           &toy_spec(), toy_global(&meta), participation)
        .unwrap()
    }

    #[test]
    fn sampled_rounds_move_fewer_bytes() {
        let full = run_with(&mut Full, 4);
        let sampled =
            run_with(&mut UniformSample { fraction: 0.4 }, 4);
        let n = FleetConfig::pretest().total();
        let k = (0.4f64 * n as f64).ceil() as usize;
        assert!(sampled.rounds.iter().all(|r| r.participants == k));
        // Skipped devices contribute zero bytes in both directions.
        for (s, f) in sampled.rounds.iter().zip(&full.rounds) {
            assert!(s.up_bytes < f.up_bytes, "uplink shrinks");
            assert!(s.down_bytes < f.down_bytes, "downlink shrinks");
        }
    }

    #[test]
    fn deadline_drop_records_dropped_devices() {
        // A tight deadline on the heterogeneous pretest fleet must
        // drop someone, and round time may only shrink vs full.
        let full = run_with(&mut Full, 4);
        let dropped = run_with(&mut DeadlineDrop::new(1.01), 4);
        assert!(
            dropped.rounds.iter().any(|r| r.dropped > 0),
            "tight deadline never dropped a device"
        );
        for (d, f) in dropped.rounds.iter().zip(&full.rounds) {
            assert!(d.participants + d.dropped == f.participants);
            assert!(d.round_time <= f.round_time + 1e-9);
        }
    }
}
