//! # AsyncEngine — staleness-windowed, event-driven rounds
//!
//! The eq. 12 barrier (and its semi-sync `DeadlineDrop` relaxation)
//! forces every surviving device to synchronize once per round: the
//! round lasts `max_i t_i` and fast devices idle for eq. 13's waiting
//! time. This engine removes the barrier. Each device runs on its own
//! cadence under the [`VirtualClock`]: it pulls the current global
//! model, trains for its true eq. 12 duration, and *submits its update
//! whenever it finishes* — the coordinator folds it immediately with a
//! staleness weight
//!
//! ```text
//!     w(τ) = 1 / (1 + τ)^α        (τ = model versions elapsed
//!                                  between pull and fold)
//! ```
//!
//! applied on top of the eq. 17 fold weight, in the spirit of
//! FedAsync/FedBuff-style semi-asynchronous aggregation.
//!
//! `w(τ)` is a *relative* weight inside the eq. 17 weighted mean, not
//! an anchored server learning rate: when fresh and stale updates
//! share a window (or a slot), stale ones count proportionally less;
//! a slot reached by a single lone update still takes that update's
//! value, exactly as eq. 17 gives a layer held by one device to that
//! device. This is deliberate — FedAsync's `(1−η)·global + η·update`
//! blending would change the S = 0 limit away from eq. 17 and break
//! the bitwise degeneracy to the synchronous engine; the hard
//! protection against very stale updates is the `max_staleness`
//! cutoff itself, not the discount.
//!
//! ## Commit windows and the staleness cutoff
//!
//! Global model versions are committed in *windows* (one per
//! `FedConfig::rounds` entry, so `RunRecord` keeps its shape). Window
//! `h` dispatches the idle members of the sampled cohort against model
//! version `h − 1`, then closes at the earliest virtual time that
//! satisfies the staleness cutoff `S = max_staleness`:
//!
//! * every in-flight update dispatched at window `g ≤ h − S` has
//!   landed (so no fold can ever exceed staleness `S` — the cutoff is
//!   enforced by the commit rule, and double-checked by the
//!   aggregator's version watermark), and
//! * at least one update lands per window (progress guarantee).
//!
//! Updates completing before the close fold into version `h` with
//! `τ = h − g`; the rest stay queued — a slow device's training simply
//! spans several windows while the fleet keeps committing.
//!
//! `S = 0` forces every window to wait for all of its own dispatches:
//! the event loop degenerates to the synchronous barrier, and a fixed
//! seed reproduces [`super::engine::RoundEngine`]'s `RunRecord`
//! *bitwise* (the property suite uses the sync engine as the oracle).
//!
//! ## Determinism contract
//!
//! Event order is total: completions are keyed by
//! `(completion_time, device_id)` under `f64::total_cmp`
//! ([`EventKey`]), so ties on the virtual clock break by device id,
//! never by arrival on a wall-clock thread. All RNG draws (data,
//! fleet observation, participation) happen on the coordinator thread
//! in a fixed order, phase-④ outcomes are pure per-device functions
//! collected by job index, and within a window the folds and the
//! timing/loss reductions all run in ascending device order (the sync
//! sink's order) — so a fixed seed yields a bit-identical
//! [`RunRecord`] at every `threads × agg_shards × window` setting,
//! and the S = 0 degeneracy holds for the aggregated model itself,
//! not merely the mock-trained record.
//!
//! ## Memory
//!
//! An update that is virtually in flight must be physically buffered
//! until its completion event fires: transient memory is
//! O(model + in-flight updates), bounded by the fleet size (each
//! device holds at most one in-flight update). Within a window the
//! fold itself stays streaming (O(model) via the sharded aggregator).

use std::cmp::Ordering as CmpOrdering;
use std::collections::{BTreeMap, BTreeSet, BinaryHeap};

use anyhow::{anyhow, Result};

use crate::data::{Dataset, Spec};
use crate::device::profile::calib;
use crate::device::FleetView;
use crate::metrics::{RoundRecord, RunRecord};
use crate::model::masks::LoraConfig;
use crate::model::state::TensorMap;
use crate::runtime::Masks;
use crate::sim::clock::{timing_from_pairs, VirtualClock};
use crate::util::rng::Rng;

use super::aggregation::EdgeAggregator;
use super::capacity::{CapacityEstimator, Reallocator};
use super::engine::{admitted_cohort, device_round, device_shard,
                    mean_depth_of, sanitize, test_data, ExecOpts,
                    TrainJob};
use super::participation::Participation;
use super::serialize;
use super::server::{cosine_lr, FedConfig, ModelMeta};
use super::strategy::{Strategy, StrategyCtx};
use super::trainer::{LocalOutcome, Trainer};
use super::transport::Transport;

/// Staleness-discount weight `w(τ) = 1/(1+τ)^α`, clamped to 0 beyond
/// the `max_staleness` cutoff. Exactly 1.0 at `τ = 0` (so a fresh fold
/// is bit-identical to an unweighted one) and monotone non-increasing
/// in `τ` for any `α ≥ 0` (negative `α` is treated as 0).
pub fn staleness_weight(tau: usize, max_staleness: usize, alpha: f64)
                        -> f64 {
    if tau == 0 {
        return 1.0;
    }
    if tau > max_staleness {
        return 0.0;
    }
    (1.0 + tau as f64).powf(-alpha.max(0.0))
}

/// Deterministic event ordering: earliest virtual completion first,
/// device id breaking ties. Total order via [`f64::total_cmp`], so the
/// queue never depends on wall-clock scheduling.
#[derive(Debug, Clone, Copy)]
pub struct EventKey {
    pub time: f64,
    pub device_id: usize,
}

impl PartialEq for EventKey {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == CmpOrdering::Equal
    }
}

impl Eq for EventKey {}

impl PartialOrd for EventKey {
    fn partial_cmp(&self, other: &Self) -> Option<CmpOrdering> {
        Some(self.cmp(other))
    }
}

impl Ord for EventKey {
    fn cmp(&self, other: &Self) -> CmpOrdering {
        self.time
            .total_cmp(&other.time)
            .then(self.device_id.cmp(&other.device_id))
    }
}

struct Entry<T> {
    key: EventKey,
    item: T,
}

impl<T> PartialEq for Entry<T> {
    fn eq(&self, other: &Self) -> bool {
        self.key == other.key
    }
}

impl<T> Eq for Entry<T> {}

impl<T> PartialOrd for Entry<T> {
    fn partial_cmp(&self, other: &Self) -> Option<CmpOrdering> {
        Some(self.cmp(other))
    }
}

impl<T> Ord for Entry<T> {
    fn cmp(&self, other: &Self) -> CmpOrdering {
        self.key.cmp(&other.key)
    }
}

/// Min-queue of virtual-clock events ordered by [`EventKey`]. The pop
/// sequence is a pure function of the key set: pushing the same events
/// in any order yields the same pops (the order-invariance the async
/// fold leans on; see the property suite).
pub struct EventQueue<T> {
    heap: BinaryHeap<std::cmp::Reverse<Entry<T>>>,
}

impl<T> Default for EventQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> EventQueue<T> {
    pub fn new() -> Self {
        EventQueue { heap: BinaryHeap::new() }
    }

    pub fn push(&mut self, key: EventKey, item: T) {
        self.heap.push(std::cmp::Reverse(Entry { key, item }));
    }

    /// Key of the earliest pending event.
    pub fn peek_key(&self) -> Option<EventKey> {
        self.heap.peek().map(|std::cmp::Reverse(e)| e.key)
    }

    /// Pop the earliest pending event.
    pub fn pop(&mut self) -> Option<(EventKey, T)> {
        self.heap.pop().map(|std::cmp::Reverse(e)| (e.key, e.item))
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Unordered iteration over pending events (used to compute the
    /// must-fold deadline; never for fold order).
    pub fn iter(&self) -> impl Iterator<Item = (&EventKey, &T)> {
        self.heap.iter().map(|std::cmp::Reverse(e)| (&e.key, &e.item))
    }
}

/// One virtually in-flight update: everything the coordinator needs to
/// fold it when its completion event fires.
struct InFlight {
    /// Commit window the device was dispatched in (it trained on model
    /// version `gen − 1`).
    gen: usize,
    /// LCD plan epoch the update was *trained* under, fixed at
    /// dispatch. A spillover may legally fold into a window whose
    /// current epoch has moved on — its messages and fold keep this
    /// one.
    epoch: usize,
    /// True eq. 12 duration [virtual s], fixed at dispatch.
    duration: f64,
    /// Real encoded uplink size under the run's codec, fixed at
    /// dispatch — the update was encoded against the global the
    /// device was assigned, not whatever the global is at fold time.
    wire_bytes: usize,
    /// Outcome with `trainable` already put through the codec (the
    /// coordinator's single dequantization), ready for the fold.
    outcome: LocalOutcome,
    config: LoraConfig,
}

/// The staleness-windowed round-loop engine. Owns nothing across runs.
pub struct AsyncEngine<'a> {
    cfg: &'a FedConfig,
    meta: &'a ModelMeta,
}

impl<'a> AsyncEngine<'a> {
    pub fn new(cfg: &'a FedConfig, meta: &'a ModelMeta) -> Self {
        AsyncEngine { cfg, meta }
    }

    /// Run one full federated fine-tuning experiment asynchronously.
    pub fn run(&self, fleet: &mut dyn FleetView,
               strategy: &mut dyn Strategy,
               trainer: &mut dyn Trainer, spec: &Spec,
               mut global: TensorMap,
               participation: &mut dyn Participation)
               -> Result<RunRecord> {
        let cfg = self.cfg;
        let meta = self.meta;
        let n = fleet.len();
        participation
            .validate(n)
            .map_err(|e| anyhow!("participation: {e}"))?;
        let family = trainer.family();
        let rank_dim = meta.rank_dim(family);
        let unit_bytes = meta.unit_bytes(family);
        let alpha = cfg.staleness_alpha;
        let s_max = cfg.max_staleness;

        // ---- data (one pipeline, shared with the sync engine) -------------
        // Test set up front; training shards derived per cohort member
        // per window (pure functions of `(seed, device_id)`), so data
        // memory is O(cohort), never O(fleet).
        let batch = trainer.batch_size();
        let test = test_data(cfg, spec)?;

        // ---- state --------------------------------------------------------
        let mut estimator = CapacityEstimator::paper(n);
        let mut realloc =
            Reallocator::new(cfg.realloc_every, cfg.realloc_hysteresis);
        let transport = Transport::new();
        let mut clock = VirtualClock::new();
        let mut record = RunRecord::new(&strategy.name(), &cfg.task);
        let mut part_rng = Rng::new(cfg.seed).child("participation");
        // Sparse (round recorded, loss) per device ever trained — same
        // semantics as the sync engine's log, O(devices seen).
        let mut loss_log: BTreeMap<usize, (usize, f64)> = BTreeMap::new();
        let mut last_round_time = 0f64;
        let mut last_acc = 0f64;
        let mut last_test_loss = 0f64;
        // Async state: which devices are off training (sparse — at
        // most one in-flight update each, so O(in-flight) not
        // O(fleet)), the event queue of their completions, and the
        // most recent plan's eval mask (a window that dispatches
        // nothing still needs one).
        let mut busy: BTreeSet<usize> = BTreeSet::new();
        let mut pending: EventQueue<InFlight> = EventQueue::new();
        let mut eval_config: Option<LoraConfig> = None;

        for h in 1..=cfg.rounds {
            if h > 1 {
                fleet.advance_round();
            }
            transport.begin_round();
            let start = clock.elapsed;

            // ①a cohort sampling among *idle* devices: a device still
            // training cannot report status or accept an assignment.
            // With S = 0 everyone is idle at a window start, so the
            // draw and the filter match the sync engine exactly.
            let sampled =
                sanitize(participation.sample(h, n, &mut part_rng), n)
                    .unwrap_or_else(|| vec![0]);
            let cohort: Vec<usize> = sampled
                .into_iter()
                .filter(|i| !busy.contains(i))
                .collect();

            let mut dropped = 0usize;
            // Epoch of the plan this window dispatches under. A window
            // whose cohort is empty (everyone still training) plans
            // nothing: the epoch simply carries over.
            let mut epoch = realloc.epoch();
            if !cohort.is_empty() {
                // NOTE: phases ⓪–④ below mirror `RoundEngine::run`
                // line for line (the shareable pieces — data pipeline,
                // admission, eq. 12 inputs — already live in
                // `engine.rs` helpers). Edit both engines together:
                // the S = 0 oracle property test fails on any drift.
                // ⓪ materialize exactly this window's cohort shards.
                let shards: BTreeMap<usize, Dataset> = cohort
                    .iter()
                    .map(|&i| {
                        Ok((i, device_shard(cfg, spec, i, n, batch)?))
                    })
                    .collect::<Result<_>>()?;

                // ①b status reports → capacity estimation (eq. 8–9)
                // → the window's plan capacities, exactly as in the
                // sync engine: live estimates frozen between
                // `--realloc-every` refits, epoch resolved before any
                // message is logged.
                let live: Vec<_> = cohort
                    .iter()
                    .map(|&i| {
                        let (mu_hat, beta_hat) =
                            fleet.observe(i, unit_bytes);
                        estimator.update(i, mu_hat, beta_hat);
                        estimator.get(i).expect("cohort reported")
                    })
                    .collect();
                let estimates =
                    realloc.plan_estimates(h, &cohort, &live);
                epoch = realloc.epoch();
                for &i in &cohort {
                    transport.recv_status(h, epoch, i);
                }
                let n_batches: Vec<usize> = cohort
                    .iter()
                    .map(|&i| {
                        shards[&i]
                            .len()
                            .div_ceil(batch)
                            .min(cfg.max_batches)
                    })
                    .collect();

                // ② LoRA configuration (§4.4) over the cohort.
                let fwd_times: Vec<f64> = estimates
                    .iter()
                    .map(|c| calib::FWD_FRAC * c.mu * meta.n_layers as f64)
                    .collect();
                let ctx = StrategyCtx {
                    round: h,
                    n_layers: meta.n_layers,
                    rank_dim,
                    fwd_times: fwd_times.clone(),
                    estimates: estimates.clone(),
                    n_batches: n_batches.clone(),
                    unit_rank_bytes: unit_bytes,
                    compute_budgets: vec![f64::MAX; cohort.len()],
                    comm_budgets: vec![usize::MAX; cohort.len()],
                    last_losses: cohort
                        .iter()
                        .map(|&i| match loss_log.get(&i) {
                            Some(&(r, loss)) if r + 1 == h => loss,
                            _ => 0.0,
                        })
                        .collect(),
                    last_round_time,
                    device_ids: cohort.clone(),
                    staleness: cohort
                        .iter()
                        .map(|&i| match loss_log.get(&i) {
                            Some(&(r, _)) => {
                                (h - 1).saturating_sub(r)
                            }
                            None => usize::MAX,
                        })
                        .collect(),
                };
                let plan = strategy.configure(&ctx);
                debug_assert_eq!(plan.device_configs.len(), cohort.len());
                eval_config = Some(plan.eval_config.clone());

                // ①c deadline admission from PS-side estimates — same
                // predictions and fallback as the sync engine.
                let predicted: Vec<f64> = (0..cohort.len())
                    .map(|j| {
                        device_round(meta, unit_bytes, cohort[j],
                                     estimates[j].mu, estimates[j].beta,
                                     fwd_times[j],
                                     &plan.device_configs[j],
                                     n_batches[j])
                            .completion_time()
                    })
                    .collect();
                let admitted = admitted_cohort(participation, h, &cohort,
                                               &predicted, n);
                let admitted_pos: Vec<usize> = admitted
                    .iter()
                    .map(|i| cohort.binary_search(i).unwrap())
                    .collect();
                dropped = cohort.len() - admitted.len();

                // ③ assignment + download accounting, ④ local training
                // at dispatch (the outcome is a pure function of the
                // model version pulled now; only its *fold* waits for
                // the virtual completion event).
                let lr = cosine_lr(cfg.lr0, h, cfg.rounds) as f32;
                let mut outs: Vec<Option<LocalOutcome>> =
                    (0..admitted_pos.len()).map(|_| None).collect();
                {
                    let jobs: Vec<TrainJob<'_>> = admitted_pos
                        .iter()
                        .map(|&j| {
                            let i = cohort[j];
                            let config = &plan.device_configs[j];
                            transport.send_assignment(h, epoch, i,
                                                      &global, config,
                                                      meta.n_layers,
                                                      rank_dim);
                            TrainJob {
                                device_id: i,
                                init: &global,
                                masks: Masks {
                                    rank_mask: config
                                        .rank_mask(meta.n_layers, rank_dim),
                                    layer_mask: config
                                        .layer_mask(meta.n_layers),
                                },
                                shard: &shards[&i],
                                lr,
                                max_batches: cfg.max_batches,
                            }
                        })
                        .collect();
                    let opts = ExecOpts {
                        threads: cfg.threads,
                        window: cfg.window,
                    };
                    let outs_r = &mut outs;
                    let mut sink =
                        |k: usize, out: LocalOutcome| -> Result<()> {
                            outs_r[k] = Some(out);
                            Ok(())
                        };
                    trainer.train_cohort(&jobs, &opts, &mut sink)?;
                }
                // Schedule completion events at the true eq. 12 times.
                for (k, &j) in admitted_pos.iter().enumerate() {
                    let i = cohort[j];
                    let duration =
                        device_round(meta, unit_bytes, i,
                                     fleet.true_mu(i),
                                     fleet.true_beta(i, unit_bytes),
                                     fleet.forward_time(i, meta.n_layers),
                                     &plan.device_configs[j], n_batches[j])
                            .completion_time();
                    let mut outcome = outs[k]
                        .take()
                        .expect("trainer must deliver every outcome");
                    // Encode/decode against the *assigned* global —
                    // the delta reference both ends hold at dispatch;
                    // by fold time the global may have moved on.
                    let (wire_bytes, restored) =
                        serialize::through_wire(
                            cfg.codec, outcome.trainable, &global,
                            &plan.device_configs[j], meta.n_layers,
                            rank_dim)?;
                    // Buffer the in-flight update at its own trained
                    // rank; the eq. 17 fold re-pads it to the full
                    // rank dimension when its event fires (layout.rs
                    // owns the one padding rule), so in-flight memory
                    // scales with the device's assigned rank, not
                    // r_max — and an update trained under an older
                    // plan folds unchanged.
                    outcome.trainable = serialize::trim_to_rank(
                        &restored, &plan.device_configs[j],
                        meta.n_layers, rank_dim);
                    pending.push(
                        EventKey { time: start + duration, device_id: i },
                        InFlight {
                            gen: h,
                            epoch,
                            duration,
                            wire_bytes,
                            outcome,
                            config: plan.device_configs[j].clone(),
                        },
                    );
                    busy.insert(i);
                }
            }

            // Commit horizon: every update that would exceed the
            // staleness cutoff S if it slipped past this window MUST
            // fold now, and each window folds at least one update.
            // With S = 0 the deadline is this window's own slowest
            // dispatch — the synchronous barrier.
            let must_deadline = pending
                .iter()
                .filter(|(_, f)| f.gen.saturating_add(s_max) <= h)
                .map(|(k, _)| k.time)
                .fold(f64::NEG_INFINITY, f64::max);
            let t_commit = if must_deadline > f64::NEG_INFINITY {
                must_deadline
            } else if let Some(k) = pending.peek_key() {
                k.time
            } else {
                start
            };

            // ⑤ drain everything landing by the horizon in
            // deterministic (time, device_id) event order — event
            // order decides *window membership* — then fold within
            // the window in ascending device order. That is exactly
            // the order the sync engine's sink folds in, so at S = 0
            // the aggregated model itself (not just the record) is
            // bitwise sync-identical for any trainer; the updates
            // were already buffered as in-flight events, so this
            // costs no extra memory.
            let mut drained: Vec<(EventKey, InFlight)> = Vec::new();
            while pending
                .peek_key()
                .is_some_and(|k| k.time <= t_commit)
            {
                drained.push(pending.pop().unwrap());
            }
            drained.sort_by_key(|(k, _)| k.device_id);
            // Async windows often fold a single update (the commit
            // rule closes at the earliest completion when nothing is
            // overdue); spawning shard worker threads for that would
            // cost more than the fold. Shard count never affects the
            // result bitwise (property-tested), so fold tiny windows
            // inline.
            let shard_cap = if cfg.window > 0 { cfg.window } else { 8 };
            let eff_shards =
                if drained.len() <= 1 { 1 } else { cfg.agg_shards };
            let mut agg = EdgeAggregator::new(
                &global, meta.n_layers, rank_dim, cfg.edge_aggregators,
                eff_shards, shard_cap, drained.len(),
            );
            agg.set_watermark(h.saturating_sub(s_max));
            // (device, completion relative to this window, loss, depth)
            let mut folded: Vec<(usize, f64, f64, usize)> = Vec::new();
            for (k, inf) in drained {
                let i = k.device_id;
                let tau = h - inf.gen;
                let w = staleness_weight(tau, s_max, alpha);
                // Arrival-time tally (this window's traffic), but the
                // message logs the round AND plan epoch the exchange
                // belongs to — the dispatch round's — not whichever
                // window/epoch happens to be current when a stale
                // update finally folds.
                transport.recv_update(inf.gen, inf.epoch, i,
                                      inf.wire_bytes);
                loss_log.insert(i, (h, inf.outcome.mean_loss));
                // Same-window folds keep their exact duration (the
                // sync-oracle path); spillovers are measured against
                // this window's start.
                let rel = if inf.gen == h {
                    inf.duration
                } else {
                    (k.time - start).max(0.0)
                };
                folded.push((i, rel, inf.outcome.mean_loss,
                             inf.config.depth(meta.n_layers)));
                let accepted = agg.push_versioned(inf.outcome.trainable,
                                                  &inf.config, w,
                                                  inf.gen)?;
                debug_assert!(accepted,
                              "commit rule violated the watermark");
                busy.remove(&i);
            }
            let tally = transport.round_tally();
            agg.finish(&mut global)?;

            // ⑥ timing + loss reductions — `folded` is already in
            // ascending device order, so the arithmetic (and thus the
            // record) is bit-stable and matches the sync engine when
            // S = 0.
            let timing = timing_from_pairs(
                folded.iter().map(|&(id, rel, _, _)| (id, rel)).collect(),
            );
            clock.advance(&timing);
            last_round_time = timing.round_time;
            let mut loss_sum = 0f64;
            for &(_, _, loss, _) in &folded {
                // detlint-allow: float-accum `folded` is already in ascending device order
                loss_sum += loss;
            }
            // Depth diagnostic over the configs the folded updates
            // *trained under* (their own InFlight configs — possibly
            // an older plan epoch), via the shared helper.
            let depths: Vec<usize> =
                folded.iter().map(|&(_, _, _, d)| d).collect();
            let mean_depth = mean_depth_of(&depths);

            // Evaluation of the aggregated global model.
            if h % cfg.eval_every == 0 || h == cfg.rounds {
                if let Some(ec) = &eval_config {
                    let eval_masks = Masks {
                        rank_mask: ec.rank_mask(meta.n_layers, rank_dim),
                        layer_mask: ec.layer_mask(meta.n_layers),
                    };
                    let (tl, ta) =
                        trainer.evaluate(&global, &eval_masks, &test)?;
                    last_acc = ta;
                    last_test_loss = tl;
                }
            }

            record.rounds.push(RoundRecord {
                round: h,
                sim_time: clock.elapsed,
                round_time: timing.round_time,
                avg_waiting: timing.avg_waiting,
                up_bytes: tally.uplink,
                down_bytes: tally.downlink,
                train_loss: loss_sum / folded.len().max(1) as f64,
                test_acc: last_acc,
                test_loss: last_test_loss,
                mean_depth,
                plan_epoch: epoch,
                participants: folded.len(),
                dropped,
            });
            if cfg.verbose {
                println!(
                    "[{}/{}] {} async(α={}, S={}) t={:.0}s acc={:.3} \
                     loss={:.3} epoch={} folded={} in-flight={}",
                    h,
                    cfg.rounds,
                    strategy.name(),
                    alpha,
                    s_max,
                    clock.elapsed,
                    last_acc,
                    loss_sum / folded.len().max(1) as f64,
                    epoch,
                    folded.len(),
                    pending.len(),
                );
            }
        }
        // Updates still in flight when the run ends are discarded —
        // the experiment is over and there is no later version to fold
        // them into.
        record.rank_realloc_epochs = realloc.epoch();
        Ok(record)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn staleness_weight_fresh_is_exactly_one() {
        assert_eq!(staleness_weight(0, 0, 0.5).to_bits(),
                   1.0f64.to_bits());
        assert_eq!(staleness_weight(0, 10, 3.0).to_bits(),
                   1.0f64.to_bits());
    }

    #[test]
    fn staleness_weight_clamps_beyond_cutoff() {
        assert_eq!(staleness_weight(1, 0, 0.5), 0.0);
        assert_eq!(staleness_weight(3, 2, 0.5), 0.0);
        assert!(staleness_weight(2, 2, 0.5) > 0.0);
    }

    #[test]
    fn staleness_weight_matches_formula() {
        let w = staleness_weight(3, 8, 2.0);
        assert!((w - 1.0 / 16.0).abs() < 1e-12);
        // α = 0: no discount inside the cutoff.
        assert_eq!(staleness_weight(5, 8, 0.0), 1.0);
        // Negative α is clamped to 0, never an amplifier.
        assert_eq!(staleness_weight(5, 8, -2.0), 1.0);
    }

    #[test]
    fn event_key_orders_by_time_then_id() {
        let a = EventKey { time: 1.0, device_id: 9 };
        let b = EventKey { time: 2.0, device_id: 0 };
        let c = EventKey { time: 1.0, device_id: 3 };
        assert!(a < b, "earlier time wins");
        assert!(c < a, "tie broken by device id");
        assert_eq!(a, EventKey { time: 1.0, device_id: 9 });
    }

    #[test]
    fn event_queue_pops_in_key_order() {
        let mut q: EventQueue<&'static str> = EventQueue::new();
        assert!(q.is_empty());
        q.push(EventKey { time: 2.0, device_id: 1 }, "late");
        q.push(EventKey { time: 1.0, device_id: 7 }, "tie-b");
        q.push(EventKey { time: 1.0, device_id: 2 }, "tie-a");
        assert_eq!(q.len(), 3);
        assert_eq!(q.peek_key().unwrap().device_id, 2);
        assert_eq!(q.pop().unwrap().1, "tie-a");
        assert_eq!(q.pop().unwrap().1, "tie-b");
        assert_eq!(q.pop().unwrap().1, "late");
        assert!(q.pop().is_none());
    }
}
