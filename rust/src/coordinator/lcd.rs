//! LCD — the LoRA Configuration Determination algorithm (Alg. 1, §4.4).
//!
//! Given per-device capacity estimates, LCD jointly picks each
//! device's LoRA depth and the global (arithmetic, eq. 10-compliant)
//! rank distribution, then greedily trims depths until the
//! device-specific compute (eq. 14) and communication (eq. 15)
//! budgets hold:
//!
//!  1. reference completion times t_i at full depth L;
//!  2. depth gap  k^h = ⌈L · (t_max − t_min)/t_max⌉;
//!  3. per-device k_i = ⌈k^h · (t_max − t_i)/t_max⌉,
//!     depth_i = L − k^h + k_i  (fastest → L, slowest → L − k^h);
//!  4. global ranks r_l = r_{l-1} + λ within total budget ψ;
//!  5. trim depth while eq. (14)/(15) are violated.

use crate::model::masks::{arithmetic_ranks, LayerSet, LoraConfig};

use super::capacity::Capacity;

/// Algorithm parameters (λ = 1, ψ defaults to Σ(1..L) as in §4.4).
#[derive(Debug, Clone)]
pub struct LcdParams {
    pub n_layers: usize,
    pub r_max: usize,
    /// Rank arithmetic-sequence common difference λ.
    pub lambda: usize,
    /// Rank of the shallowest layer (r_0).
    pub r0: usize,
    /// Total rank budget ψ over all L layers (eq. 11).
    pub psi: usize,
    /// Never assign less than this depth (a device must train
    /// something to contribute).
    pub min_depth: usize,
}

impl LcdParams {
    pub fn paper(n_layers: usize, r_max: usize) -> Self {
        LcdParams {
            n_layers,
            r_max,
            lambda: 1,
            r0: 1,
            psi: (1..=n_layers).sum(),
            min_depth: 1,
        }
    }
}

/// Per-device inputs to LCD for one round.
#[derive(Debug, Clone)]
pub struct LcdDevice {
    pub capacity: Capacity,
    /// Forward time per batch [s] (t̂ of eq. 12, per batch).
    pub fwd_time: f64,
    /// Local batches this round.
    pub n_batches: usize,
    /// Compute budget C_i: max per-round compute seconds (eq. 14's
    /// budget expressed in time — c·rank-units are seconds here).
    pub compute_budget: f64,
    /// Communication budget B_i: max upload bytes per round (eq. 15).
    pub comm_budget: usize,
    /// Bytes per unit-rank LoRA layer (to convert ranks → bytes).
    pub unit_rank_bytes: usize,
}

/// Sum of the ranks of the `k` DEEPEST layers — the layers a
/// depth-`k` device actually trains and uploads. Both the eq. 12
/// completion estimate and the eq. 15 upload-byte check hinge on this
/// sum; computing it in one place means they can never disagree about
/// which layers a depth buys.
pub fn deepest_rank_sum(ranks: &[usize], k: usize) -> usize {
    ranks.iter().rev().take(k).sum()
}

impl LcdDevice {
    /// Reference completion time at depth `k` with ranks `ranks`
    /// (eq. 12 with estimated capacities).
    pub fn est_completion(&self, k: usize, ranks: &[usize]) -> f64 {
        self.compute_seconds(k)
            + deepest_rank_sum(ranks, k) as f64 * self.capacity.beta
    }

    fn compute_seconds(&self, k: usize) -> f64 {
        self.n_batches as f64
            * (self.fwd_time + k as f64 * self.capacity.mu)
    }

    fn upload_bytes(&self, k: usize, ranks: &[usize]) -> usize {
        deepest_rank_sum(ranks, k) * self.unit_rank_bytes
    }
}

/// Run Alg. 1; returns one [`LoraConfig`] per device.
pub fn determine(params: &LcdParams, devices: &[LcdDevice])
                 -> Vec<LoraConfig> {
    assert!(!devices.is_empty());
    let l = params.n_layers;

    // Line 4 (order swapped, it's independent): the global rank
    // distribution shared by all devices this round.
    let ranks =
        arithmetic_ranks(l, params.lambda, params.r0, params.psi,
                         params.r_max);

    // Lines 2–3: depth from completion-time gaps at full depth.
    let t: Vec<f64> =
        devices.iter().map(|d| d.est_completion(l, &ranks)).collect();
    let t_max = t.iter().cloned().fold(f64::MIN, f64::max);
    let t_min = t.iter().cloned().fold(f64::MAX, f64::min);
    let gap = if t_max > 0.0 {
        ((l as f64) * (t_max - t_min) / t_max).ceil() as usize
    } else {
        0
    };
    let gap = gap.min(l - params.min_depth);

    // Line 3. NOTE: Alg. 1 writes k_i = ⌈k^h·(t^h − t_i)/t^h⌉, but §4.4's
    // prose requires the most powerful device to land exactly on depth L
    // and the weakest on L − k^h, which the literal formula misses
    // whenever ⌈·⌉ rounds differently for k^h and k_i. We normalize by
    // the span (t_max − t_min) so the endpoints match the stated intent.
    let span = (t_max - t_min).max(f64::MIN_POSITIVE);
    devices
        .iter()
        .zip(&t)
        .map(|(d, &ti)| {
            let ki = if t_max > t_min {
                ((gap as f64) * (t_max - ti) / span).ceil() as usize
            } else {
                gap
            };
            let mut depth = (l - gap + ki.min(gap)).max(params.min_depth);
            // Line 5: greedy trim until eq. (14)/(15) hold.
            while depth > params.min_depth
                && (d.compute_seconds(depth) > d.compute_budget
                    || d.upload_bytes(depth, &ranks) > d.comm_budget)
            {
                depth -= 1;
            }
            LoraConfig { layers: LayerSet::Depth(depth), ranks: ranks.clone() }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dev(mu: f64, beta: f64) -> LcdDevice {
        LcdDevice {
            capacity: Capacity { mu, beta },
            fwd_time: 0.3 * mu * 12.0,
            n_batches: 8,
            compute_budget: f64::MAX,
            comm_budget: usize::MAX,
            unit_rank_bytes: 2048,
        }
    }

    fn params() -> LcdParams {
        LcdParams::paper(12, 16)
    }

    #[test]
    fn fastest_gets_full_depth_slowest_gets_least() {
        let devices =
            vec![dev(0.005, 0.01), dev(0.05, 0.1), dev(0.5, 1.0)];
        let cfgs = determine(&params(), &devices);
        let depths: Vec<usize> =
            cfgs.iter().map(|c| c.depth(12)).collect();
        assert_eq!(depths[0], 12, "fastest device gets L");
        assert!(depths[2] < depths[1] && depths[1] < depths[0],
                "{depths:?} must decrease with slowness");
        assert!(depths[2] >= 1);
    }

    #[test]
    fn homogeneous_fleet_gets_uniform_full_depth() {
        let devices = vec![dev(0.01, 0.05); 6];
        let cfgs = determine(&params(), &devices);
        for c in &cfgs {
            assert_eq!(c.depth(12), 12);
        }
    }

    #[test]
    fn ranks_monotone_and_within_psi() {
        let devices = vec![dev(0.005, 0.01), dev(0.08, 0.4)];
        let cfgs = determine(&params(), &devices);
        for c in &cfgs {
            for w in c.ranks.windows(2) {
                assert!(w[0] <= w[1], "eq. 10 violated: {:?}", c.ranks);
            }
            assert!(c.ranks.iter().sum::<usize>() <= params().psi);
        }
    }

    #[test]
    fn compute_budget_trims_depth() {
        let mut d = dev(0.01, 0.001);
        // Allow only ~forward + 4 layers of backprop per round.
        d.compute_budget =
            8.0 * (d.fwd_time + 4.0 * d.capacity.mu) + 1e-9;
        let cfgs = determine(&params(), &[d]);
        assert!(cfgs[0].depth(12) <= 4, "depth {}", cfgs[0].depth(12));
    }

    #[test]
    fn comm_budget_trims_depth() {
        let mut d = dev(0.001, 0.5);
        // Budget covers only the deepest ~2 layers' ranks.
        let ranks = arithmetic_ranks(12, 1, 1, 78, 16);
        let two: usize = ranks[10..].iter().sum();
        d.comm_budget = two * d.unit_rank_bytes;
        let cfgs = determine(&params(), &[d]);
        assert!(cfgs[0].depth(12) <= 2);
    }

    #[test]
    fn min_depth_respected_under_impossible_budgets() {
        let mut d = dev(1.0, 10.0);
        d.compute_budget = 0.0;
        d.comm_budget = 0;
        let cfgs = determine(&params(), &[d]);
        assert_eq!(cfgs[0].depth(12), 1);
    }

    #[test]
    fn deepest_rank_sum_takes_the_last_k_layers() {
        let ranks: Vec<usize> = (1..=12).collect();
        assert_eq!(deepest_rank_sum(&ranks, 0), 0);
        assert_eq!(deepest_rank_sum(&ranks, 3), 10 + 11 + 12);
        assert_eq!(deepest_rank_sum(&ranks, 12), 78);
        // k beyond the layer count saturates at the full sum.
        assert_eq!(deepest_rank_sum(&ranks, 99), 78);
        // The two eq. 12/15 call sites must agree through the shared
        // helper: completion minus compute equals upload converted to
        // seconds-per-unit-rank for every depth.
        let d = dev(0.01, 0.1);
        for k in 0..=12 {
            let via_completion = d.est_completion(k, &ranks)
                - 8.0 * (d.fwd_time + k as f64 * 0.01);
            let via_bytes =
                d.upload_bytes(k, &ranks) as f64 / 2048.0 * 0.1;
            assert!((via_completion - via_bytes).abs() < 1e-9,
                    "depth {k}: {via_completion} vs {via_bytes}");
        }
    }

    #[test]
    fn est_completion_matches_eq12() {
        let d = dev(0.01, 0.1);
        let ranks: Vec<usize> = (1..=12).collect();
        // depth 3 → deepest ranks 10+11+12 = 33
        let t = d.est_completion(3, &ranks);
        let expect = 8.0 * (0.3 * 0.01 * 12.0 + 3.0 * 0.01) + 33.0 * 0.1;
        assert!((t - expect).abs() < 1e-12);
    }
}
