//! # Multi-job coordination — N tenants, one fleet, one engine
//!
//! One coordinator multiplexing N concurrent fine-tuning jobs over a
//! shared heterogeneous fleet (docs/MULTIJOB.md). Each job is a full
//! [`FedConfig`] + strategy + trainer + participation policy with its
//! own global model, round loop ([`super::engine::RoundLoopState`]),
//! transport endpoint and [`RunRecord`]; the scheduler owns what is a
//! property of the *fleet* rather than of any job: the shared
//! [`CapacityEstimator`], the per-round device partition, and the
//! admission ledger.
//!
//! Invariants (property-tested in `rust/tests/multi_job.rs`):
//!
//! * **Disjoint cohorts** — no device appears in two jobs' cohorts in
//!   the same global round. Jobs claim devices in a deterministic
//!   order; a later claimant loses contested devices and backfills
//!   from the fastest unclaimed devices the capacity estimator has
//!   seen.
//! * **Starvation-freedom** — a rotating guarantee slot puts one
//!   active job at the head of the claim order each round (round-robin
//!   over active jobs, ahead of priority), so every admitted job's
//!   cohort is non-empty at least once every `P = |active jobs|`
//!   rounds, however skewed the priorities.
//! * **Token-bucket rate limit** — per-job ingest is bounded by a
//!   [`TokenBucket`]: a job never folds more updates than its bucket
//!   grants, refill happens on round advance, and `reset`/`disable`
//!   restore the documented states exactly.
//! * **Admission control** — a job is rejected when the residual
//!   fleet capacity (fleet size minus the `min_cohort` reservations of
//!   already-admitted jobs) cannot meet its own `min_cohort`, or when
//!   its participation policy rejects the residual slice.
//! * **Determinism** — everything here is ordered collections and
//!   integer/`total_cmp` comparisons on the coordinator thread; fixed
//!   seed ⇒ bit-identical per-job `RunRecord`s at every threads ×
//!   agg-shards × window setting, and a single admitted job
//!   reproduces [`super::engine::RoundEngine::run`] bitwise.
//!
//! Capacity-awareness without breaking determinism: the scheduler
//! never calls `fleet.observe` itself — observation draws live in
//! per-`(device, round)` counter cells keyed by an observation
//! counter, so an extra scheduler-side draw would shift every job's
//! estimates. Only `step` observes (exactly as the single-job engine
//! does), and the shared estimator accumulates reports across all
//! jobs' cohorts.

use std::collections::{BTreeMap, BTreeSet};

use anyhow::{anyhow, Result};

use crate::data::Spec;
use crate::device::FleetView;
use crate::metrics::RunRecord;
use crate::model::state::TensorMap;

use super::capacity::CapacityEstimator;
use super::engine::RoundLoopState;
use super::participation::Participation;
use super::server::{FedConfig, ModelMeta};
use super::strategy::Strategy;
use super::trainer::Trainer;
use super::transport::Tally;

/// Token-bucket configuration for one job's coordinator ingest:
/// at most `burst` tokens held at any instant, `refill` added per
/// round advance. One token = one folded device update.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RateLimit {
    pub burst: usize,
    pub refill: usize,
}

/// Per-job ingest rate limiter.
///
/// Contract (property-tested):
/// * starts full (`tokens == burst`);
/// * [`TokenBucket::advance_round`] sets
///   `tokens = min(burst, tokens + refill)` — so over any window of
///   `w` round advances a job is granted at most `burst + w·refill`
///   tokens;
/// * [`TokenBucket::take`] grants `min(want, tokens)` and deducts the
///   grant;
/// * [`TokenBucket::reset`] restores the documented initial state
///   (a full bucket);
/// * [`TokenBucket::disable`] stops limiting — `available` reads
///   `usize::MAX` and `take` grants everything without deducting —
///   while the stored token level keeps refilling normally, so
///   [`TokenBucket::enable`] resumes exactly where an idle limiter
///   would have been.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TokenBucket {
    burst: usize,
    refill: usize,
    tokens: usize,
    enabled: bool,
}

impl TokenBucket {
    /// A bucket that starts full.
    pub fn new(burst: usize, refill: usize) -> Self {
        TokenBucket { burst, refill, tokens: burst, enabled: true }
    }

    /// A bucket that never limits (the default when no `--job-rate`
    /// is set). Equivalent to `new(0, 0)` + `disable()`.
    pub fn unlimited() -> Self {
        TokenBucket { burst: 0, refill: 0, tokens: 0, enabled: false }
    }

    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    /// Stored token level (meaningful even while disabled).
    pub fn tokens(&self) -> usize {
        self.tokens
    }

    /// Tokens a taker could get right now: `usize::MAX` when
    /// disabled, the stored level otherwise.
    pub fn available(&self) -> usize {
        if self.enabled {
            self.tokens
        } else {
            usize::MAX
        }
    }

    /// Consume up to `want` tokens; returns the grant. A disabled
    /// bucket grants everything and deducts nothing.
    pub fn take(&mut self, want: usize) -> usize {
        if !self.enabled {
            return want;
        }
        let grant = want.min(self.tokens);
        self.tokens -= grant;
        grant
    }

    /// Round advance: add `refill`, capped at `burst`. The stored
    /// level refills whether or not the limiter is enabled.
    pub fn advance_round(&mut self) {
        self.tokens = self.tokens.saturating_add(self.refill).min(self.burst);
    }

    /// Restore the documented initial state: a full bucket. Does not
    /// change enablement.
    pub fn reset(&mut self) {
        self.tokens = self.burst;
    }

    /// Stop limiting (grants become unlimited, nothing is deducted).
    pub fn disable(&mut self) {
        self.enabled = false;
    }

    /// Resume limiting from the stored token level.
    pub fn enable(&mut self) {
        self.enabled = true;
    }
}

/// One tenant's job description: a full [`FedConfig`] plus the
/// scheduling contract it is admitted under.
#[derive(Debug, Clone)]
pub struct JobSpec {
    pub cfg: FedConfig,
    /// Target accuracy. Metric-only unless `stop_at_target` is set.
    pub target_acc: f64,
    /// Claim-order priority: higher claims devices earlier (after the
    /// round's rotating guarantee slot); ties break by job id.
    pub priority: i64,
    /// Admission floor: the job is only admitted while the residual
    /// fleet capacity can reserve this many devices for it.
    pub min_cohort: usize,
    /// Ingest token bucket; `None` = unlimited.
    pub rate: Option<RateLimit>,
    /// Finish the job early once `target_acc` is reached (its
    /// reservation is released back to the residual pool). Off by
    /// default: the single-job engine never stops early, and
    /// `--jobs 1` must reproduce it bitwise.
    pub stop_at_target: bool,
}

impl JobSpec {
    pub fn new(cfg: FedConfig) -> Self {
        let target_acc = cfg.target_acc;
        JobSpec {
            cfg,
            target_acc,
            priority: 0,
            min_cohort: 1,
            rate: None,
            stop_at_target: false,
        }
    }
}

/// Why [`JobScheduler::admit`] rejected a job.
#[derive(Debug, Clone, PartialEq, thiserror::Error)]
pub enum AdmissionError {
    #[error("job needs a minimum cohort of at least 1 device")]
    EmptyMinCohort,
    #[error(
        "residual fleet capacity {residual} of {fleet} devices \
         cannot meet the job's minimum cohort {need}"
    )]
    InsufficientCapacity {
        need: usize,
        residual: usize,
        fleet: usize,
    },
    #[error("participation policy rejects the residual fleet slice: {0}")]
    Participation(String),
    #[error("job init: {0}")]
    Init(String),
}

struct JobEntry<'a> {
    spec: JobSpec,
    strategy: Box<dyn Strategy + 'a>,
    trainer: Box<dyn Trainer + 'a>,
    participation: Box<dyn Participation + 'a>,
    global: TensorMap,
    state: RoundLoopState,
    bucket: TokenBucket,
    finished: bool,
}

/// What a multi-job run produced.
#[derive(Debug)]
pub struct MultiJobReport {
    /// Per-job run records keyed by job id (admission order).
    pub records: BTreeMap<usize, RunRecord>,
    /// Per-job round tallies merged: total coordinator traffic.
    pub fleet_traffic: Tally,
    /// Per-global-round cohort assignment (job id → sorted device
    /// ids), recorded only under
    /// [`JobScheduler::record_cohorts`] — the invariant suite's
    /// direct evidence for disjointness and starvation-freedom.
    /// Empty when recording is off (the default: O(rounds · cohort)
    /// memory has no business in a production run).
    pub cohorts: Vec<BTreeMap<usize, Vec<usize>>>,
}

/// Capacity-aware multi-job scheduler. Admit jobs with
/// [`JobScheduler::admit`], then drive every admitted job to its
/// configured `rounds` with [`JobScheduler::run`].
pub struct JobScheduler<'a> {
    meta: ModelMeta,
    /// The shared data spec (task grammar); every job's shards and
    /// test set derive from it under the job's own seed.
    data: Spec,
    n_devices: usize,
    /// Σ min_cohort over admitted, unfinished jobs.
    reserved: usize,
    estimator: CapacityEstimator,
    jobs: Vec<JobEntry<'a>>,
    record_cohorts: bool,
}

impl<'a> JobScheduler<'a> {
    pub fn new(meta: ModelMeta, data: Spec, n_devices: usize) -> Self {
        JobScheduler {
            meta,
            data,
            n_devices,
            reserved: 0,
            estimator: CapacityEstimator::paper(n_devices),
            jobs: Vec::new(),
            record_cohorts: false,
        }
    }

    /// Record the per-round cohort partition into
    /// [`MultiJobReport::cohorts`] (test/diagnostic use).
    pub fn record_cohorts(&mut self, on: bool) {
        self.record_cohorts = on;
    }

    /// Devices not yet reserved by admitted jobs' minimum cohorts.
    pub fn residual_capacity(&self) -> usize {
        self.n_devices.saturating_sub(self.reserved)
    }

    pub fn n_jobs(&self) -> usize {
        self.jobs.len()
    }

    /// The starvation bound P: with J admitted jobs, every job's
    /// cohort is non-empty at least once every J rounds (while its
    /// bucket grants and its own `rounds` budget lasts).
    pub fn starvation_bound(&self) -> usize {
        self.jobs.len().max(1)
    }

    /// Admission control: reject when the residual fleet capacity
    /// cannot meet the job's minimum cohort, or when its participation
    /// policy cannot operate on the residual slice (e.g. an absolute
    /// `--sample-count` larger than what is left). On success the
    /// job's `min_cohort` is reserved and its job id returned.
    pub fn admit(&mut self, spec: JobSpec,
                 strategy: Box<dyn Strategy + 'a>,
                 trainer: Box<dyn Trainer + 'a>,
                 participation: Box<dyn Participation + 'a>,
                 global: TensorMap)
                 -> Result<usize, AdmissionError> {
        if spec.min_cohort == 0 {
            return Err(AdmissionError::EmptyMinCohort);
        }
        let residual = self.residual_capacity();
        if spec.min_cohort > residual {
            return Err(AdmissionError::InsufficientCapacity {
                need: spec.min_cohort,
                residual,
                fleet: self.n_devices,
            });
        }
        participation
            .validate(residual)
            .map_err(AdmissionError::Participation)?;
        let state = RoundLoopState::new(
            &spec.cfg, &self.meta, strategy.as_ref(), trainer.as_ref(),
            &self.data, self.n_devices, participation.as_ref(),
        )
        .map_err(|e| AdmissionError::Init(format!("{e:#}")))?;
        let bucket = match spec.rate {
            Some(r) => TokenBucket::new(r.burst, r.refill),
            None => TokenBucket::unlimited(),
        };
        self.reserved += spec.min_cohort;
        let id = self.jobs.len();
        self.jobs.push(JobEntry {
            spec,
            strategy,
            trainer,
            participation,
            global,
            state,
            bucket,
            finished: false,
        });
        Ok(id)
    }

    /// Deterministic claim order for global round `h` over the active
    /// jobs: the rotating guarantee slot first (active job at index
    /// `(h − 1) mod |active|` in job-id order — this is what bounds
    /// starvation at P = |active|), then the rest by descending
    /// priority, ties by ascending job id.
    fn claim_order(&self, h: usize) -> Vec<usize> {
        let active: Vec<usize> = self
            .jobs
            .iter()
            .enumerate()
            .filter(|(_, j)| !j.finished && h <= j.spec.cfg.rounds)
            .map(|(id, _)| id)
            .collect();
        if active.is_empty() {
            return active;
        }
        let pinned = active[(h - 1) % active.len()];
        let mut order = vec![pinned];
        let mut rest: Vec<usize> = active
            .iter()
            .copied()
            .filter(|&id| id != pinned)
            .collect();
        rest.sort_by_key(|&id| {
            (std::cmp::Reverse(self.jobs[id].spec.priority), id)
        });
        order.extend(rest);
        order
    }

    /// Drive every admitted job to its configured `rounds` over the
    /// shared fleet, one global round at a time: partition the fleet
    /// into disjoint per-job cohorts, step each job that holds both
    /// devices and tokens, and collect the per-job [`RunRecord`]s.
    pub fn run(mut self, fleet: &mut dyn FleetView)
               -> Result<MultiJobReport> {
        if fleet.len() != self.n_devices {
            return Err(anyhow!(
                "scheduler sized for {} devices, fleet has {}",
                self.n_devices,
                fleet.len()
            ));
        }
        if self.jobs.is_empty() {
            return Err(anyhow!("no jobs admitted"));
        }
        let last_round = self
            .jobs
            .iter()
            .map(|j| j.spec.cfg.rounds)
            .max()
            .unwrap_or(0);
        let mut fleet_traffic = Tally::default();
        let mut cohort_log: Vec<BTreeMap<usize, Vec<usize>>> =
            Vec::new();
        for h in 1..=last_round {
            if h > 1 {
                fleet.advance_round();
                for job in &mut self.jobs {
                    job.bucket.advance_round();
                }
            }
            let order = self.claim_order(h);
            let mut claimed: BTreeSet<usize> = BTreeSet::new();
            let mut round_cohorts: BTreeMap<usize, Vec<usize>> =
                BTreeMap::new();
            for id in order {
                let job = &mut self.jobs[id];
                // Consult the bucket BEFORE sampling: a job with no
                // tokens idles the whole round — no sample draw, no
                // observation, no record — so "never folds more than
                // the grant" is exact, and an idle round costs the
                // job's RNG streams nothing.
                let grant = job.bucket.available();
                if grant == 0 {
                    continue;
                }
                let sampled =
                    job.state.sample_cohort(job.participation.as_mut(), h);
                // Contested devices went to an earlier claimant this
                // round; backfill from the fastest unclaimed devices
                // the shared estimator knows.
                let mut cohort: Vec<usize> = sampled
                    .iter()
                    .copied()
                    .filter(|i| !claimed.contains(i))
                    .collect();
                let lost = sampled.len() - cohort.len();
                backfill(&mut cohort, &sampled, &claimed,
                         &self.estimator, lost);
                if cohort.is_empty() {
                    // Everything it wanted is taken and nothing is
                    // known to backfill from: the job sits this round
                    // out. The rotating guarantee slot bounds how
                    // often this can happen (head claimant never
                    // loses a device).
                    continue;
                }
                claimed.extend(cohort.iter().copied());
                let report = job.state.step(
                    &job.spec.cfg, &self.meta, fleet,
                    job.strategy.as_mut(), job.trainer.as_mut(),
                    &self.data, &mut job.global,
                    job.participation.as_mut(), &mut self.estimator,
                    h, &cohort, grant,
                )?;
                job.bucket.take(report.folded);
                fleet_traffic = fleet_traffic.merged(&report.tally);
                if self.record_cohorts {
                    round_cohorts.insert(id, cohort);
                }
                if job.spec.stop_at_target
                    && job.state.latest_accuracy() >= job.spec.target_acc
                {
                    job.finished = true;
                    self.reserved = self
                        .reserved
                        .saturating_sub(job.spec.min_cohort);
                }
            }
            if self.record_cohorts {
                cohort_log.push(round_cohorts);
            }
        }
        let records = self
            .jobs
            .into_iter()
            .enumerate()
            .map(|(id, j)| (id, j.state.finish()))
            .collect();
        Ok(MultiJobReport {
            records,
            fleet_traffic,
            cohorts: cohort_log,
        })
    }
}

/// Refill a cohort that lost contested devices to earlier claimants,
/// drawing up to `want` of the fastest unclaimed devices the shared
/// capacity estimator has seen (ascending μ under `total_cmp`, ties
/// by id), then restoring ascending-id order. Devices the estimator
/// has never seen are not candidates: their capacity is unknown, and
/// scanning the id space for them would be O(fleet) on a
/// lazily-derived million-device fleet.
fn backfill(cohort: &mut Vec<usize>, sampled: &[usize],
            claimed: &BTreeSet<usize>, estimator: &CapacityEstimator,
            want: usize) {
    if want == 0 {
        return;
    }
    let mut candidates: Vec<(f64, usize)> = estimator
        .seen()
        .filter(|(i, _)| {
            !claimed.contains(i) && sampled.binary_search(i).is_err()
        })
        .map(|(i, c)| (c.mu, i))
        .collect();
    candidates.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
    cohort.extend(candidates.into_iter().take(want).map(|(_, i)| i));
    cohort.sort_unstable();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn token_bucket_starts_full_and_caps_at_burst() {
        let mut b = TokenBucket::new(3, 2);
        assert_eq!(b.available(), 3);
        assert_eq!(b.take(5), 3);
        assert_eq!(b.available(), 0);
        b.advance_round();
        assert_eq!(b.available(), 2);
        b.advance_round();
        b.advance_round();
        assert_eq!(b.available(), 3, "refill saturates at burst");
    }

    #[test]
    fn token_bucket_reset_and_disable_contracts() {
        let mut b = TokenBucket::new(4, 1);
        assert_eq!(b.take(4), 4);
        b.reset();
        assert_eq!(b.available(), 4, "reset restores a full bucket");
        b.take(4);
        b.disable();
        assert_eq!(b.available(), usize::MAX);
        assert_eq!(b.take(100), 100, "disabled grants without deducting");
        b.advance_round();
        assert_eq!(b.tokens(), 1, "stored level keeps refilling");
        b.enable();
        assert_eq!(b.available(), 1, "enable resumes the stored level");
    }

    #[test]
    fn unlimited_bucket_never_limits() {
        let mut b = TokenBucket::unlimited();
        assert!(!b.is_enabled());
        assert_eq!(b.available(), usize::MAX);
        assert_eq!(b.take(1_000_000), 1_000_000);
        b.advance_round();
        assert_eq!(b.available(), usize::MAX);
    }

    #[test]
    fn backfill_prefers_fastest_seen_and_keeps_order() {
        let mut est = CapacityEstimator::paper(10);
        // seen: 1 (slow), 4 (fast), 7 (medium), 9 (claimed).
        est.update(1, 0.09, 0.9);
        est.update(4, 0.01, 0.1);
        est.update(7, 0.05, 0.5);
        est.update(9, 0.02, 0.2);
        let claimed: BTreeSet<usize> = [2, 9].into_iter().collect();
        // Sampled {2, 5}; device 2 was claimed → cohort {5}, lost 1.
        let mut cohort = vec![5];
        backfill(&mut cohort, &[2, 5], &claimed, &est, 1);
        assert_eq!(cohort, vec![4, 5], "fastest unclaimed seen device");
        // Wanting more than is known caps at what is known.
        let mut cohort = vec![5];
        backfill(&mut cohort, &[2, 5], &claimed, &est, 10);
        assert_eq!(cohort, vec![1, 4, 5, 7]);
    }

    #[test]
    fn backfill_never_duplicates_sampled_devices() {
        let mut est = CapacityEstimator::paper(10);
        est.update(3, 0.01, 0.1);
        est.update(6, 0.02, 0.2);
        let claimed = BTreeSet::new();
        // Device 3 is already in the sampled cohort: only 6 may fill.
        let mut cohort = vec![3];
        backfill(&mut cohort, &[3], &claimed, &est, 2);
        assert_eq!(cohort, vec![3, 6]);
    }
}
