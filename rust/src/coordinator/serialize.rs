//! Wire (de)serialization of LoRA payloads.
//!
//! The transport counts — and the tests round-trip — the exact bytes a
//! deployment would put on the wire: for each active layer `l`, the
//! first `r_l` rows of the A factors and columns of the B factors
//! (f32 little-endian), then the full head. Padded slots never travel;
//! this is what makes LEGEND's traffic numbers (Fig. 11) smaller than
//! FedLoRA's even though both share one padded artifact in memory.

use crate::model::masks::LoraConfig;
use crate::model::state::TensorMap;
use crate::model::TensorSpec;

/// How a trainable tensor maps to (layer, slot) cells; mirrors the
/// aggregation patterns.
fn slot_layout(spec: &TensorSpec, n_layers: usize, rank_dim: usize)
               -> Option<(bool, usize)> {
    // Returns (slot_on_axis1, inner) for [L, r, inner] (true) or
    // [L, inner, r] (false); None = full tensor (head).
    match spec.shape.as_slice() {
        [l, a, b] if *l == n_layers && *a == rank_dim => Some((true, *b)),
        [l, a, b] if *l == n_layers && *b == rank_dim => Some((false, *a)),
        [l, a] if *l == n_layers && *a == rank_dim => Some((true, 1)),
        _ => None,
    }
}

/// Bytes of the active payload for `config` (what actually travels).
pub fn active_payload_bytes(state: &TensorMap, config: &LoraConfig,
                            n_layers: usize, rank_dim: usize) -> usize {
    let mask = config.rank_mask(n_layers, rank_dim);
    let mut total = 0usize;
    for (spec, _) in &state.entries {
        match slot_layout(spec, n_layers, rank_dim) {
            None => total += spec.numel() * 4,
            Some((_, inner)) => {
                let active: usize =
                    mask.iter().map(|&m| m as usize).sum();
                total += active * inner * 4;
            }
        }
    }
    total
}

/// Serialize the active slots to wire bytes (f32 LE).
pub fn encode(state: &TensorMap, config: &LoraConfig, n_layers: usize,
              rank_dim: usize) -> Vec<u8> {
    let mask = config.rank_mask(n_layers, rank_dim);
    let mut out =
        Vec::with_capacity(active_payload_bytes(state, config, n_layers,
                                                rank_dim));
    let mut push = |x: f32| out.extend_from_slice(&x.to_le_bytes());
    for (spec, data) in &state.entries {
        match slot_layout(spec, n_layers, rank_dim) {
            None => {
                for &x in data {
                    push(x);
                }
            }
            Some((rows, inner)) => {
                for l in 0..n_layers {
                    for j in 0..rank_dim {
                        if mask[l * rank_dim + j] == 0.0 {
                            continue;
                        }
                        if rows {
                            let off = (l * rank_dim + j) * inner;
                            for &x in &data[off..off + inner] {
                                push(x);
                            }
                        } else {
                            let base = l * inner * rank_dim + j;
                            for i in 0..inner {
                                push(data[base + i * rank_dim]);
                            }
                        }
                    }
                }
            }
        }
    }
    out
}

#[derive(Debug, thiserror::Error)]
pub enum WireError {
    #[error("payload truncated: wanted {want} bytes, got {got}")]
    Truncated { want: usize, got: usize },
    #[error("trailing bytes: {0}")]
    Trailing(usize),
}

/// Decode wire bytes into `dest`'s active slots (inactive slots are
/// left untouched — they weren't transmitted).
pub fn decode(bytes: &[u8], dest: &mut TensorMap, config: &LoraConfig,
              n_layers: usize, rank_dim: usize) -> Result<(), WireError> {
    let want = active_payload_bytes(dest, config, n_layers, rank_dim);
    if bytes.len() < want {
        return Err(WireError::Truncated { want, got: bytes.len() });
    }
    let mask = config.rank_mask(n_layers, rank_dim);
    let mut off = 0usize;
    let mut next = |off: &mut usize| -> f32 {
        let v = f32::from_le_bytes(
            bytes[*off..*off + 4].try_into().unwrap());
        *off += 4;
        v
    };
    for (spec, data) in &mut dest.entries {
        match slot_layout(spec, n_layers, rank_dim) {
            None => {
                for x in data.iter_mut() {
                    *x = next(&mut off);
                }
            }
            Some((rows, inner)) => {
                for l in 0..n_layers {
                    for j in 0..rank_dim {
                        if mask[l * rank_dim + j] == 0.0 {
                            continue;
                        }
                        if rows {
                            let o = (l * rank_dim + j) * inner;
                            for x in &mut data[o..o + inner] {
                                *x = next(&mut off);
                            }
                        } else {
                            let base = l * inner * rank_dim + j;
                            for i in 0..inner {
                                data[base + i * rank_dim] =
                                    next(&mut off);
                            }
                        }
                    }
                }
            }
        }
    }
    if off != bytes.len() {
        return Err(WireError::Trailing(bytes.len() - off));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::masks::LayerSet;
    use crate::util::rng::Rng;

    const L: usize = 4;
    const R: usize = 3;
    const D: usize = 2;

    fn specs() -> Vec<TensorSpec> {
        vec![
            TensorSpec { name: "aq".into(), shape: vec![L, R, D] },
            TensorSpec { name: "bq".into(), shape: vec![L, D, R] },
            TensorSpec { name: "head_w".into(), shape: vec![D, 2] },
        ]
    }

    fn filled(seed: u64) -> TensorMap {
        let mut rng = Rng::new(seed);
        let mut t = TensorMap::zeros(&specs());
        for (_, v) in &mut t.entries {
            for x in v.iter_mut() {
                *x = rng.f32() - 0.5;
            }
        }
        t
    }

    #[test]
    fn roundtrip_restores_active_slots_only() {
        let src = filled(1);
        let cfg = LoraConfig {
            layers: LayerSet::Depth(2),
            ranks: vec![0, 0, 1, 3],
        };
        let wire = encode(&src, &cfg, L, R);
        assert_eq!(wire.len(), active_payload_bytes(&src, &cfg, L, R));

        let mut dst = filled(2);
        let before = dst.clone();
        decode(&wire, &mut dst, &cfg, L, R).unwrap();

        let mask = cfg.rank_mask(L, R);
        let aq_src = src.get("aq").unwrap();
        let aq_dst = dst.get("aq").unwrap();
        let aq_old = before.get("aq").unwrap();
        for l in 0..L {
            for j in 0..R {
                for i in 0..D {
                    let e = (l * R + j) * D + i;
                    if mask[l * R + j] > 0.0 {
                        assert_eq!(aq_dst[e], aq_src[e], "active e={e}");
                    } else {
                        assert_eq!(aq_dst[e], aq_old[e],
                                   "inactive e={e} must not travel");
                    }
                }
            }
        }
        // Head always travels.
        assert_eq!(dst.get("head_w").unwrap(),
                   src.get("head_w").unwrap());
    }

    #[test]
    fn payload_bytes_match_traffic_formula() {
        let src = filled(3);
        let cfg = LoraConfig {
            layers: LayerSet::Depth(2),
            ranks: vec![1, 1, 2, 3],
        };
        // active ranks = 2+3 = 5 slots; aq contributes 5·D, bq 5·D,
        // head D·2 floats.
        let want = (5 * D + 5 * D + D * 2) * 4;
        assert_eq!(active_payload_bytes(&src, &cfg, L, R), want);
    }

    #[test]
    fn truncated_payload_rejected() {
        let src = filled(4);
        let cfg = LoraConfig::uniform(LayerSet::All, 2, L);
        let wire = encode(&src, &cfg, L, R);
        let mut dst = filled(5);
        assert!(matches!(
            decode(&wire[..wire.len() - 4], &mut dst, &cfg, L, R),
            Err(WireError::Truncated { .. })
        ));
    }

    #[test]
    fn col_layout_roundtrips_exactly() {
        let src = filled(6);
        let cfg = LoraConfig::uniform(LayerSet::All, R, L);
        let wire = encode(&src, &cfg, L, R);
        let mut dst = TensorMap::zeros(&specs());
        decode(&wire, &mut dst, &cfg, L, R).unwrap();
        assert_eq!(dst.get("bq").unwrap(), src.get("bq").unwrap());
    }
}
