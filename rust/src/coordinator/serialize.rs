//! Wire (de)serialization of LoRA payloads + the update codec.
//!
//! The transport counts — and the tests round-trip — the exact bytes a
//! deployment would put on the wire: for each active layer `l`, the
//! first `r_l` rows of the A factors and columns of the B factors,
//! then the full head. Padded slots never travel; this is what makes
//! LEGEND's traffic numbers (Fig. 11) smaller than FedLoRA's even
//! though both share one padded artifact in memory.
//!
//! Which elements are "active" — and in what order they travel — is
//! decided by [`super::layout`], the same classifier the eq. 17
//! aggregators fold with, so the transmitted slots are by construction
//! the folded slots (`serialize` used to keep its own shape-only copy
//! of the rule and silently mis-laid-out square `[L, r, r]` B-side
//! tensors).
//!
//! On top of the raw f32 format sits the [`Codec`] layer
//! (`--codec none|int8|int4`): quantized modes ship each uplink tensor
//! as a 12-byte framed header (affine `scale`/`zero_point` + active
//! count) followed by packed int8 bytes or int4 nibbles of the
//! *delta* against the device's assigned global — deltas shrink with
//! convergence, which is what makes the low-bit range cheap. Encoding
//! happens on the device side of the exchange; the coordinator
//! dequantizes **exactly once** (in [`through_wire`]) before the i128
//! Q60 eq. 17 fold, so the fold itself stays bit-identical for a
//! fixed codec choice. `Codec::None` is a zero-copy pass-through of
//! today's wire format. Assignments (downlink) always travel f32:
//! quantizing the model a device trains *on* would perturb training
//! itself, not just the update in flight. See docs/TRANSPORT.md.

use crate::model::masks::LoraConfig;
use crate::model::state::TensorMap;
use crate::model::TensorSpec;

use super::layout::{self, classify, Pattern};

/// Update codec on the device → PS wire.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Codec {
    /// Raw f32 little-endian active slots — today's format, bitwise.
    None,
    /// Per-tensor affine int8 quantization of the delta vs the
    /// assigned global.
    Int8,
    /// Per-tensor affine int4 (packed nibbles) quantization of the
    /// delta vs the assigned global.
    Int4,
}

impl Codec {
    pub fn by_name(name: &str) -> anyhow::Result<Codec> {
        match name {
            "none" => Ok(Codec::None),
            "int8" => Ok(Codec::Int8),
            "int4" => Ok(Codec::Int4),
            other => Err(anyhow::anyhow!(
                "unknown codec '{other}' (expected none|int8|int4)"
            )),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Codec::None => "none",
            Codec::Int8 => "int8",
            Codec::Int4 => "int4",
        }
    }

    /// Quantized modes encode the delta vs the assigned global (the
    /// reference both ends already hold), not raw values.
    pub fn uses_delta(self) -> bool {
        !matches!(self, Codec::None)
    }

    /// Inclusive integer range of the quantized representation.
    fn qrange(self) -> (i32, i32) {
        match self {
            Codec::None => unreachable!("codec none has no qrange"),
            Codec::Int8 => (-128, 127),
            Codec::Int4 => (-8, 7),
        }
    }

    /// Packed bytes for `n` quantized values (headers not included).
    fn packed_len(self, n: usize) -> usize {
        match self {
            Codec::None => n * 4,
            Codec::Int8 => n,
            Codec::Int4 => (n + 1) / 2,
        }
    }
}

/// Per-tensor framed header of the quantized formats: `scale` (f32 LE)
/// + `zero_point` (i32 LE) + active-value count (u32 LE).
pub const TENSOR_HEADER_BYTES: usize = 12;

/// The active element indices of one tensor, in canonical wire order.
fn active_indices(spec: &TensorSpec, mask: &[f32], n_layers: usize,
                  rank_dim: usize) -> Vec<usize> {
    match classify(spec, n_layers, rank_dim) {
        Pattern::Full => (0..spec.numel()).collect(),
        pat => {
            let mut idx =
                Vec::with_capacity(layout::active_elems(spec, mask,
                                                        n_layers,
                                                        rank_dim));
            layout::for_each_active(pat, n_layers, mask,
                                    |e| idx.push(e));
            idx
        }
    }
}

/// Bytes of the raw-f32 active payload for `config` (what travels
/// under `Codec::None`, and on every assignment downlink).
pub fn active_payload_bytes(state: &TensorMap, config: &LoraConfig,
                            n_layers: usize, rank_dim: usize) -> usize {
    let mask = config.rank_mask(n_layers, rank_dim);
    state
        .entries
        .iter()
        .map(|(spec, _)| {
            layout::active_elems(spec, &mask, n_layers, rank_dim) * 4
        })
        .sum()
}

/// Bytes `encode_update` will produce for `state` under `codec`.
pub fn encoded_len(codec: Codec, state: &TensorMap, config: &LoraConfig,
                   n_layers: usize, rank_dim: usize) -> usize {
    if codec == Codec::None {
        return active_payload_bytes(state, config, n_layers, rank_dim);
    }
    let mask = config.rank_mask(n_layers, rank_dim);
    state
        .entries
        .iter()
        .map(|(spec, _)| {
            let n =
                layout::active_elems(spec, &mask, n_layers, rank_dim);
            TENSOR_HEADER_BYTES + codec.packed_len(n)
        })
        .sum()
}

/// Serialize the active slots to wire bytes (f32 LE) — the
/// `Codec::None` format.
pub fn encode(state: &TensorMap, config: &LoraConfig, n_layers: usize,
              rank_dim: usize) -> Vec<u8> {
    let mask = config.rank_mask(n_layers, rank_dim);
    let mut out =
        Vec::with_capacity(active_payload_bytes(state, config, n_layers,
                                                rank_dim));
    for (spec, data) in &state.entries {
        for e in active_indices(spec, &mask, n_layers, rank_dim) {
            out.extend_from_slice(&data[e].to_le_bytes());
        }
    }
    out
}

#[derive(Debug, thiserror::Error)]
pub enum WireError {
    #[error("payload truncated: wanted {want} bytes, got {got}")]
    Truncated { want: usize, got: usize },
    #[error("trailing bytes: {0}")]
    Trailing(usize),
    #[error("bad tensor header at byte {at}: {why}")]
    BadHeader { at: usize, why: &'static str },
    #[error("active-count mismatch for {tensor}: header says {got}, \
             config implies {want}")]
    CountMismatch { tensor: String, want: usize, got: usize },
}

/// Decode raw-f32 wire bytes into `dest`'s active slots (inactive
/// slots are left untouched — they weren't transmitted).
pub fn decode(bytes: &[u8], dest: &mut TensorMap, config: &LoraConfig,
              n_layers: usize, rank_dim: usize) -> Result<(), WireError> {
    let want = active_payload_bytes(dest, config, n_layers, rank_dim);
    if bytes.len() < want {
        return Err(WireError::Truncated { want, got: bytes.len() });
    }
    let mask = config.rank_mask(n_layers, rank_dim);
    let mut off = 0usize;
    for (spec, data) in &mut dest.entries {
        for e in active_indices(spec, &mask, n_layers, rank_dim) {
            data[e] = f32::from_le_bytes(
                bytes[off..off + 4].try_into().expect("checked above"));
            off += 4;
        }
    }
    if off != bytes.len() {
        return Err(WireError::Trailing(bytes.len() - off));
    }
    Ok(())
}

/// Affine quantization parameters mapping `[min, max]` onto
/// `[qmin, qmax]`. Degenerate inputs (empty range, NaN, zero or
/// non-finite spread) fall back to `(1.0, 0)` so the codec stays total
/// and deterministic. All arithmetic is f64 with a single f32/i32
/// store, so both ends recompute nothing — the header is authoritative.
fn affine_params(min: f32, max: f32, qmin: i32, qmax: i32)
                 -> (f32, i32) {
    if !(min <= max) || !min.is_finite() || !max.is_finite() {
        return (1.0, 0);
    }
    let range = max as f64 - min as f64;
    let scale = (range / (qmax - qmin) as f64) as f32;
    if !scale.is_finite() || scale <= 0.0 {
        return (1.0, 0);
    }
    // Place zero_point so `min` maps to `qmin`; saturating f64→i32
    // cast keeps pathological ranges deterministic instead of UB.
    let zp = (qmin as f64 - (min as f64 / scale as f64).round()) as i32;
    (scale, zp)
}

/// Quantize one value under `(scale, zp)` into `[qmin, qmax]`.
/// NaN maps to 0 (then clamped) via the saturating cast —
/// deterministic.
fn q_of(x: f32, scale: f32, zp: i32, qmin: i32, qmax: i32) -> i32 {
    let q = (x as f64 / scale as f64).round() + zp as f64;
    (q as i32).clamp(qmin, qmax)
}

/// Dequantize one value. i64 intermediate: a corrupt wire header can
/// carry any i32 `zp`, and `q - zp` must not overflow.
fn dq_of(q: i32, scale: f32, zp: i32) -> f32 {
    ((q as i64 - zp as i64) as f64 * scale as f64) as f32
}

/// Encode `update` under `codec` for the wire. Quantized modes frame
/// each tensor as [`TENSOR_HEADER_BYTES`] + packed values of the delta
/// `update − reference` over the active elements in canonical layout
/// order; `Codec::None` is the raw f32 format (reference unused).
pub fn encode_update(codec: Codec, update: &TensorMap,
                     reference: &TensorMap, config: &LoraConfig,
                     n_layers: usize, rank_dim: usize) -> Vec<u8> {
    if codec == Codec::None {
        return encode(update, config, n_layers, rank_dim);
    }
    let (qmin, qmax) = codec.qrange();
    let mask = config.rank_mask(n_layers, rank_dim);
    let mut out = Vec::with_capacity(encoded_len(codec, update, config,
                                                 n_layers, rank_dim));
    for (spec, data) in &update.entries {
        let refd = reference
            .get(&spec.name)
            .expect("reference missing tensor");
        let idx = active_indices(spec, &mask, n_layers, rank_dim);
        let mut lo = f32::INFINITY;
        let mut hi = f32::NEG_INFINITY;
        for &e in &idx {
            let v = data[e] - refd[e];
            lo = lo.min(v);
            hi = hi.max(v);
        }
        let (scale, zp) = affine_params(lo, hi, qmin, qmax);
        out.extend_from_slice(&scale.to_le_bytes());
        out.extend_from_slice(&zp.to_le_bytes());
        out.extend_from_slice(&(idx.len() as u32).to_le_bytes());
        match codec {
            Codec::None => unreachable!(),
            Codec::Int8 => {
                for &e in &idx {
                    let q = q_of(data[e] - refd[e], scale, zp, qmin,
                                 qmax);
                    out.push(q as i8 as u8);
                }
            }
            Codec::Int4 => {
                // Two values per byte, low nibble first; nibbles store
                // q + 8 ∈ [0, 15]. Odd tail leaves the high nibble 0.
                let mut pending: Option<u8> = None;
                for &e in &idx {
                    let u = (q_of(data[e] - refd[e], scale, zp, qmin,
                                  qmax)
                             + 8) as u8;
                    match pending.take() {
                        Option::None => pending = Some(u),
                        Some(lo_nib) => out.push(lo_nib | (u << 4)),
                    }
                }
                if let Some(lo_nib) = pending {
                    out.push(lo_nib);
                }
            }
        }
    }
    out
}

/// Decode `encode_update` output into `dest`'s active slots, adding
/// the dequantized delta back onto `reference` (the assigned global
/// both ends hold). Never panics on truncated, corrupted, or trailing
/// bytes — every malformed input maps to a [`WireError`].
pub fn decode_update(codec: Codec, bytes: &[u8], dest: &mut TensorMap,
                     reference: &TensorMap, config: &LoraConfig,
                     n_layers: usize, rank_dim: usize)
                     -> Result<(), WireError> {
    if codec == Codec::None {
        return decode(bytes, dest, config, n_layers, rank_dim);
    }
    let mask = config.rank_mask(n_layers, rank_dim);
    let mut off = 0usize;
    for (spec, data) in &mut dest.entries {
        let refd = reference
            .get(&spec.name)
            .expect("reference missing tensor");
        let idx = active_indices(spec, &mask, n_layers, rank_dim);
        if bytes.len() < off + TENSOR_HEADER_BYTES {
            return Err(WireError::Truncated {
                want: off + TENSOR_HEADER_BYTES,
                got: bytes.len(),
            });
        }
        let scale = f32::from_le_bytes(
            bytes[off..off + 4].try_into().expect("checked above"));
        let zp = i32::from_le_bytes(
            bytes[off + 4..off + 8].try_into().expect("checked above"));
        let count = u32::from_le_bytes(
            bytes[off + 8..off + 12].try_into().expect("checked above"))
            as usize;
        if !scale.is_finite() || scale <= 0.0 {
            return Err(WireError::BadHeader {
                at: off,
                why: "scale must be finite and positive",
            });
        }
        off += TENSOR_HEADER_BYTES;
        if count != idx.len() {
            return Err(WireError::CountMismatch {
                tensor: spec.name.clone(),
                want: idx.len(),
                got: count,
            });
        }
        let nbytes = codec.packed_len(count);
        if bytes.len() < off + nbytes {
            return Err(WireError::Truncated {
                want: off + nbytes,
                got: bytes.len(),
            });
        }
        match codec {
            Codec::None => unreachable!(),
            Codec::Int8 => {
                for (i, &e) in idx.iter().enumerate() {
                    let q = bytes[off + i] as i8 as i32;
                    data[e] = refd[e] + dq_of(q, scale, zp);
                }
            }
            Codec::Int4 => {
                for (i, &e) in idx.iter().enumerate() {
                    let byte = bytes[off + i / 2];
                    let nib =
                        if i % 2 == 0 { byte & 0x0f } else { byte >> 4 };
                    let q = nib as i32 - 8;
                    data[e] = refd[e] + dq_of(q, scale, zp);
                }
            }
        }
        off += nbytes;
    }
    if off != bytes.len() {
        return Err(WireError::Trailing(bytes.len() - off));
    }
    Ok(())
}

/// One device → PS exchange through the codec: encode on the device
/// side, decode exactly once on the coordinator side, and report the
/// real bytes that travelled. Returns `(wire_bytes, restored_update)`
/// where `restored_update` is what the eq. 17 fold must consume — for
/// `Codec::None` that is the update itself, untouched (bitwise
/// pass-through, no copy); for quantized codecs it is the reference
/// plus the dequantized delta.
pub fn through_wire(codec: Codec, update: TensorMap,
                    reference: &TensorMap, config: &LoraConfig,
                    n_layers: usize, rank_dim: usize)
                    -> Result<(usize, TensorMap), WireError> {
    if codec == Codec::None {
        let bytes =
            active_payload_bytes(&update, config, n_layers, rank_dim);
        return Ok((bytes, update));
    }
    let wire = encode_update(codec, &update, reference, config,
                             n_layers, rank_dim);
    let mut restored = reference.clone();
    decode_update(codec, &wire, &mut restored, reference, config,
                  n_layers, rank_dim)?;
    Ok((wire.len(), restored))
}

/// Re-lay-out a trained update at the smallest rank dimension that
/// keeps every active slot: each rank-sloted tensor drops from the
/// run's `rank_dim` to the device's [`LoraConfig::max_active_rank`]
/// (`Full` tensors — the head — are untouched). This is the exact
/// inverse of [`layout::pad_to_rank`] on the slots that matter: active
/// slots `j < r_l ≤ r_dst` survive in their `(l, j)` cell, and the
/// dropped slots are inactive under `config`'s mask, so they neither
/// travel nor fold. The async engine buffers in-flight updates in this
/// form — O(device rank) instead of O(r_max) per tensor — and the
/// aggregators pad them back through the single padding rule on fold.
pub fn trim_to_rank(update: &TensorMap, config: &LoraConfig,
                    n_layers: usize, rank_dim: usize) -> TensorMap {
    let r_dst = config.max_active_rank(n_layers).min(rank_dim).max(1);
    let entries = update
        .entries
        .iter()
        .map(|(spec, data)| match classify(spec, n_layers, rank_dim) {
            Pattern::Full => (spec.clone(), data.clone()),
            _ if r_dst == rank_dim => (spec.clone(), data.clone()),
            Pattern::Rows { r, inner } => {
                let mut out = vec![0.0f32; n_layers * r_dst * inner];
                for l in 0..n_layers {
                    for j in 0..r_dst {
                        let src = (l * r + j) * inner;
                        let dst = (l * r_dst + j) * inner;
                        out[dst..dst + inner]
                            .copy_from_slice(&data[src..src + inner]);
                    }
                }
                let shape = if spec.shape.len() == 2 {
                    vec![n_layers, r_dst]
                } else {
                    vec![n_layers, r_dst, inner]
                };
                (TensorSpec { name: spec.name.clone(), shape }, out)
            }
            Pattern::Cols { r, inner } => {
                let mut out = vec![0.0f32; n_layers * inner * r_dst];
                for l in 0..n_layers {
                    for i in 0..inner {
                        let src = l * inner * r + i * r;
                        let dst = l * inner * r_dst + i * r_dst;
                        out[dst..dst + r_dst]
                            .copy_from_slice(&data[src..src + r_dst]);
                    }
                }
                let shape = vec![n_layers, inner, r_dst];
                (TensorSpec { name: spec.name.clone(), shape }, out)
            }
        })
        .collect();
    TensorMap { entries }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::masks::LayerSet;
    use crate::util::rng::Rng;

    const L: usize = 4;
    const R: usize = 3;
    const D: usize = 2;

    fn specs() -> Vec<TensorSpec> {
        vec![
            TensorSpec { name: "aq".into(), shape: vec![L, R, D] },
            TensorSpec { name: "bq".into(), shape: vec![L, D, R] },
            TensorSpec { name: "head_w".into(), shape: vec![D, 2] },
        ]
    }

    fn filled_of(seed: u64, specs: &[TensorSpec]) -> TensorMap {
        let mut rng = Rng::new(seed);
        let mut t = TensorMap::zeros(specs);
        for (_, v) in &mut t.entries {
            for x in v.iter_mut() {
                *x = rng.f32() - 0.5;
            }
        }
        t
    }

    fn filled(seed: u64) -> TensorMap {
        filled_of(seed, &specs())
    }

    #[test]
    fn roundtrip_restores_active_slots_only() {
        let src = filled(1);
        let cfg = LoraConfig {
            layers: LayerSet::Depth(2),
            ranks: vec![0, 0, 1, 3],
        };
        let wire = encode(&src, &cfg, L, R);
        assert_eq!(wire.len(), active_payload_bytes(&src, &cfg, L, R));

        let mut dst = filled(2);
        let before = dst.clone();
        decode(&wire, &mut dst, &cfg, L, R).unwrap();

        let mask = cfg.rank_mask(L, R);
        let aq_src = src.get("aq").unwrap();
        let aq_dst = dst.get("aq").unwrap();
        let aq_old = before.get("aq").unwrap();
        for l in 0..L {
            for j in 0..R {
                for i in 0..D {
                    let e = (l * R + j) * D + i;
                    if mask[l * R + j] > 0.0 {
                        assert_eq!(aq_dst[e], aq_src[e], "active e={e}");
                    } else {
                        assert_eq!(aq_dst[e], aq_old[e],
                                   "inactive e={e} must not travel");
                    }
                }
            }
        }
        // Head always travels.
        assert_eq!(dst.get("head_w").unwrap(),
                   src.get("head_w").unwrap());
    }

    #[test]
    fn payload_bytes_match_traffic_formula() {
        let src = filled(3);
        let cfg = LoraConfig {
            layers: LayerSet::Depth(2),
            ranks: vec![1, 1, 2, 3],
        };
        // active ranks = 2+3 = 5 slots; aq contributes 5·D, bq 5·D,
        // head D·2 floats.
        let want = (5 * D + 5 * D + D * 2) * 4;
        assert_eq!(active_payload_bytes(&src, &cfg, L, R), want);
    }

    #[test]
    fn truncated_payload_rejected() {
        let src = filled(4);
        let cfg = LoraConfig::uniform(LayerSet::All, 2, L);
        let wire = encode(&src, &cfg, L, R);
        let mut dst = filled(5);
        assert!(matches!(
            decode(&wire[..wire.len() - 4], &mut dst, &cfg, L, R),
            Err(WireError::Truncated { .. })
        ));
    }

    #[test]
    fn col_layout_roundtrips_exactly() {
        let src = filled(6);
        let cfg = LoraConfig::uniform(LayerSet::All, R, L);
        let wire = encode(&src, &cfg, L, R);
        let mut dst = TensorMap::zeros(&specs());
        decode(&wire, &mut dst, &cfg, L, R).unwrap();
        assert_eq!(dst.get("bq").unwrap(), src.get("bq").unwrap());
    }

    #[test]
    fn square_b_tensor_travels_along_last_axis() {
        // Wire-level regression mirroring aggregation's
        // `square_b_tensor_aggregates_along_last_axis`: encode a
        // rank-1 update of a square bq, decode into a zeroed map, and
        // the aggregator-active slots — column 0 of every row, i.e.
        // elements with e % R == 0 — must be exactly the ones
        // restored. Under the old shape-only `slot_layout`, squares
        // always travelled row-major (the first R elements of each
        // layer) and the transmitted slots were not the folded slots.
        let sq = vec![TensorSpec {
            name: "bq".into(),
            shape: vec![L, R, R],
        }];
        let mut src = TensorMap::zeros(&sq);
        for (_, v) in &mut src.entries {
            v.iter_mut().for_each(|x| *x = 7.0);
        }
        let cfg = LoraConfig {
            layers: LayerSet::Depth(L),
            ranks: vec![1; L],
        };
        let wire = encode(&src, &cfg, L, R);
        assert_eq!(wire.len(), L * R * 4,
                   "rank-1 square bq ships R values per layer");
        let mut dst = TensorMap::zeros(&sq);
        decode(&wire, &mut dst, &cfg, L, R).unwrap();
        for (e, &v) in dst.get("bq").unwrap().iter().enumerate() {
            let want = if e % R == 0 { 7.0 } else { 0.0 };
            assert_eq!(v, want, "bq[{e}]");
        }
    }

    #[test]
    fn codec_none_is_byte_identical_and_pass_through() {
        let src = filled(7);
        let zero = TensorMap::zeros(&specs());
        let cfg = LoraConfig {
            layers: LayerSet::Depth(3),
            ranks: vec![0, 1, 2, 3],
        };
        let legacy = encode(&src, &cfg, L, R);
        let coded = encode_update(Codec::None, &src, &zero, &cfg, L, R);
        assert_eq!(legacy, coded, "codec=none must be today's bytes");
        let (bytes, restored) =
            through_wire(Codec::None, src.clone(), &zero, &cfg, L, R)
                .unwrap();
        assert_eq!(bytes, legacy.len());
        assert_eq!(restored, src, "pass-through must be bitwise");
    }

    #[test]
    fn quantized_roundtrip_error_within_affine_bound() {
        for codec in [Codec::Int8, Codec::Int4] {
            let (qmin, qmax) = codec.qrange();
            let steps = (qmax - qmin) as f64;
            for seed in 1..=8u64 {
                let update = filled_of(seed, &specs());
                let reference = filled_of(seed + 100, &specs());
                let cfg = LoraConfig {
                    layers: LayerSet::Depth(3),
                    ranks: vec![1, 1, 2, 3],
                };
                let (bytes, restored) = through_wire(
                    codec, update.clone(), &reference, &cfg, L, R)
                    .unwrap();
                assert_eq!(bytes,
                           encoded_len(codec, &update, &cfg, L, R));
                let mask = cfg.rank_mask(L, R);
                for (spec, got) in &restored.entries {
                    let want = update.get(&spec.name).unwrap();
                    let refd = reference.get(&spec.name).unwrap();
                    let idx = active_indices(spec, &mask, L, R);
                    // Per-tensor bound: one quantization step of the
                    // delta range (+ f32 slack).
                    let mut lo = f32::INFINITY;
                    let mut hi = f32::NEG_INFINITY;
                    for &e in &idx {
                        let v = want[e] - refd[e];
                        lo = lo.min(v);
                        hi = hi.max(v);
                    }
                    let bound =
                        ((hi as f64 - lo as f64) / steps).max(1e-7)
                            * (1.0 + 1e-4);
                    for &e in &idx {
                        let err = (got[e] as f64 - want[e] as f64).abs();
                        assert!(
                            err <= bound,
                            "{:?} {}[{e}]: |{} - {}| = {err} > {bound}",
                            codec, spec.name, got[e], want[e]
                        );
                    }
                    // Inactive slots restore to the reference exactly.
                    let active: std::collections::BTreeSet<usize> =
                        idx.iter().copied().collect();
                    for e in 0..got.len() {
                        if !active.contains(&e) {
                            assert_eq!(got[e], refd[e],
                                       "inactive {}[{e}]", spec.name);
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn quantized_wire_is_smaller_than_f32() {
        let src = filled(9);
        let cfg = LoraConfig::uniform(LayerSet::All, R, L);
        let f32_bytes = active_payload_bytes(&src, &cfg, L, R);
        let i8_bytes = encoded_len(Codec::Int8, &src, &cfg, L, R);
        let i4_bytes = encoded_len(Codec::Int4, &src, &cfg, L, R);
        assert!(i8_bytes < f32_bytes, "{i8_bytes} !< {f32_bytes}");
        assert!(i4_bytes < i8_bytes, "{i4_bytes} !< {i8_bytes}");
    }

    #[test]
    fn constant_delta_roundtrips_exactly() {
        // A degenerate (zero-range) delta must hit the scale fallback
        // and restore exactly: q == zp everywhere ⇒ dq == 0.
        let reference = filled(10);
        let update = reference.clone();
        let cfg = LoraConfig::uniform(LayerSet::All, R, L);
        for codec in [Codec::Int8, Codec::Int4] {
            let (_, restored) = through_wire(
                codec, update.clone(), &reference, &cfg, L, R)
                .unwrap();
            assert_eq!(restored, update, "{codec:?}");
        }
    }

    #[test]
    fn decode_never_panics_on_malformed_bytes() {
        let update = filled(11);
        let reference = filled(12);
        let cfg = LoraConfig {
            layers: LayerSet::Depth(3),
            ranks: vec![2, 1, 2, 3],
        };
        for codec in [Codec::None, Codec::Int8, Codec::Int4] {
            let wire = encode_update(codec, &update, &reference, &cfg,
                                     L, R);
            // Every truncation prefix is rejected, never a panic.
            for cut in 0..wire.len() {
                let mut dst = reference.clone();
                assert!(
                    decode_update(codec, &wire[..cut], &mut dst,
                                  &reference, &cfg, L, R)
                        .is_err(),
                    "{codec:?}: prefix {cut}/{} accepted", wire.len()
                );
            }
            // Trailing garbage is rejected.
            let mut long = wire.clone();
            long.extend_from_slice(&[0xAB; 3]);
            let mut dst = reference.clone();
            assert!(matches!(
                decode_update(codec, &long, &mut dst, &reference, &cfg,
                              L, R),
                Err(WireError::Trailing(3))
            ));
            // Single-byte corruption anywhere either decodes to
            // *something* or errors — never panics. (Headers carry
            // scale/zp/count; bit-flipped counts and scales must be
            // caught, value bytes are always in-range by
            // construction.)
            for i in 0..wire.len() {
                let mut bad = wire.clone();
                bad[i] ^= 0xFF;
                let mut dst = reference.clone();
                let _ = decode_update(codec, &bad, &mut dst, &reference,
                                      &cfg, L, R);
            }
        }
    }

    #[test]
    fn corrupt_headers_reported_as_wire_errors() {
        let update = filled(13);
        let reference = filled(14);
        let cfg = LoraConfig::uniform(LayerSet::All, 2, L);
        let wire = encode_update(Codec::Int8, &update, &reference, &cfg,
                                 L, R);
        // Non-finite scale in the first header.
        let mut bad = wire.clone();
        bad[0..4].copy_from_slice(&f32::NAN.to_le_bytes());
        let mut dst = reference.clone();
        assert!(matches!(
            decode_update(Codec::Int8, &bad, &mut dst, &reference, &cfg,
                          L, R),
            Err(WireError::BadHeader { at: 0, .. })
        ));
        // Wrong active count in the first header.
        let mut bad = wire.clone();
        bad[8..12].copy_from_slice(&u32::MAX.to_le_bytes());
        let mut dst = reference.clone();
        assert!(matches!(
            decode_update(Codec::Int8, &bad, &mut dst, &reference, &cfg,
                          L, R),
            Err(WireError::CountMismatch { .. })
        ));
    }

    #[test]
    fn trim_to_rank_is_the_inverse_of_pad_on_active_slots() {
        let src = filled(20);
        let cfg = LoraConfig {
            layers: LayerSet::Depth(2),
            ranks: vec![0, 0, 1, 2],
        };
        let trimmed = trim_to_rank(&src, &cfg, L, R);
        // max_active_rank = 2 of R = 3: rank-sloted tensors shrink,
        // the head does not.
        assert_eq!(trimmed.spec("aq").unwrap().shape, vec![L, 2, D]);
        assert_eq!(trimmed.spec("bq").unwrap().shape, vec![L, D, 2]);
        assert_eq!(trimmed.get("head_w").unwrap(),
                   src.get("head_w").unwrap());
        // Specs stay consistent with their data.
        for (spec, v) in &trimmed.entries {
            assert_eq!(spec.numel(), v.len(), "{}", spec.name);
        }
        // Padding back restores every active element bitwise.
        let mask = cfg.rank_mask(L, R);
        for name in ["aq", "bq"] {
            let spec = src.spec(name).unwrap();
            let pat = classify(spec, L, R);
            let padded = layout::pad_to_rank(
                pat, L, trimmed.get(name).unwrap().to_vec())
                .unwrap();
            let orig = src.get(name).unwrap();
            layout::for_each_active(pat, L, &mask, |e| {
                assert_eq!(padded[e], orig[e], "{name}[{e}]");
            });
        }
        // A config already at full rank trims to an identical map.
        let full = LoraConfig::uniform(LayerSet::All, R, L);
        assert_eq!(trim_to_rank(&src, &full, L, R), src);
    }

    #[test]
    fn padded_square_b_tensor_folds_like_the_unpadded_reference() {
        // Hetero-rank × codec: a device stores its square [L, r, r]
        // B-side update at its own max rank, the coordinator zero-pads
        // it back through layout::pad_to_rank, ships it through every
        // codec, and the eq. 17 fold of what comes off the wire is
        // bit-identical to folding the unpadded original.
        use super::super::aggregation::{aggregate, DeviceUpdate};
        let sq = vec![TensorSpec {
            name: "bq".into(),
            shape: vec![L, R, R],
        }];
        let update = filled_of(21, &sq);
        let reference = filled_of(22, &sq);
        let cfg = LoraConfig {
            layers: LayerSet::Depth(L),
            ranks: vec![2; L],
        };
        let trimmed = trim_to_rank(&update, &cfg, L, R);
        assert_eq!(trimmed.get("bq").unwrap().len(), L * R * 2,
                   "square bq must trim along its LAST axis");
        let pat = classify(&sq[0], L, R);
        let mut padded = TensorMap::zeros(&sq);
        *padded.get_mut("bq").unwrap() = layout::pad_to_rank(
            pat, L, trimmed.get("bq").unwrap().to_vec())
            .unwrap();

        let fold = |restored: TensorMap| {
            let mut g = TensorMap::zeros(&sq);
            let ups = [DeviceUpdate {
                trainable: restored,
                config: cfg.clone(),
                weight: 1.0,
            }];
            aggregate(&mut g, &ups, L, R);
            g
        };
        for codec in [Codec::None, Codec::Int8, Codec::Int4] {
            // The padded slots are inactive: same bytes travel.
            let wire_p = encode_update(codec, &padded, &reference, &cfg,
                                       L, R);
            let wire_u = encode_update(codec, &update, &reference, &cfg,
                                       L, R);
            assert_eq!(wire_p, wire_u,
                       "{codec:?}: padded slots must not travel");
            let (bytes_p, restored_p) = through_wire(
                codec, padded.clone(), &reference, &cfg, L, R)
                .unwrap();
            let (bytes_u, restored_u) = through_wire(
                codec, update.clone(), &reference, &cfg, L, R)
                .unwrap();
            assert_eq!(bytes_p, bytes_u);
            assert_eq!(fold(restored_p), fold(restored_u),
                       "{codec:?}: padded fold drifted");
        }
    }

    #[test]
    fn codec_names_roundtrip() {
        for codec in [Codec::None, Codec::Int8, Codec::Int4] {
            assert_eq!(Codec::by_name(codec.name()).unwrap(), codec);
        }
        assert!(Codec::by_name("int2").is_err());
        assert!(!Codec::None.uses_delta());
        assert!(Codec::Int8.uses_delta() && Codec::Int4.uses_delta());
    }
}
