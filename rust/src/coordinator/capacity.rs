//! Capacity estimation (§4.3, eq. 8–9).
//!
//! The PS maintains a moving average of each device's reported
//! per-layer backprop time μ̂ and unit-rank upload time β̂:
//!   μ_i^h = ρ·μ_i^{h-1} + (1-ρ)·μ̂_i^h,
//!   β_i^h = ρ·β_i^{h-1} + (1-ρ)·β̂_i^h,   ρ = 0.8 by default.
//! The first observation seeds the state directly (no bias toward 0).
//!
//! State is keyed sparsely by device id: only devices that have ever
//! reported occupy memory, so the estimator stays O(devices seen) —
//! O(cohort · rounds at worst) — rather than O(fleet), which matters
//! once the fleet is lazily a million devices wide.

use std::collections::BTreeMap;

/// One device's EMA state.
#[derive(Debug, Clone, Copy, Default)]
struct Ema {
    mu: f64,
    beta: f64,
}

/// PS-side estimator over the fleet.
#[derive(Debug, Clone)]
pub struct CapacityEstimator {
    rho: f64,
    n_devices: usize,
    state: BTreeMap<usize, Ema>,
}

/// A device's estimated capacities for the current round.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Capacity {
    /// Estimated per-layer backprop time [s/layer/batch].
    pub mu: f64,
    /// Estimated unit-rank upload time [s].
    pub beta: f64,
}

impl CapacityEstimator {
    /// `rho` = 0.8 in the paper's experiments.
    pub fn new(n_devices: usize, rho: f64) -> Self {
        assert!((0.0..=1.0).contains(&rho), "rho must be in [0,1]");
        CapacityEstimator { rho, n_devices, state: BTreeMap::new() }
    }

    pub fn paper(n_devices: usize) -> Self {
        Self::new(n_devices, 0.8)
    }

    /// Fold in a round's status report (μ̂, β̂) from device `i`. The
    /// first report from a device seeds its state directly.
    ///
    /// An out-of-range id is dropped, in release builds too — a
    /// `debug_assert!` here used to let a stray report silently
    /// pollute `state` in release, and everything downstream
    /// (backfill via [`Self::seen`], plan snapshots) trusts `state`
    /// to hold only real devices.
    pub fn update(&mut self, i: usize, mu_hat: f64, beta_hat: f64) {
        if i >= self.n_devices {
            return;
        }
        match self.state.entry(i) {
            std::collections::btree_map::Entry::Vacant(v) => {
                v.insert(Ema { mu: mu_hat, beta: beta_hat });
            }
            std::collections::btree_map::Entry::Occupied(mut o) => {
                let e = o.get_mut();
                e.mu = self.rho * e.mu + (1.0 - self.rho) * mu_hat;
                e.beta = self.rho * e.beta + (1.0 - self.rho) * beta_hat;
            }
        }
    }

    /// Current estimate for device `i` (None before first report).
    pub fn get(&self, i: usize) -> Option<Capacity> {
        self.state
            .get(&i)
            .map(|e| Capacity { mu: e.mu, beta: e.beta })
    }

    /// Fleet size the estimator serves (not the number of seeded
    /// entries — state is sparse).
    pub fn len(&self) -> usize {
        self.n_devices
    }

    pub fn is_empty(&self) -> bool {
        self.n_devices == 0
    }

    /// The devices that have ever reported, with their current
    /// estimates, in ascending id order. O(devices seen), not
    /// O(fleet) — the multi-job scheduler's cohort backfill iterates
    /// this instead of scanning the id space, which matters on a
    /// lazily-derived million-device fleet.
    pub fn seen(&self) -> impl Iterator<Item = (usize, Capacity)> + '_ {
        self.state
            .iter()
            .map(|(&i, e)| (i, Capacity { mu: e.mu, beta: e.beta }))
    }
}

/// Periodic LCD re-allocation policy: turns the live EWMA estimates
/// into the *plan inputs* for each round, making the LoRA plan a
/// per-round value instead of a run constant.
///
/// * `every == 0` — re-allocation off: live estimates pass through
///   untouched every round and the plan epoch stays 0, reproducing the
///   pre-refactor engine bitwise.
/// * `every == K ≥ 1` — the capacity snapshot feeding the strategy is
///   *frozen* between refit rounds. On refit rounds (`(h − 1) % K == 0`;
///   round 1 always refits) the live estimates are compared to the
///   frozen snapshot under the relative hysteresis band
///   `|live − frozen| ≤ hysteresis · |frozen|` (per device, μ and β
///   both): if every cohort device is inside the band, the frozen
///   snapshot is kept *bitwise* (an unchanged fit is a no-op plan);
///   otherwise the live snapshot is adopted and the plan epoch
///   increments. Between refits, cohort devices not yet in the frozen
///   snapshot (churn) seed from their live estimate without bumping
///   the epoch — determinism only needs the seeding order to be fixed,
///   and it is (ascending cohort position).
///
/// Determinism: only plain float comparison/subtraction/multiplication
/// (no accumulation, no `partial_cmp`), all on the coordinator thread
/// in cohort order — detlint-clean by construction.
#[derive(Debug, Clone)]
pub struct Reallocator {
    every: usize,
    hysteresis: f64,
    epoch: usize,
    frozen: BTreeMap<usize, Capacity>,
}

impl Reallocator {
    pub fn new(every: usize, hysteresis: f64) -> Self {
        Reallocator {
            every,
            hysteresis: hysteresis.max(0.0),
            epoch: 0,
            frozen: BTreeMap::new(),
        }
    }

    /// Plan epoch the *next* plan will be produced under. 0 until the
    /// first adopted refit; with `every == 0` it never moves.
    pub fn epoch(&self) -> usize {
        self.epoch
    }

    /// True when re-allocation is enabled and round `h` (1-based) is a
    /// refit round.
    fn is_refit_round(&self, h: usize) -> bool {
        self.every > 0 && (h.saturating_sub(1)) % self.every == 0
    }

    /// `b` within the relative hysteresis band around `a`.
    fn within_band(&self, a: f64, b: f64) -> bool {
        let d = b - a;
        let lim = self.hysteresis * if a < 0.0 { -a } else { a };
        -lim <= d && d <= lim
    }

    /// Produce the capacity snapshot the strategy plans round `h` from.
    /// `cohort[j]`'s live estimate is `live[j]`; the result is indexed
    /// the same way. Mutates the frozen snapshot / epoch per the policy
    /// above.
    pub fn plan_estimates(&mut self, h: usize, cohort: &[usize],
                          live: &[Capacity]) -> Vec<Capacity> {
        debug_assert_eq!(cohort.len(), live.len());
        if self.every == 0 {
            return live.to_vec();
        }
        if self.is_refit_round(h) {
            let unchanged = cohort.iter().zip(live).all(|(&i, c)| {
                match self.frozen.get(&i) {
                    Some(f) => {
                        self.within_band(f.mu, c.mu)
                            && self.within_band(f.beta, c.beta)
                    }
                    None => false,
                }
            });
            if !unchanged {
                for (&i, c) in cohort.iter().zip(live) {
                    self.frozen.insert(i, *c);
                }
                self.epoch += 1;
            }
        } else {
            // Between refits: devices the snapshot has never seen
            // (churn) seed from live without an epoch bump.
            for (&i, c) in cohort.iter().zip(live) {
                self.frozen.entry(i).or_insert(*c);
            }
        }
        cohort
            .iter()
            .map(|i| self.frozen[i])
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_observation_seeds() {
        let mut est = CapacityEstimator::paper(2);
        assert!(est.get(0).is_none());
        est.update(0, 0.01, 0.2);
        let c = est.get(0).unwrap();
        assert_eq!(c.mu, 0.01);
        assert_eq!(c.beta, 0.2);
        assert!(est.get(1).is_none());
    }

    #[test]
    fn ema_blends_with_rho() {
        let mut est = CapacityEstimator::new(1, 0.8);
        est.update(0, 0.010, 0.10);
        est.update(0, 0.020, 0.30);
        let c = est.get(0).unwrap();
        assert!((c.mu - (0.8 * 0.010 + 0.2 * 0.020)).abs() < 1e-12);
        assert!((c.beta - (0.8 * 0.10 + 0.2 * 0.30)).abs() < 1e-12);
    }

    #[test]
    fn estimate_stays_within_observation_hull() {
        let mut est = CapacityEstimator::paper(1);
        let obs = [0.01, 0.03, 0.02, 0.05, 0.04, 0.015];
        for &o in &obs {
            est.update(0, o, o * 10.0);
            let c = est.get(0).unwrap();
            assert!(c.mu >= 0.01 - 1e-12 && c.mu <= 0.05 + 1e-12);
        }
    }

    #[test]
    fn converges_to_stationary_truth() {
        let mut est = CapacityEstimator::paper(1);
        for _ in 0..200 {
            est.update(0, 0.042, 1.3);
        }
        let c = est.get(0).unwrap();
        assert!((c.mu - 0.042).abs() < 1e-9);
        assert!((c.beta - 1.3).abs() < 1e-9);
    }

    #[test]
    fn state_is_sparse_in_devices_seen() {
        // A huge fleet costs nothing until devices actually report.
        let mut est = CapacityEstimator::paper(1_000_000);
        assert_eq!(est.len(), 1_000_000);
        est.update(999_999, 0.01, 0.1);
        assert!(est.get(999_999).is_some());
        assert!(est.get(0).is_none());
        assert_eq!(est.state.len(), 1);
    }

    #[test]
    fn tracks_mode_change() {
        // After a DVFS reshuffle the estimate should move most of the
        // way to the new value within ~10 rounds (1 - 0.8^10 ≈ 0.89).
        let mut est = CapacityEstimator::paper(1);
        for _ in 0..50 {
            est.update(0, 0.01, 0.1);
        }
        for _ in 0..10 {
            est.update(0, 0.05, 0.1);
        }
        let c = est.get(0).unwrap();
        assert!(c.mu > 0.04, "estimate {0} should chase the new mode",
                c.mu);
    }

    #[test]
    fn out_of_range_update_is_dropped_not_recorded() {
        // Regression: this used to be a debug_assert! only, so a
        // release build silently seeded state for a device the fleet
        // does not have. It must be a no-op in every profile.
        let mut est = CapacityEstimator::paper(3);
        est.update(3, 0.01, 0.1);
        est.update(usize::MAX, 0.01, 0.1);
        assert!(est.get(3).is_none());
        assert_eq!(est.state.len(), 0);
        assert_eq!(est.seen().count(), 0);
        // In-range reports still land, and seen() reflects exactly
        // the devices that reported.
        est.update(2, 0.01, 0.1);
        assert_eq!(est.seen().collect::<Vec<_>>(),
                   vec![(2, Capacity { mu: 0.01, beta: 0.1 })]);
    }

    fn cap(mu: f64) -> Capacity {
        Capacity { mu, beta: mu * 10.0 }
    }

    #[test]
    fn realloc_off_passes_live_estimates_through() {
        let mut r = Reallocator::new(0, 0.05);
        for h in 1..=5 {
            let live = vec![cap(0.01 * h as f64), cap(0.02 * h as f64)];
            let got = r.plan_estimates(h, &[0, 1], &live);
            assert_eq!(got, live, "off must be a bitwise pass-through");
            assert_eq!(r.epoch(), 0, "off never moves the epoch");
        }
    }

    #[test]
    fn realloc_freezes_between_refits_and_adopts_on_drift() {
        // K = 2: rounds 1, 3, 5 … are refit rounds.
        let mut r = Reallocator::new(2, 0.05);
        let seed = vec![cap(0.010), cap(0.020)];
        assert_eq!(r.plan_estimates(1, &[0, 1], &seed), seed);
        assert_eq!(r.epoch(), 1, "round 1 adopts the first fit");
        // Round 2 is frozen: live estimates moved, the plan input
        // must not.
        let moved = vec![cap(0.015), cap(0.030)];
        assert_eq!(r.plan_estimates(2, &[0, 1], &moved), seed);
        assert_eq!(r.epoch(), 1);
        // Round 3 refits and the drift exceeds 5%: adopt.
        assert_eq!(r.plan_estimates(3, &[0, 1], &moved), moved);
        assert_eq!(r.epoch(), 2);
    }

    #[test]
    fn realloc_hysteresis_keeps_an_unchanged_fit_bitwise() {
        let mut r = Reallocator::new(1, 0.10);
        let seed = vec![cap(0.010)];
        assert_eq!(r.plan_estimates(1, &[0], &seed), seed);
        assert_eq!(r.epoch(), 1);
        // 5% drift, inside the 10% band: the FROZEN values survive
        // bitwise, and the epoch holds.
        let nudged = vec![cap(0.0105)];
        let got = r.plan_estimates(2, &[0], &nudged);
        assert_eq!(got[0].mu.to_bits(), seed[0].mu.to_bits());
        assert_eq!(got[0].beta.to_bits(), seed[0].beta.to_bits());
        assert_eq!(r.epoch(), 1);
        // 20% drift breaks the band: adopt, epoch moves.
        let jumped = vec![cap(0.012)];
        assert_eq!(r.plan_estimates(3, &[0], &jumped), jumped);
        assert_eq!(r.epoch(), 2);
    }

    #[test]
    fn realloc_unseen_device_on_refit_round_forces_adoption() {
        let mut r = Reallocator::new(1, 1000.0);
        let _ = r.plan_estimates(1, &[0], &[cap(0.010)]);
        assert_eq!(r.epoch(), 1);
        // A churned-in device has no frozen entry: even a huge band
        // cannot call the fit unchanged.
        let live = vec![cap(0.010), cap(0.040)];
        assert_eq!(r.plan_estimates(2, &[0, 1], &live), live);
        assert_eq!(r.epoch(), 2);
    }

    #[test]
    fn realloc_seeds_churned_devices_between_refits() {
        // K = 3: round 2 is not a refit round, but a never-seen device
        // must still get a deterministic estimate (its live one) —
        // without an epoch bump.
        let mut r = Reallocator::new(3, 0.05);
        let _ = r.plan_estimates(1, &[0], &[cap(0.010)]);
        assert_eq!(r.epoch(), 1);
        let got = r.plan_estimates(2, &[0, 1], &[cap(0.5), cap(0.040)]);
        assert_eq!(got[0], cap(0.010), "frozen device stays frozen");
        assert_eq!(got[1], cap(0.040), "churned device seeds from live");
        assert_eq!(r.epoch(), 1);
        // And the seed sticks on the next non-refit round.
        let again = r.plan_estimates(3, &[1], &[cap(0.9)]);
        assert_eq!(again[0], cap(0.040));
        assert_eq!(r.epoch(), 1);
    }

    #[test]
    fn realloc_hysteresis_band_is_symmetric() {
        // |live − frozen| ≤ H·|frozen| must hold on BOTH sides of the
        // frozen value: a 10% band around 0.010 keeps live values in
        // [0.009, 0.011] bitwise (epoch holds) and adopts just
        // outside either edge.
        for (live_mu, keeps) in [
            (0.011, true),   // exactly at the upper edge: kept
            (0.009, true),   // exactly at the lower edge: kept
            (0.0111, false), // just above: adopted
            (0.0089, false), // just below: adopted
        ] {
            let mut r = Reallocator::new(1, 0.10);
            let seed = vec![cap(0.010)];
            assert_eq!(r.plan_estimates(1, &[0], &seed), seed);
            assert_eq!(r.epoch(), 1);
            let live = vec![cap(live_mu)];
            let got = r.plan_estimates(2, &[0], &live);
            if keeps {
                assert_eq!(got[0].mu.to_bits(), seed[0].mu.to_bits(),
                           "live {live_mu} is inside the band");
                assert_eq!(r.epoch(), 1);
            } else {
                assert_eq!(got[0].mu.to_bits(), live[0].mu.to_bits(),
                           "live {live_mu} is outside the band");
                assert_eq!(r.epoch(), 2);
            }
        }
    }

    #[test]
    fn realloc_churn_seed_counts_as_frozen_on_next_refit() {
        // A device seeded between refits (churn, no epoch bump) is
        // real frozen state: if the next refit round finds the whole
        // cohort inside the band — the churned device included — the
        // fit is unchanged and the epoch must still not move.
        let mut r = Reallocator::new(2, 0.10);
        let _ = r.plan_estimates(1, &[0], &[cap(0.010)]);
        assert_eq!(r.epoch(), 1);
        // Round 2 (between refits): device 1 churns in, seeds from
        // live, epoch holds.
        let got = r.plan_estimates(2, &[0, 1], &[cap(0.010), cap(0.020)]);
        assert_eq!(got[1], cap(0.020));
        assert_eq!(r.epoch(), 1);
        // Round 3 refits; both devices are within 10% of their frozen
        // values (device 1's being the churn seed): no adoption.
        let live = vec![cap(0.0101), cap(0.0202)];
        let kept = r.plan_estimates(3, &[0, 1], &live);
        assert_eq!(kept[0], cap(0.010));
        assert_eq!(kept[1], cap(0.020));
        assert_eq!(r.epoch(), 1, "in-band refit must not bump the epoch");
    }

    #[test]
    fn realloc_every_one_zero_hysteresis_tracks_live() {
        // K = 1 with a zero band refits and adopts every round the
        // estimates move at all — the estimates the strategy sees are
        // exactly the live ones (the off-equivalence the property
        // suite checks end to end).
        let mut r = Reallocator::new(1, 0.0);
        for h in 1..=4 {
            let live = vec![cap(0.01 + 0.001 * h as f64)];
            assert_eq!(r.plan_estimates(h, &[0], &live), live);
        }
        assert_eq!(r.epoch(), 4);
        // Bitwise-identical estimates inside the zero band: frozen is
        // kept, but frozen == live bitwise, so the plan is unchanged.
        let same = vec![cap(0.01 + 0.001 * 4.0)];
        assert_eq!(r.plan_estimates(5, &[0], &same), same);
        assert_eq!(r.epoch(), 4);
    }
}
