//! Capacity estimation (§4.3, eq. 8–9).
//!
//! The PS maintains a moving average of each device's reported
//! per-layer backprop time μ̂ and unit-rank upload time β̂:
//!   μ_i^h = ρ·μ_i^{h-1} + (1-ρ)·μ̂_i^h,
//!   β_i^h = ρ·β_i^{h-1} + (1-ρ)·β̂_i^h,   ρ = 0.8 by default.
//! The first observation seeds the state directly (no bias toward 0).
//!
//! State is keyed sparsely by device id: only devices that have ever
//! reported occupy memory, so the estimator stays O(devices seen) —
//! O(cohort · rounds at worst) — rather than O(fleet), which matters
//! once the fleet is lazily a million devices wide.

use std::collections::BTreeMap;

/// One device's EMA state.
#[derive(Debug, Clone, Copy, Default)]
struct Ema {
    mu: f64,
    beta: f64,
}

/// PS-side estimator over the fleet.
#[derive(Debug, Clone)]
pub struct CapacityEstimator {
    rho: f64,
    n_devices: usize,
    state: BTreeMap<usize, Ema>,
}

/// A device's estimated capacities for the current round.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Capacity {
    /// Estimated per-layer backprop time [s/layer/batch].
    pub mu: f64,
    /// Estimated unit-rank upload time [s].
    pub beta: f64,
}

impl CapacityEstimator {
    /// `rho` = 0.8 in the paper's experiments.
    pub fn new(n_devices: usize, rho: f64) -> Self {
        assert!((0.0..=1.0).contains(&rho), "rho must be in [0,1]");
        CapacityEstimator { rho, n_devices, state: BTreeMap::new() }
    }

    pub fn paper(n_devices: usize) -> Self {
        Self::new(n_devices, 0.8)
    }

    /// Fold in a round's status report (μ̂, β̂) from device `i`. The
    /// first report from a device seeds its state directly.
    pub fn update(&mut self, i: usize, mu_hat: f64, beta_hat: f64) {
        debug_assert!(i < self.n_devices, "device {i} out of range");
        match self.state.entry(i) {
            std::collections::btree_map::Entry::Vacant(v) => {
                v.insert(Ema { mu: mu_hat, beta: beta_hat });
            }
            std::collections::btree_map::Entry::Occupied(mut o) => {
                let e = o.get_mut();
                e.mu = self.rho * e.mu + (1.0 - self.rho) * mu_hat;
                e.beta = self.rho * e.beta + (1.0 - self.rho) * beta_hat;
            }
        }
    }

    /// Current estimate for device `i` (None before first report).
    pub fn get(&self, i: usize) -> Option<Capacity> {
        self.state
            .get(&i)
            .map(|e| Capacity { mu: e.mu, beta: e.beta })
    }

    /// Fleet size the estimator serves (not the number of seeded
    /// entries — state is sparse).
    pub fn len(&self) -> usize {
        self.n_devices
    }

    pub fn is_empty(&self) -> bool {
        self.n_devices == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_observation_seeds() {
        let mut est = CapacityEstimator::paper(2);
        assert!(est.get(0).is_none());
        est.update(0, 0.01, 0.2);
        let c = est.get(0).unwrap();
        assert_eq!(c.mu, 0.01);
        assert_eq!(c.beta, 0.2);
        assert!(est.get(1).is_none());
    }

    #[test]
    fn ema_blends_with_rho() {
        let mut est = CapacityEstimator::new(1, 0.8);
        est.update(0, 0.010, 0.10);
        est.update(0, 0.020, 0.30);
        let c = est.get(0).unwrap();
        assert!((c.mu - (0.8 * 0.010 + 0.2 * 0.020)).abs() < 1e-12);
        assert!((c.beta - (0.8 * 0.10 + 0.2 * 0.30)).abs() < 1e-12);
    }

    #[test]
    fn estimate_stays_within_observation_hull() {
        let mut est = CapacityEstimator::paper(1);
        let obs = [0.01, 0.03, 0.02, 0.05, 0.04, 0.015];
        for &o in &obs {
            est.update(0, o, o * 10.0);
            let c = est.get(0).unwrap();
            assert!(c.mu >= 0.01 - 1e-12 && c.mu <= 0.05 + 1e-12);
        }
    }

    #[test]
    fn converges_to_stationary_truth() {
        let mut est = CapacityEstimator::paper(1);
        for _ in 0..200 {
            est.update(0, 0.042, 1.3);
        }
        let c = est.get(0).unwrap();
        assert!((c.mu - 0.042).abs() < 1e-9);
        assert!((c.beta - 1.3).abs() < 1e-9);
    }

    #[test]
    fn state_is_sparse_in_devices_seen() {
        // A huge fleet costs nothing until devices actually report.
        let mut est = CapacityEstimator::paper(1_000_000);
        assert_eq!(est.len(), 1_000_000);
        est.update(999_999, 0.01, 0.1);
        assert!(est.get(999_999).is_some());
        assert!(est.get(0).is_none());
        assert_eq!(est.state.len(), 1);
    }

    #[test]
    fn tracks_mode_change() {
        // After a DVFS reshuffle the estimate should move most of the
        // way to the new value within ~10 rounds (1 - 0.8^10 ≈ 0.89).
        let mut est = CapacityEstimator::paper(1);
        for _ in 0..50 {
            est.update(0, 0.01, 0.1);
        }
        for _ in 0..10 {
            est.update(0, 0.05, 0.1);
        }
        let c = est.get(0).unwrap();
        assert!(c.mu > 0.04, "estimate {0} should chase the new mode",
                c.mu);
    }
}
