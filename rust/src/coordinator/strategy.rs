//! Configuration strategies: LEGEND and every baseline/ablation the
//! paper evaluates (§6.1 Baselines, §6.3 Ablation, §2 pre-tests).
//!
//! A strategy decides, each round, which layers each device trains and
//! at what ranks (widths, for the adapter family). Everything else —
//! local training, aggregation, timing, traffic — is shared framework
//! code in `server.rs`, so strategies differ *only* in the paper's
//! actual design axes.

use std::collections::BTreeMap;

use crate::model::masks::{arithmetic_ranks, LayerSet, LoraConfig};

use super::capacity::Capacity;
use super::lcd::{self, LcdDevice, LcdParams};

/// Round context handed to strategies.
#[derive(Debug, Clone)]
pub struct StrategyCtx {
    pub round: usize,
    pub n_layers: usize,
    /// Rank dimension of the active family (r_max or adapter w_max).
    pub rank_dim: usize,
    /// Per-device capacity estimates (eq. 8–9 output).
    pub estimates: Vec<Capacity>,
    /// Per-device forward time per batch [s].
    pub fwd_times: Vec<f64>,
    /// Per-device local batches per round.
    pub n_batches: Vec<usize>,
    pub unit_rank_bytes: usize,
    /// Per-device budgets (eq. 14/15); f64::MAX / usize::MAX = unbound.
    pub compute_budgets: Vec<f64>,
    pub comm_budgets: Vec<usize>,
    /// Mean local train loss per device *from the immediately previous
    /// round* — feedback for search-based strategies (FedAdapter).
    /// 0 means "no fresh loss" (round 1, the device was
    /// deadline-dropped, or it sat out sampled rounds since it last
    /// trained): the engine tracks the round each loss was recorded
    /// and never surfaces an older loss as "last round".
    pub last_losses: Vec<f64>,
    /// Virtual duration of the previous round [s].
    pub last_round_time: f64,
    /// Global device ids of this round's cohort: every per-device
    /// vector above is indexed by *cohort position*, and
    /// `device_ids[j]` maps position `j` back to the fleet id. Under
    /// full participation this is `0..n`; a sampling/deadline
    /// [`crate::coordinator::participation::Participation`] policy
    /// hands strategies only the sampled cohort.
    pub device_ids: Vec<usize>,
    /// Rounds elapsed since each device's loss in `last_losses` was
    /// recorded: 0 = fresh (the immediately previous round, the only
    /// case where `last_losses` is non-zero in the sync engine),
    /// `usize::MAX` = the device has never folded an update. The async
    /// engine surfaces intermediate values for devices whose training
    /// spans commit windows, so search-based strategies (FedAdapter)
    /// can discount stale feedback instead of folding it at face
    /// value.
    pub staleness: Vec<usize>,
}

impl StrategyCtx {
    pub fn n_devices(&self) -> usize {
        self.estimates.len()
    }

    fn lcd_devices(&self) -> Vec<LcdDevice> {
        (0..self.n_devices())
            .map(|i| LcdDevice {
                capacity: self.estimates[i],
                fwd_time: self.fwd_times[i],
                n_batches: self.n_batches[i],
                compute_budget: self.compute_budgets[i],
                comm_budget: self.comm_budgets[i],
                unit_rank_bytes: self.unit_rank_bytes,
            })
            .collect()
    }

    /// Reference completion times at full depth (for capability
    /// ordering in HetLoRA / FedAdapter group assignment).
    fn full_depth_times(&self, ranks: &[usize]) -> Vec<f64> {
        self.lcd_devices()
            .iter()
            .map(|d| d.est_completion(self.n_layers, ranks))
            .collect()
    }
}

/// A per-round plan: one config per device + the mask to evaluate the
/// aggregated global model under.
#[derive(Debug, Clone)]
pub struct Plan {
    pub device_configs: Vec<LoraConfig>,
    pub eval_config: LoraConfig,
}

/// The strategy interface.
pub trait Strategy {
    fn name(&self) -> String;
    /// "lora" or "adapter" — selects the artifact family.
    fn family(&self) -> &'static str {
        "lora"
    }
    fn configure(&mut self, ctx: &StrategyCtx) -> Plan;
}

// ---------------------------------------------------------------------------
// LEGEND + ablations
// ---------------------------------------------------------------------------

/// Full LEGEND: LCD depths + arithmetic rank distribution (§4.4).
pub struct Legend {
    pub params: LcdParams,
}

impl Legend {
    pub fn paper(n_layers: usize, r_max: usize) -> Self {
        Legend { params: LcdParams::paper(n_layers, r_max) }
    }
}

impl Strategy for Legend {
    fn name(&self) -> String {
        "LEGEND".into()
    }

    fn configure(&mut self, ctx: &StrategyCtx) -> Plan {
        let device_configs = lcd::determine(&self.params, &ctx.lcd_devices());
        let ranks = arithmetic_ranks(
            self.params.n_layers,
            self.params.lambda,
            self.params.r0,
            self.params.psi,
            self.params.r_max,
        );
        Plan {
            device_configs,
            eval_config: LoraConfig { layers: LayerSet::All, ranks },
        }
    }
}

/// LEGEND w/o LoRA depth (§6.3): every device fine-tunes ALL layers
/// with the arithmetic rank distribution.
pub struct LegendNoLd {
    pub params: LcdParams,
}

impl Strategy for LegendNoLd {
    fn name(&self) -> String {
        "LEGEND w/o LD".into()
    }

    fn configure(&mut self, ctx: &StrategyCtx) -> Plan {
        let ranks = arithmetic_ranks(
            self.params.n_layers,
            self.params.lambda,
            self.params.r0,
            self.params.psi,
            self.params.r_max,
        );
        let cfg = LoraConfig { layers: LayerSet::All, ranks };
        Plan {
            device_configs: vec![cfg.clone(); ctx.n_devices()],
            eval_config: cfg,
        }
    }
}

/// LEGEND w/o rank distribution (§6.3): LCD depths but a uniform rank
/// on every layer.
pub struct LegendNoRd {
    pub params: LcdParams,
    pub rank: usize,
}

impl Strategy for LegendNoRd {
    fn name(&self) -> String {
        "LEGEND w/o RD".into()
    }

    fn configure(&mut self, ctx: &StrategyCtx) -> Plan {
        let mut params = self.params.clone();
        // Uniform distribution via λ=0, r0=rank; ψ must admit it.
        params.lambda = 0;
        params.r0 = self.rank;
        params.psi = self.rank * params.n_layers;
        let device_configs = lcd::determine(&params, &ctx.lcd_devices());
        Plan {
            device_configs,
            eval_config: LoraConfig::uniform(
                LayerSet::All,
                self.rank,
                self.params.n_layers,
            ),
        }
    }
}

// ---------------------------------------------------------------------------
// Baselines
// ---------------------------------------------------------------------------

/// FedLoRA [20]: identical uniform-rank LoRA on all layers of all
/// devices (vanilla).
pub struct FedLora {
    pub rank: usize,
}

impl Strategy for FedLora {
    fn name(&self) -> String {
        "FedLoRA".into()
    }

    fn configure(&mut self, ctx: &StrategyCtx) -> Plan {
        let cfg =
            LoraConfig::uniform(LayerSet::All, self.rank, ctx.n_layers);
        Plan {
            device_configs: vec![cfg.clone(); ctx.n_devices()],
            eval_config: cfg,
        }
    }
}

/// HetLoRA [27]: all layers, per-device uniform rank matched to the
/// device's capability (fast → high rank); zero-padded aggregation is
/// handled by the slot-aware aggregator.
pub struct HetLora {
    pub min_rank: usize,
    pub max_rank: usize,
}

impl Strategy for HetLora {
    fn name(&self) -> String {
        "HetLoRA".into()
    }

    fn configure(&mut self, ctx: &StrategyCtx) -> Plan {
        let ref_ranks = vec![self.max_rank; ctx.n_layers];
        let times = ctx.full_depth_times(&ref_ranks);
        let t_max = times.iter().cloned().fold(f64::MIN, f64::max);
        let t_min = times.iter().cloned().fold(f64::MAX, f64::min);
        let span = (t_max - t_min).max(1e-12);
        let device_configs = times
            .iter()
            .map(|&t| {
                let frac = (t_max - t) / span; // 1 = fastest
                let r = self.min_rank as f64
                    + frac * (self.max_rank - self.min_rank) as f64;
                LoraConfig::uniform(
                    LayerSet::All,
                    (r.round() as usize)
                        .clamp(self.min_rank, self.max_rank),
                    ctx.n_layers,
                )
            })
            .collect();
        Plan {
            device_configs,
            eval_config: LoraConfig::uniform(
                LayerSet::All,
                self.max_rank,
                ctx.n_layers,
            ),
        }
    }
}

/// FedAdapter [10]: adapter family with a progressive configuration
/// search — device groups try candidate (depth, width) pairs, the PS
/// scores candidates by loss-drop per virtual second and re-centers
/// the candidate set every `window` rounds (the paper's dynamic
/// "cascade" search, simplified but load-faithful: search overhead
/// shows up as extra traffic + waiting exactly like in FedAdapter).
pub struct FedAdapter {
    pub candidates: Vec<(usize, usize)>,
    pub window: usize,
    pub w_max: usize,
    /// (sum of loss drops, rounds) per candidate in current window.
    scores: Vec<(f64, usize)>,
    /// Per-device feedback state from the previous `configure`, keyed
    /// by fleet device id: (candidate index, the loss the device
    /// entered that round with, the round of assignment). Id-keying —
    /// not cohort position — means resampled cohorts still fold for
    /// the devices both rounds share, and devices that never trained
    /// (deadline-dropped; stale losses surface as 0) never fold
    /// phantom drops.
    assigned: BTreeMap<usize, (usize, f64, usize)>,
}

impl FedAdapter {
    pub fn paper(n_layers: usize, w_max: usize) -> Self {
        let d = n_layers;
        FedAdapter {
            candidates: vec![
                (2.min(d), 8),
                (d / 2, 16),
                (d, w_max.min(32)),
            ],
            window: 5,
            w_max,
            scores: vec![(0.0, 0); 3],
            assigned: BTreeMap::new(),
        }
    }

    fn fold_feedback(&mut self, ctx: &StrategyCtx) {
        for (j, &id) in ctx.device_ids.iter().enumerate() {
            let Some(&(c, loss_in, round)) = self.assigned.get(&id)
            else {
                continue;
            };
            // Only the immediately previous round's assignment is
            // attributable to its candidate — an older one measured a
            // global model many rounds stale.
            if round + 1 != ctx.round {
                continue;
            }
            // A stale loss (the device's last fold is older than one
            // round — possible under the async engine, where training
            // spans commit windows) measured a global model that the
            // candidate never saw; folding it would credit/blame the
            // wrong configuration. Today both engines also surface a
            // stale loss as 0 (caught below), so this gate is
            // defense-in-depth: it states the freshness contract
            // explicitly instead of leaning on the 0.0 sentinel, and
            // keeps the feedback correct for any future engine that
            // surfaces real stale losses alongside `staleness`.
            if ctx.staleness.get(j).copied().unwrap_or(usize::MAX) != 0 {
                continue;
            }
            let loss_out = ctx.last_losses[j];
            // 0 is "no fresh loss": the device was deadline-dropped
            // last round (never trained under the candidate), or it
            // had no baseline when assigned. Either way there is no
            // attributable drop.
            if loss_out == 0.0 || loss_in == 0.0 {
                continue;
            }
            let drop = loss_in - loss_out;
            if drop.is_finite() {
                // detlint-allow: float-accum feedback folds on one thread in cohort order
                self.scores[c].0 += drop;
                self.scores[c].1 += 1;
            }
        }
    }

    fn recenter(&mut self, n_layers: usize) {
        let best = self
            .scores
            .iter()
            .enumerate()
            .max_by(|a, b| {
                let sa = a.1 .0 / (a.1 .1.max(1) as f64);
                let sb = b.1 .0 / (b.1 .1.max(1) as f64);
                sa.total_cmp(&sb)
            })
            .map(|(i, _)| i)
            .unwrap_or(0);
        let (d, w) = self.candidates[best];
        // Cascade: best, one deeper, one wider.
        self.candidates = vec![
            (d, w),
            ((d + 2).min(n_layers), w),
            (d, (w * 2).min(self.w_max)),
        ];
        self.scores = vec![(0.0, 0); self.candidates.len()];
    }
}

impl Strategy for FedAdapter {
    fn name(&self) -> String {
        "FedAdapter".into()
    }

    fn family(&self) -> &'static str {
        "adapter"
    }

    fn configure(&mut self, ctx: &StrategyCtx) -> Plan {
        self.fold_feedback(ctx);
        if ctx.round > 1 && ctx.round % self.window == 0 {
            self.recenter(ctx.n_layers);
        }
        let n = ctx.n_devices();
        let c = self.candidates.len();
        let assignment: Vec<usize> = (0..n).map(|i| i % c).collect();
        let device_configs = assignment
            .iter()
            .map(|&ci| {
                let (depth, width) = self.candidates[ci];
                LoraConfig::uniform(
                    LayerSet::Depth(depth),
                    width,
                    ctx.n_layers,
                )
            })
            .collect();
        self.assigned = ctx
            .device_ids
            .iter()
            .enumerate()
            .map(|(j, &id)| {
                (id, (assignment[j], ctx.last_losses[j], ctx.round))
            })
            .collect();
        // Evaluate under the widest candidate's mask on all layers any
        // group trained.
        let max_w = self
            .candidates
            .iter()
            .map(|&(_, w)| w)
            .max()
            .unwrap_or(8);
        let max_d = self
            .candidates
            .iter()
            .map(|&(d, _)| d)
            .max()
            .unwrap_or(ctx.n_layers);
        Plan {
            device_configs,
            eval_config: LoraConfig::uniform(
                LayerSet::Depth(max_d),
                max_w,
                ctx.n_layers,
            ),
        }
    }
}

// ---------------------------------------------------------------------------
// Pre-test strategies (§2.2–2.4, Figs. 3–5)
// ---------------------------------------------------------------------------

/// Fixed layer set + uniform rank (Fig. 3 Layers-A/S/M/D; Fig. 4 depth
/// sweep via `LayerSet::Depth(k)`).
pub struct FixedLayers {
    pub label: String,
    pub layers: LayerSet,
    pub rank: usize,
}

impl Strategy for FixedLayers {
    fn name(&self) -> String {
        self.label.clone()
    }

    fn configure(&mut self, ctx: &StrategyCtx) -> Plan {
        let cfg = LoraConfig {
            layers: self.layers.clone(),
            ranks: vec![self.rank; ctx.n_layers],
        };
        Plan {
            device_configs: vec![cfg.clone(); ctx.n_devices()],
            eval_config: cfg,
        }
    }
}

/// Fixed explicit rank distribution over all layers (Fig. 5's
/// Uniform / Inc / Dec variants).
pub struct FixedRankDist {
    pub label: String,
    pub ranks: Vec<usize>,
}

impl FixedRankDist {
    pub fn uniform(n_layers: usize, r: usize) -> Self {
        FixedRankDist {
            label: format!("Uniform-r{r}"),
            ranks: vec![r; n_layers],
        }
    }

    pub fn increasing(n_layers: usize, r_max: usize) -> Self {
        FixedRankDist {
            label: "Inc".into(),
            ranks: (0..n_layers).map(|l| (l + 1).min(r_max)).collect(),
        }
    }

    pub fn decreasing(n_layers: usize, r_max: usize) -> Self {
        FixedRankDist {
            label: "Dec".into(),
            ranks: (0..n_layers)
                .map(|l| (n_layers - l).min(r_max))
                .collect(),
        }
    }
}

impl Strategy for FixedRankDist {
    fn name(&self) -> String {
        self.label.clone()
    }

    fn configure(&mut self, ctx: &StrategyCtx) -> Plan {
        let cfg = LoraConfig {
            layers: LayerSet::All,
            ranks: self.ranks.clone(),
        };
        Plan {
            device_configs: vec![cfg.clone(); ctx.n_devices()],
            eval_config: cfg,
        }
    }
}

/// Build a strategy by name (CLI / experiment harness entry point).
pub fn by_name(name: &str, n_layers: usize, r_max: usize, w_max: usize)
               -> Option<Box<dyn Strategy>> {
    Some(match name {
        "legend" => Box::new(Legend::paper(n_layers, r_max)),
        "legend-no-ld" => {
            Box::new(LegendNoLd { params: LcdParams::paper(n_layers, r_max) })
        }
        "legend-no-rd" => Box::new(LegendNoRd {
            params: LcdParams::paper(n_layers, r_max),
            rank: 8,
        }),
        "fedlora" => Box::new(FedLora { rank: 8 }),
        "hetlora" => Box::new(HetLora { min_rank: 2, max_rank: 8 }),
        "fedadapter" => Box::new(FedAdapter::paper(n_layers, w_max)),
        _ => return None,
    })
}

#[cfg(test)]
mod tests {
    use std::collections::BTreeMap;

    use super::*;

    fn ctx(mus: &[f64]) -> StrategyCtx {
        let n = mus.len();
        StrategyCtx {
            round: 1,
            n_layers: 12,
            rank_dim: 16,
            estimates: mus
                .iter()
                .map(|&mu| Capacity { mu, beta: mu * 10.0 })
                .collect(),
            fwd_times: mus.iter().map(|&mu| mu * 3.0).collect(),
            n_batches: vec![8; n],
            unit_rank_bytes: 2048,
            compute_budgets: vec![f64::MAX; n],
            comm_budgets: vec![usize::MAX; n],
            last_losses: vec![0.0; n],
            last_round_time: 0.0,
            device_ids: (0..n).collect(),
            staleness: vec![0; n],
        }
    }

    #[test]
    fn legend_depths_track_capability() {
        let mut s = Legend::paper(12, 16);
        let plan = s.configure(&ctx(&[0.005, 0.05, 0.5]));
        let d: Vec<usize> =
            plan.device_configs.iter().map(|c| c.depth(12)).collect();
        assert_eq!(d[0], 12);
        assert!(d[2] < d[0]);
        // eval config covers all layers.
        assert_eq!(plan.eval_config.depth(12), 12);
    }

    #[test]
    fn no_ld_gives_everyone_full_depth() {
        let mut s =
            LegendNoLd { params: LcdParams::paper(12, 16) };
        let plan = s.configure(&ctx(&[0.005, 0.5]));
        assert!(plan.device_configs.iter().all(|c| c.depth(12) == 12));
        // …but increasing ranks survive.
        let r = &plan.device_configs[0].ranks;
        assert!(r.windows(2).all(|w| w[0] <= w[1]) && r[0] < r[11]);
    }

    #[test]
    fn no_rd_gives_uniform_ranks_with_adaptive_depth() {
        let mut s = LegendNoRd {
            params: LcdParams::paper(12, 16),
            rank: 8,
        };
        let plan = s.configure(&ctx(&[0.005, 0.5]));
        assert!(plan
            .device_configs
            .iter()
            .all(|c| c.ranks.iter().all(|&r| r == 8)));
        let d: Vec<usize> =
            plan.device_configs.iter().map(|c| c.depth(12)).collect();
        assert!(d[1] < d[0]);
    }

    #[test]
    fn fedlora_is_homogeneous() {
        let mut s = FedLora { rank: 8 };
        let plan = s.configure(&ctx(&[0.005, 0.5]));
        assert_eq!(plan.device_configs[0], plan.device_configs[1]);
        assert_eq!(plan.device_configs[0].depth(12), 12);
        assert_eq!(plan.device_configs[0].total_rank(12), 96);
    }

    #[test]
    fn hetlora_rank_tracks_capability() {
        let mut s = HetLora { min_rank: 2, max_rank: 8 };
        let plan = s.configure(&ctx(&[0.005, 0.05, 0.5]));
        let r: Vec<usize> = plan
            .device_configs
            .iter()
            .map(|c| c.ranks[0])
            .collect();
        assert_eq!(r[0], 8, "fastest gets max rank");
        assert_eq!(r[2], 2, "slowest gets min rank");
        assert!(r[1] >= 2 && r[1] <= 8);
        assert!(plan
            .device_configs
            .iter()
            .all(|c| c.depth(12) == 12));
    }

    #[test]
    fn fedadapter_assigns_groups_and_recenters() {
        let mut s = FedAdapter::paper(12, 32);
        assert_eq!(s.family(), "adapter");
        let mut c = ctx(&[0.01; 6]);
        let plan = s.configure(&c);
        // 3 candidates → devices 0..6 split into 3 groups of 2.
        let cfgs = &plan.device_configs;
        assert_eq!(cfgs[0], cfgs[3]);
        assert_eq!(cfgs[1], cfgs[4]);
        assert_ne!(cfgs[0], cfgs[1]);
        // Feed back: candidate 1 shows the biggest loss drop.
        c.round = 5;
        c.last_losses = vec![1.0, 0.1, 1.0, 1.0, 0.1, 1.0];
        s.assigned = (0..6usize)
            .map(|i| (i, (i % 3, 1.0, 4usize)))
            .collect();
        let before = s.candidates.clone();
        let _ = s.configure(&c);
        assert_ne!(s.candidates, before, "window recenter must fire");
        assert_eq!(s.candidates[0], before[1], "best candidate kept");
    }

    #[test]
    fn fedadapter_folds_feedback_by_device_id_across_cohorts() {
        // A resampled cohort shares devices 2 and 5 with the previous
        // round at different positions: id-keyed feedback folds their
        // drops to the right candidates; ids never assigned (7, 9) and
        // ids resampled out (6) contribute nothing.
        let mut s = FedAdapter::paper(12, 32);
        let mut c = ctx(&[0.01; 4]);
        c.round = 3;
        c.device_ids = vec![2, 5, 7, 9];
        c.last_losses = vec![0.4, 0.9, 1.0, 1.0];
        s.assigned = BTreeMap::from([
            (2, (2, 1.0, 2)),
            (5, (0, 1.0, 2)),
            (6, (1, 1.0, 2)),
        ]);
        let _ = s.configure(&c);
        assert_eq!(s.scores[2].1, 1, "device 2 folded once");
        assert!((s.scores[2].0 - 0.6).abs() < 1e-12);
        assert_eq!(s.scores[0].1, 1, "device 5 folded once");
        assert!((s.scores[0].0 - 0.1).abs() < 1e-12);
        assert_eq!(s.scores[1], (0.0, 0), "device 6 never folds");
    }

    #[test]
    fn fedadapter_skips_dropped_and_stale_devices() {
        let mut s = FedAdapter::paper(12, 32);
        let mut c = ctx(&[0.01; 3]);
        c.round = 4;
        c.device_ids = vec![0, 1, 2];
        // 0: deadline-dropped last round — its stale loss surfaces as
        //    0 (round-1 semantics), so no phantom drop folds.
        // 1: assignment is from round 1, not round 3 — too old.
        // 2: assigned without a baseline loss (loss_in 0).
        c.last_losses = vec![0.0, 0.8, 0.7];
        s.assigned = BTreeMap::from([
            (0, (0, 1.0, 3)),
            (1, (1, 1.0, 1)),
            (2, (2, 0.0, 3)),
        ]);
        let before = s.scores.clone();
        let _ = s.configure(&c);
        assert_eq!(s.scores, before, "no phantom folds");
    }

    #[test]
    fn fedadapter_discounts_stale_losses_via_ctx_staleness() {
        // Contract test for the staleness gate itself (both current
        // engines zero stale losses before they get here, so this
        // hand-builds the state a future engine could surface): a
        // device re-enters the cohort with a non-zero loss whose fold
        // is 2 windows old. The staleness field must gate the
        // feedback even though the loss value itself looks fresh.
        let mut s = FedAdapter::paper(12, 32);
        let mut c = ctx(&[0.01; 2]);
        c.round = 4;
        c.device_ids = vec![0, 1];
        c.last_losses = vec![0.7, 0.6];
        c.staleness = vec![2, 0];
        s.assigned = BTreeMap::from([
            (0, (0, 1.0, 3)),
            (1, (1, 1.0, 3)),
        ]);
        let _ = s.configure(&c);
        assert_eq!(s.scores[0], (0.0, 0), "stale device 0 must not fold");
        assert_eq!(s.scores[1].1, 1, "fresh device 1 folds");
        assert!((s.scores[1].0 - 0.4).abs() < 1e-12);
    }

    #[test]
    fn by_name_covers_all_methods() {
        for m in ["legend", "legend-no-ld", "legend-no-rd", "fedlora",
                  "hetlora", "fedadapter"] {
            assert!(by_name(m, 12, 16, 32).is_some(), "{m}");
        }
        assert!(by_name("nope", 12, 16, 32).is_none());
    }
}
