//! Message-passing transport between the PS and devices.
//!
//! The paper's prototype uses MPI (`comm.send`/`comm.recv`, §5); here
//! the devices are in-process, but every PS↔device exchange still goes
//! through an explicit message layer with byte-exact accounting — the
//! traffic numbers in Fig. 11 are message-level, so we count them at
//! the same place the paper does. Payloads are the *logically
//! transmitted* bytes: only a device's active LoRA slots travel (plus
//! the head and a fixed-size status report), never the padded tensors.
//! Uplink updates go through the run's [`super::serialize::Codec`] —
//! the engine encodes/decodes and hands this layer the real
//! bytes-on-wire; assignments always travel f32 (docs/TRANSPORT.md).
//!
//! Accounting rules under partial participation (engine cohorts):
//! a sampled-out device exchanges **nothing** — no `STATUS_BYTES`, no
//! assignment, no update. A deadline-dropped device reported status
//! before the drop decision, so it contributes `STATUS_BYTES` and
//! nothing else (ISSUE: "STATUS_BYTES only for devices that actually
//! reported"). Only devices the engine actually touched appear in the
//! round tally.
//!
//! Every exchange carries its **logical round** explicitly: under the
//! async engine an update can complete (and be tallied) after
//! `begin_round` has advanced, and a round stamp read from shared
//! transport state at record time would mis-attribute it to the new
//! round. Tallies remain arrival-time (a late fold is traffic of the
//! window it lands in — that is what Fig. 11 measures), but the log
//! stamps each message with the round the exchange logically belongs
//! to.
//!
//! Thread safety: tallies are atomic and the message log is behind a
//! mutex, so every method takes `&self` and the endpoint can be shared
//! across coordinator shards. The round engine still performs all
//! accounting on its own thread in device-index order, which keeps the
//! message log deterministic.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::model::masks::LoraConfig;
use crate::model::state::TensorMap;

use super::serialize;

/// Message kinds on the wire (mirrors the prototype's MPI tags).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Tag {
    /// PS → device: LoRA assignment (§4.6).
    Assign,
    /// device → PS: updated LoRA layers (§4.2).
    Update,
    /// device → PS: status report (μ̂, β̂) (§4.3).
    Status,
}

/// One accounted message.
#[derive(Debug, Clone)]
pub struct Message {
    pub tag: Tag,
    pub device: usize,
    pub round: usize,
    /// LCD plan epoch the exchange was produced under (0 until the
    /// first re-allocation). Like `round`, it is named explicitly by
    /// the caller: under the async engine an update trained under the
    /// *previous* plan legally folds after a re-allocation, and its
    /// messages must keep the epoch that shaped them.
    pub plan_epoch: usize,
    pub bytes: usize,
}

/// Per-round, per-direction byte tallies.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Tally {
    pub downlink: usize,
    pub uplink: usize,
    pub messages: usize,
}

impl Tally {
    /// Component-wise sum. The multi-job scheduler runs one transport
    /// endpoint per job and merges their tallies into the fleet-wide
    /// traffic total (coordinator/jobs.rs).
    pub fn merged(&self, other: &Tally) -> Tally {
        Tally {
            downlink: self.downlink + other.downlink,
            uplink: self.uplink + other.uplink,
            messages: self.messages + other.messages,
        }
    }
}

#[derive(Debug, Default)]
struct Counters {
    downlink: AtomicUsize,
    uplink: AtomicUsize,
    messages: AtomicUsize,
}

impl Counters {
    fn snapshot(&self) -> Tally {
        Tally {
            downlink: self.downlink.load(Ordering::Acquire),
            uplink: self.uplink.load(Ordering::Acquire),
            messages: self.messages.load(Ordering::Acquire),
        }
    }

    fn reset(&self) {
        self.downlink.store(0, Ordering::Release);
        self.uplink.store(0, Ordering::Release);
        self.messages.store(0, Ordering::Release);
    }
}

/// The PS-side transport endpoint.
#[derive(Debug, Default)]
pub struct Transport {
    current: Counters,
    total: Counters,
    /// Optional message log (enabled for tests/debugging).
    log: Option<Mutex<Vec<Message>>>,
}

/// Size of a status report: two f64 measurements + ids/padding,
/// matching a small fixed MPI payload.
pub const STATUS_BYTES: usize = 32;

impl Transport {
    pub fn new() -> Self {
        Transport::default()
    }

    pub fn with_log() -> Self {
        Transport {
            log: Some(Mutex::new(Vec::new())),
            ..Default::default()
        }
    }

    /// Reset the per-round tallies. The round itself is *not* latched
    /// here — each exchange names its logical round explicitly, so a
    /// late async completion cannot be mis-stamped into the round that
    /// happens to be current at record time.
    pub fn begin_round(&self) {
        self.current.reset();
    }

    fn record(&self, tag: Tag, device: usize, round: usize,
              epoch: usize, bytes: usize, uplink: bool) {
        if uplink {
            self.current.uplink.fetch_add(bytes, Ordering::AcqRel);
            self.total.uplink.fetch_add(bytes, Ordering::AcqRel);
        } else {
            self.current.downlink.fetch_add(bytes, Ordering::AcqRel);
            self.total.downlink.fetch_add(bytes, Ordering::AcqRel);
        }
        self.current.messages.fetch_add(1, Ordering::AcqRel);
        self.total.messages.fetch_add(1, Ordering::AcqRel);
        if let Some(log) = &self.log {
            log.lock().expect("log poisoned").push(Message {
                tag,
                device,
                round,
                plan_epoch: epoch,
                bytes,
            });
        }
    }

    /// PS → device: assign the active LoRA slots + head (§4.6).
    /// Returns the counted payload bytes. The in-process "wire" is a
    /// shared reference to the global model (devices never mutate
    /// their assignment), so nothing is copied here — and assignments
    /// always travel f32, so the payload is the raw active footprint.
    pub fn send_assignment(&self, round: usize, epoch: usize,
                           device: usize, global: &TensorMap,
                           config: &LoraConfig, n_layers: usize,
                           rank_dim: usize) -> usize {
        let bytes = serialize::active_payload_bytes(
            global, config, n_layers, rank_dim);
        self.record(Tag::Assign, device, round, epoch, bytes, false);
        bytes
    }

    /// device → PS: upload the updated active slots. `bytes` is the
    /// real encoded size the engine put through the codec
    /// (`serialize::through_wire`), so the tally reflects what
    /// actually travels under `--codec`. `epoch` is the plan epoch the
    /// update was *trained* under — for an async fold landing after a
    /// re-allocation, that is the previous epoch, not the current one.
    pub fn recv_update(&self, round: usize, epoch: usize, device: usize,
                       bytes: usize) -> usize {
        self.record(Tag::Update, device, round, epoch, bytes, true);
        bytes
    }

    /// device → PS: status report (μ̂, β̂).
    pub fn recv_status(&self, round: usize, epoch: usize,
                       device: usize) {
        self.record(Tag::Status, device, round, epoch, STATUS_BYTES,
                    true);
    }

    pub fn round_tally(&self) -> Tally {
        self.current.snapshot()
    }

    pub fn total_tally(&self) -> Tally {
        self.total.snapshot()
    }

    /// Snapshot of the message log (None unless built `with_log`).
    pub fn log_snapshot(&self) -> Option<Vec<Message>> {
        self.log
            .as_ref()
            .map(|l| l.lock().expect("log poisoned").clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::masks::LayerSet;
    use crate::model::TensorSpec;

    const L: usize = 4;
    const R: usize = 3;

    #[test]
    fn tally_merged_sums_componentwise() {
        let a = Tally { downlink: 10, uplink: 3, messages: 2 };
        let b = Tally { downlink: 5, uplink: 7, messages: 1 };
        let m = a.merged(&b);
        assert_eq!(m, Tally { downlink: 15, uplink: 10, messages: 3 });
        // Identity and commutativity — the scheduler folds per-job
        // tallies in job-id order, but the total must not care.
        assert_eq!(a.merged(&Tally::default()), a);
        assert_eq!(a.merged(&b), b.merged(&a));
    }

    fn global() -> TensorMap {
        TensorMap::zeros(&[
            TensorSpec { name: "aq".into(), shape: vec![L, R, 2] },
            TensorSpec { name: "head_w".into(), shape: vec![2, 2] },
        ])
    }

    fn cfg(depth: usize) -> LoraConfig {
        LoraConfig { layers: LayerSet::Depth(depth), ranks: vec![2; L] }
    }

    fn payload(c: &LoraConfig) -> usize {
        serialize::active_payload_bytes(&global(), c, L, R)
    }

    #[test]
    fn tallies_conserve_and_split_by_direction() {
        let t = Transport::with_log();
        t.begin_round();
        let g = global();
        let c = cfg(2);
        let down = t.send_assignment(1, 0, 0, &g, &c, L, R);
        t.recv_status(1, 0, 0);
        let up = t.recv_update(1, 0, 0, payload(&c));
        let tally = t.round_tally();
        assert_eq!(down, up, "symmetric assign/update payload");
        assert_eq!(tally.downlink, up);
        assert_eq!(tally.uplink, up + STATUS_BYTES);
        assert_eq!(tally.messages, 3);
        assert_eq!(t.log_snapshot().unwrap().len(), 3);
    }

    #[test]
    fn deeper_config_costs_more_bytes() {
        let t = Transport::new();
        t.begin_round();
        let g = global();
        let _ = t.send_assignment(1, 0, 0, &g, &cfg(1), L, R);
        let shallow = t.round_tally().downlink;
        t.begin_round();
        let _ = t.send_assignment(2, 0, 0, &g, &cfg(4), L, R);
        let deep = t.round_tally().downlink;
        assert!(deep > shallow);
    }

    #[test]
    fn begin_round_resets_current_not_total() {
        let t = Transport::new();
        t.begin_round();
        t.recv_status(1, 0, 0);
        t.begin_round();
        assert_eq!(t.round_tally(), Tally::default());
        assert_eq!(t.total_tally().uplink, STATUS_BYTES);
    }

    #[test]
    fn skipped_devices_cost_nothing() {
        // Devices 0 and 2 take part, device 1 is sampled out: the
        // tally must be exactly two devices' worth of traffic and two
        // STATUS_BYTES — nothing for the skipped device.
        let t = Transport::with_log();
        t.begin_round();
        let g = global();
        let c = cfg(4);
        let mut down = 0;
        let mut up = 0;
        for dev in [0usize, 2] {
            t.recv_status(1, 0, dev);
            down += t.send_assignment(1, 0, dev, &g, &c, L, R);
            up += t.recv_update(1, 0, dev, payload(&c));
        }
        let tally = t.round_tally();
        assert_eq!(tally.downlink, down);
        assert_eq!(tally.uplink, up + 2 * STATUS_BYTES);
        assert_eq!(tally.messages, 6);
        let log = t.log_snapshot().unwrap();
        assert!(log.iter().all(|m| m.device != 1),
                "skipped device must never appear on the wire");
    }

    #[test]
    fn stale_update_logs_its_own_round() {
        // Async-engine shape of events: the exchange for round 1 is
        // tallied after begin_round has moved the endpoint on to
        // round 3. The message must carry round 1 — the logical round
        // passed by the caller — not whatever round is current at
        // record time (the old `round` atomic mis-stamped exactly this
        // case).
        let t = Transport::with_log();
        t.begin_round();
        t.recv_status(1, 0, 0);
        t.begin_round(); // round 2 opens…
        t.begin_round(); // …and round 3 opens before the fold lands.
        let stale = t.recv_update(1, 0, 0, 64);
        let fresh = t.recv_update(3, 0, 1, 64);
        assert_eq!(stale, fresh);
        let log = t.log_snapshot().unwrap();
        assert_eq!(log.len(), 3);
        assert_eq!((log[1].tag, log[1].device, log[1].round),
                   (Tag::Update, 0, 1),
                   "stale-but-admitted update keeps its own round");
        assert_eq!((log[2].tag, log[2].device, log[2].round),
                   (Tag::Update, 1, 3));
        // Arrival-time tallies are unchanged: both updates land in the
        // current window's counters.
        assert_eq!(t.round_tally().uplink, 128);
    }

    #[test]
    fn messages_carry_their_plan_epoch() {
        // An update trained under epoch 1 legally folds after the plan
        // moved on to epoch 2 (async engine + re-allocation): the log
        // must keep the epoch the exchange was produced under, exactly
        // like the logical round.
        let t = Transport::with_log();
        t.begin_round();
        let g = global();
        let c = cfg(2);
        let _ = t.send_assignment(4, 1, 0, &g, &c, L, R);
        let _ = t.recv_update(5, 1, 0, 64); // trained under epoch 1…
        t.recv_status(5, 2, 0); // …while round 5 re-planned to epoch 2.
        let log = t.log_snapshot().unwrap();
        assert_eq!(
            log.iter()
                .map(|m| (m.tag, m.round, m.plan_epoch))
                .collect::<Vec<_>>(),
            vec![
                (Tag::Assign, 4, 1),
                (Tag::Update, 5, 1),
                (Tag::Status, 5, 2),
            ]
        );
    }

    #[test]
    fn shared_across_threads() {
        // &self endpoint: concurrent status reports all land.
        let t = Transport::new();
        t.begin_round();
        std::thread::scope(|s| {
            for dev in 0..8 {
                let t = &t;
                s.spawn(move || t.recv_status(1, 0, dev));
            }
        });
        assert_eq!(t.round_tally().uplink, 8 * STATUS_BYTES);
        assert_eq!(t.round_tally().messages, 8);
    }
}
