//! Message-passing transport between the PS and devices.
//!
//! The paper's prototype uses MPI (`comm.send`/`comm.recv`, §5); here
//! the devices are in-process, but every PS↔device exchange still goes
//! through an explicit message layer with byte-exact accounting — the
//! traffic numbers in Fig. 11 are message-level, so we count them at
//! the same place the paper does. Payloads are the *logically
//! transmitted* bytes: only a device's active LoRA slots travel (plus
//! the head and a fixed-size status report), never the padded tensors.

use crate::model::masks::LoraConfig;
use crate::model::state::TensorMap;

use super::serialize;

/// Message kinds on the wire (mirrors the prototype's MPI tags).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Tag {
    /// PS → device: LoRA assignment (§4.6).
    Assign,
    /// device → PS: updated LoRA layers (§4.2).
    Update,
    /// device → PS: status report (μ̂, β̂) (§4.3).
    Status,
}

/// One accounted message.
#[derive(Debug, Clone)]
pub struct Message {
    pub tag: Tag,
    pub device: usize,
    pub round: usize,
    pub bytes: usize,
}

/// Per-round, per-direction byte tallies.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Tally {
    pub downlink: usize,
    pub uplink: usize,
    pub messages: usize,
}

/// The PS-side transport endpoint.
#[derive(Debug, Default)]
pub struct Transport {
    round: usize,
    current: Tally,
    total: Tally,
    /// Optional message log (enabled for tests/debugging).
    pub log: Option<Vec<Message>>,
}

/// Size of a status report: two f64 measurements + ids/padding,
/// matching a small fixed MPI payload.
pub const STATUS_BYTES: usize = 32;

impl Transport {
    pub fn new() -> Self {
        Transport::default()
    }

    pub fn with_log() -> Self {
        Transport { log: Some(Vec::new()), ..Default::default() }
    }

    pub fn begin_round(&mut self, round: usize) {
        self.round = round;
        self.current = Tally::default();
    }

    fn record(&mut self, tag: Tag, device: usize, bytes: usize,
              uplink: bool) {
        if uplink {
            self.current.uplink += bytes;
            self.total.uplink += bytes;
        } else {
            self.current.downlink += bytes;
            self.total.downlink += bytes;
        }
        self.current.messages += 1;
        self.total.messages += 1;
        if let Some(log) = &mut self.log {
            log.push(Message { tag, device, round: self.round, bytes });
        }
    }

    /// PS → device: assign the active LoRA slots + head (§4.6).
    /// Returns the payload so callers can hand it to the device.
    pub fn send_assignment(&mut self, device: usize, global: &TensorMap,
                           config: &LoraConfig, n_layers: usize,
                           rank_dim: usize) -> TensorMap {
        let bytes = serialize::active_payload_bytes(
            global, config, n_layers, rank_dim);
        self.record(Tag::Assign, device, bytes, false);
        // In-process "wire": the device works on its own copy.
        global.clone()
    }

    /// device → PS: upload the updated active slots.
    pub fn recv_update(&mut self, device: usize, update: &TensorMap,
                       config: &LoraConfig, n_layers: usize,
                       rank_dim: usize) -> usize {
        let bytes = serialize::active_payload_bytes(
            update, config, n_layers, rank_dim);
        self.record(Tag::Update, device, bytes, true);
        bytes
    }

    /// device → PS: status report (μ̂, β̂).
    pub fn recv_status(&mut self, device: usize) {
        self.record(Tag::Status, device, STATUS_BYTES, true);
    }

    pub fn round_tally(&self) -> Tally {
        self.current
    }

    pub fn total_tally(&self) -> Tally {
        self.total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::masks::LayerSet;
    use crate::model::TensorSpec;

    const L: usize = 4;
    const R: usize = 3;

    fn global() -> TensorMap {
        TensorMap::zeros(&[
            TensorSpec { name: "aq".into(), shape: vec![L, R, 2] },
            TensorSpec { name: "head_w".into(), shape: vec![2, 2] },
        ])
    }

    fn cfg(depth: usize) -> LoraConfig {
        LoraConfig { layers: LayerSet::Depth(depth), ranks: vec![2; L] }
    }

    #[test]
    fn tallies_conserve_and_split_by_direction() {
        let mut t = Transport::with_log();
        t.begin_round(1);
        let g = global();
        let c = cfg(2);
        let payload = t.send_assignment(0, &g, &c, L, R);
        assert_eq!(payload.numel(), g.numel());
        t.recv_status(0);
        let up = t.recv_update(0, &g, &c, L, R);
        let tally = t.round_tally();
        assert_eq!(tally.downlink, up, "symmetric assign/update payload");
        assert_eq!(tally.uplink, up + STATUS_BYTES);
        assert_eq!(tally.messages, 3);
        assert_eq!(t.log.as_ref().unwrap().len(), 3);
    }

    #[test]
    fn deeper_config_costs_more_bytes() {
        let mut t = Transport::new();
        t.begin_round(1);
        let g = global();
        let _ = t.send_assignment(0, &g, &cfg(1), L, R);
        let shallow = t.round_tally().downlink;
        t.begin_round(2);
        let _ = t.send_assignment(0, &g, &cfg(4), L, R);
        let deep = t.round_tally().downlink;
        assert!(deep > shallow);
    }

    #[test]
    fn begin_round_resets_current_not_total() {
        let mut t = Transport::new();
        t.begin_round(1);
        t.recv_status(0);
        t.begin_round(2);
        assert_eq!(t.round_tally(), Tally::default());
        assert_eq!(t.total_tally().uplink, STATUS_BYTES);
    }
}
