//! L3 — the paper's coordination contribution.
//!
//! * [`capacity`] — moving-average device capability estimation
//!   (§4.3, eq. 8–9);
//! * [`lcd`] — the LoRA Configuration Determination algorithm
//!   (Alg. 1): joint depth + rank-distribution assignment under
//!   compute/communication budgets;
//! * [`layout`] — the single (layer, rank-slot) classifier shared by
//!   the wire codec and every aggregator;
//! * [`aggregation`] — adaptive layer-wise (rank-slot-aware)
//!   aggregation of heterogeneous updates (§4.5, eq. 17);
//! * [`strategy`] — LEGEND, its two ablations, and the FedLoRA /
//!   HetLoRA / FedAdapter baselines plus the §2 pre-test variants;
//! * [`trainer`] — local fine-tuning backends (PJRT-real and mock),
//!   split into coordinator-facing [`trainer::Trainer`] and `Send`-able
//!   per-device [`trainer::DeviceTrainer`] handles;
//! * [`participation`] — cohort policies (full / uniform sampling /
//!   straggler-deadline drop);
//! * [`engine`] — the parallel, streaming round loop;
//! * [`async_engine`] — the staleness-windowed, event-driven round
//!   loop (devices fold across round boundaries with staleness
//!   weights);
//! * [`jobs`] — the multi-job scheduler: disjoint per-job cohorts
//!   over a shared fleet, per-job token-bucket ingest limits, and
//!   capacity-based admission control (docs/MULTIJOB.md);
//! * [`server`] — run configuration + the public entry points.

pub mod aggregation;
pub mod async_engine;
pub mod capacity;
pub mod engine;
pub mod jobs;
pub mod layout;
pub mod lcd;
pub mod participation;
pub mod serialize;
pub mod server;
pub mod strategy;
pub mod transport;
pub mod trainer;

pub use async_engine::AsyncEngine;
pub use engine::RoundEngine;
pub use jobs::{
    AdmissionError, JobScheduler, JobSpec, MultiJobReport, RateLimit,
    TokenBucket,
};
pub use serialize::Codec;
pub use server::{run_federated, run_federated_with, FedConfig, ModelMeta};
