//! L3 — the paper's coordination contribution.
//!
//! * [`capacity`] — moving-average device capability estimation
//!   (§4.3, eq. 8–9);
//! * [`lcd`] — the LoRA Configuration Determination algorithm
//!   (Alg. 1): joint depth + rank-distribution assignment under
//!   compute/communication budgets;
//! * [`aggregation`] — adaptive layer-wise (rank-slot-aware)
//!   aggregation of heterogeneous updates (§4.5, eq. 17);
//! * [`strategy`] — LEGEND, its two ablations, and the FedLoRA /
//!   HetLoRA / FedAdapter baselines plus the §2 pre-test variants;
//! * [`trainer`] — local fine-tuning backends (PJRT-real and mock);
//! * [`server`] — the parameter-server round loop tying it together.

pub mod aggregation;
pub mod capacity;
pub mod lcd;
pub mod serialize;
pub mod server;
pub mod strategy;
pub mod transport;
pub mod trainer;

pub use server::{run_federated, FedConfig, ModelMeta};
