//! Participation policies — which devices take part in a round.
//!
//! The paper's prototype uses full participation (every device, every
//! round). Production federated systems rarely do: cross-device FL
//! samples a cohort per round, and semi-synchronous systems drop
//! predicted stragglers to bound round time. The [`Participation`]
//! trait factors that decision out of the round loop
//! (`coordinator/engine.rs`) with two hooks:
//!
//! * [`Participation::sample`] — *before* configuration: pick the
//!   round's cohort. Sampled-out devices exchange no bytes at all.
//! * [`Participation::admit`] — *after* configuration: given each
//!   cohort member's predicted eq. 12 completion time (from the
//!   PS-side capacity estimates), drop the ones that would blow a
//!   deadline. Dropped devices never receive an assignment, so they
//!   contribute zero uplink/downlink to the round tally.
//!
//! All randomness flows through the engine-provided [`Rng`] (a child
//! stream of the run seed), so cohorts are reproducible and
//! independent of thread count.

use std::collections::BTreeSet;

use crate::sim::clock::median_completion;
use crate::util::rng::Rng;

/// Cohort-selection policy hook.
pub trait Participation {
    fn name(&self) -> String;

    /// Check the policy against the fleet it is about to run on. The
    /// engines call this once at run start, so a misconfiguration (e.g.
    /// a fixed cohort size larger than the fleet) fails loudly before
    /// round 1 instead of panicking or silently truncating downstream.
    fn validate(&self, _n_devices: usize) -> Result<(), String> {
        Ok(())
    }

    /// Pick the devices that take part this round (any order; the
    /// engine sorts/dedups). Must be non-empty: an empty or fully
    /// out-of-range result makes the engine run a minimal round with
    /// device 0 only. Default: everyone.
    fn sample(&mut self, _round: usize, n_devices: usize,
              _rng: &mut Rng) -> Vec<usize> {
        (0..n_devices).collect()
    }

    /// Filter the configured cohort by predicted completion time
    /// (`predicted[j]` belongs to `cohort[j]`). Must return a
    /// non-empty subset of `cohort`; an empty or out-of-cohort result
    /// makes the engine admit only the fastest-predicted device (a
    /// round needs ≥ 1 participant). Default: keep everyone.
    fn admit(&mut self, _round: usize, cohort: &[usize],
             _predicted: &[f64]) -> Vec<usize> {
        cohort.to_vec()
    }
}

/// The paper's setting: all devices, every round.
pub struct Full;

impl Participation for Full {
    fn name(&self) -> String {
        "full".into()
    }
}

/// Uniform client sampling: a fresh random ⌈fraction·n⌉-subset per
/// round (cross-device FL style).
pub struct UniformSample {
    pub fraction: f64,
}

impl Participation for UniformSample {
    fn name(&self) -> String {
        format!("sample({:.2})", self.fraction)
    }

    fn sample(&mut self, _round: usize, n_devices: usize,
              rng: &mut Rng) -> Vec<usize> {
        let k = ((self.fraction * n_devices as f64).ceil() as usize)
            .clamp(1, n_devices.max(1));
        let mut ids: Vec<usize> = (0..n_devices).collect();
        rng.shuffle(&mut ids);
        ids.truncate(k);
        ids.sort_unstable();
        ids
    }
}

/// Uniform sampling of an *absolute* cohort size — the cross-device
/// configuration ("1,000 of 1,000,000 per round"), where a fraction
/// would be unwieldy. Samples without materializing the id range, so
/// it stays O(count) however large the fleet is.
pub struct UniformCount {
    pub count: usize,
}

impl Participation for UniformCount {
    fn name(&self) -> String {
        format!("count({})", self.count)
    }

    fn validate(&self, n_devices: usize) -> Result<(), String> {
        if self.count == 0 {
            return Err("cohort size must be ≥ 1".into());
        }
        if self.count > n_devices {
            return Err(format!(
                "cohort size {} exceeds fleet size {n_devices}",
                self.count
            ));
        }
        Ok(())
    }

    fn sample(&mut self, _round: usize, n_devices: usize,
              rng: &mut Rng) -> Vec<usize> {
        let k = self.count.clamp(1, n_devices.max(1));
        if k * 2 >= n_devices {
            // Dense regime: rejection would thrash; shuffle instead.
            let mut ids: Vec<usize> = (0..n_devices).collect();
            rng.shuffle(&mut ids);
            ids.truncate(k);
            ids.sort_unstable();
            return ids;
        }
        // Sparse regime (k ≪ n): rejection-sample distinct ids without
        // ever allocating the fleet-sized range.
        let mut picked = BTreeSet::new();
        while picked.len() < k {
            picked.insert(rng.range(0, n_devices));
        }
        picked.into_iter().collect()
    }
}

/// Straggler-deadline drop (semi-synchronous rounds): admit devices
/// whose predicted eq. 12 completion time is within
/// `factor × median(cohort)`; always keep the `min_keep` fastest so a
/// round can never empty out.
pub struct DeadlineDrop {
    pub factor: f64,
    pub min_keep: usize,
}

impl DeadlineDrop {
    pub fn new(factor: f64) -> Self {
        DeadlineDrop { factor, min_keep: 1 }
    }
}

impl Participation for DeadlineDrop {
    fn name(&self) -> String {
        format!("deadline({:.2}×median)", self.factor)
    }

    fn admit(&mut self, _round: usize, cohort: &[usize],
             predicted: &[f64]) -> Vec<usize> {
        if cohort.is_empty() {
            return Vec::new();
        }
        let deadline = self.factor * median_completion(predicted);
        let mut keep: Vec<usize> = (0..cohort.len())
            .filter(|&j| predicted[j] <= deadline)
            .collect();
        if keep.len() < self.min_keep.min(cohort.len()) {
            // Deadline too tight: fall back to the fastest devices.
            let mut order: Vec<usize> = (0..cohort.len()).collect();
            order.sort_by(|&a, &b| {
                predicted[a]
                    .total_cmp(&predicted[b])
                    .then(cohort[a].cmp(&cohort[b]))
            });
            keep = order;
            keep.truncate(self.min_keep.min(cohort.len()));
            keep.sort_unstable();
        }
        keep.into_iter().map(|j| cohort[j]).collect()
    }
}

/// Build a policy by name (CLI entry point). A non-positive (or NaN)
/// `deadline_factor` is rejected loudly: `DeadlineDrop` with factor
/// ≤ 0 would set every deadline to ≤ 0 and silently degrade to
/// "min_keep fastest devices", which is never what the caller asked
/// for.
pub fn by_name(name: &str, sample_frac: f64, sample_count: usize,
               deadline_factor: f64)
               -> Result<Box<dyn Participation>, String> {
    match name {
        "full" => Ok(Box::new(Full)),
        "sample" => Ok(Box::new(UniformSample { fraction: sample_frac })),
        "count" => Ok(Box::new(UniformCount { count: sample_count })),
        "deadline" => {
            if !(deadline_factor > 0.0) {
                return Err(format!(
                    "deadline factor must be > 0, got {deadline_factor}"
                ));
            }
            Ok(Box::new(DeadlineDrop::new(deadline_factor)))
        }
        other => Err(format!("unknown participation policy {other:?}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_selects_everyone() {
        let mut p = Full;
        let mut rng = Rng::new(1);
        assert_eq!(p.sample(1, 4, &mut rng), vec![0, 1, 2, 3]);
        assert_eq!(p.admit(1, &[0, 2], &[1.0, 2.0]), vec![0, 2]);
    }

    #[test]
    fn uniform_sample_size_and_determinism() {
        let mut p = UniformSample { fraction: 0.25 };
        let mut rng = Rng::new(7);
        let a = p.sample(1, 80, &mut rng);
        assert_eq!(a.len(), 20);
        assert!(a.windows(2).all(|w| w[0] < w[1]), "sorted unique");
        assert!(a.iter().all(|&i| i < 80));
        // Same seed ⇒ same cohort; later draws differ.
        let mut rng2 = Rng::new(7);
        assert_eq!(p.sample(1, 80, &mut rng2), a);
        let b = p.sample(2, 80, &mut rng);
        assert_ne!(a, b, "fresh cohort per round");
    }

    #[test]
    fn uniform_sample_never_empty() {
        let mut p = UniformSample { fraction: 0.001 };
        let mut rng = Rng::new(3);
        assert_eq!(p.sample(1, 10, &mut rng).len(), 1);
    }

    #[test]
    fn deadline_drops_only_stragglers() {
        let mut p = DeadlineDrop::new(1.5);
        let cohort = [0, 1, 2, 3, 4];
        let predicted = [1.0, 1.1, 1.2, 1.3, 10.0];
        // median 1.2, deadline 1.8 → device 4 dropped.
        assert_eq!(p.admit(1, &cohort, &predicted), vec![0, 1, 2, 3]);
    }

    #[test]
    fn deadline_keeps_fastest_when_too_tight() {
        let mut p = DeadlineDrop { factor: 0.01, min_keep: 2 };
        let cohort = [5, 6, 7];
        let predicted = [3.0, 1.0, 2.0];
        assert_eq!(p.admit(1, &cohort, &predicted), vec![6, 7]);
    }

    #[test]
    fn uniform_count_samples_exact_distinct_cohort() {
        let mut p = UniformCount { count: 50 };
        let mut rng = Rng::new(11);
        // Sparse regime: 50 of 100_000 without touching the range.
        let a = p.sample(1, 100_000, &mut rng);
        assert_eq!(a.len(), 50);
        assert!(a.windows(2).all(|w| w[0] < w[1]), "sorted unique");
        assert!(a.iter().all(|&i| i < 100_000));
        // Deterministic given the stream position.
        let mut rng2 = Rng::new(11);
        assert_eq!(p.sample(1, 100_000, &mut rng2), a);
        // Dense regime falls back to the shuffle and stays exact.
        let b = p.sample(2, 60, &mut rng);
        assert_eq!(b.len(), 50);
        assert!(b.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn oversized_cohort_is_rejected_not_truncated() {
        // Regression: a cohort larger than the fleet must surface as a
        // proper Err from validate, not a panic or silent truncation.
        let p = UniformCount { count: 1_001 };
        let err = p.validate(1_000).expect_err("must reject");
        assert!(err.contains("exceeds fleet size"), "{err}");
        assert!(p.validate(1_001).is_ok());
        assert!(p.validate(2_000).is_ok());
        assert!(UniformCount { count: 0 }.validate(10).is_err());
        // The default hook accepts anything.
        assert!(Full.validate(0).is_ok());
    }

    #[test]
    fn uniform_count_validates_against_residual_slice() {
        // Multi-job admission hands validate the *residual* fleet
        // slice (fleet minus earlier jobs' reservations), not the full
        // fleet. A --sample-count that fits the fleet but not the
        // residual must surface as a proper Err — the admission path
        // turns it into AdmissionError::Participation — never a panic
        // or a silently truncated cohort.
        let p = UniformCount { count: 10 };
        assert!(p.validate(80).is_ok(), "fits the whole fleet");
        let err = p.validate(6).expect_err("must reject the residual");
        assert!(err.contains("exceeds fleet size"), "{err}");
        // Fully-reserved fleet: residual 0 rejects any count.
        assert!(UniformCount { count: 1 }.validate(0).is_err());
    }

    #[test]
    fn by_name_covers_policies() {
        for n in ["full", "sample", "count", "deadline"] {
            assert!(by_name(n, 0.3, 10, 1.5).is_ok(), "{n}");
        }
        assert!(by_name("nope", 0.3, 10, 1.5).is_err());
    }

    #[test]
    fn by_name_rejects_nonpositive_deadline_factor() {
        for bad in [0.0, -1.0, f64::NAN] {
            let e = by_name("deadline", 0.3, 10, bad)
                .map(|_| ())
                .expect_err("factor must be rejected");
            assert!(e.contains("deadline factor"), "{e}");
        }
        // Other policies ignore the factor entirely — a bogus value
        // must not poison them.
        assert!(by_name("full", 0.3, 10, 0.0).is_ok());
        assert!(by_name("sample", 0.3, 10, -2.0).is_ok());
    }
}
