//! Local fine-tuning backends.
//!
//! The trait is split in two so phase ④ of the round loop can run
//! devices concurrently (see `coordinator/engine.rs`):
//!
//! * [`Trainer`] is the coordinator-facing side: family/batch
//!   metadata, global-model evaluation, and [`Trainer::train_cohort`],
//!   which runs one round's local epochs and feeds outcomes to a sink.
//! * [`DeviceTrainer`] is a *per-device handle* owning all
//!   device-local state — optimizer moments, step counters, the
//!   data-shuffle RNG, mock progress. Handles are plain data, so a
//!   backend whose handles are `Send` can train them on worker
//!   threads (`engine::train_parallel`); a backend tied to a
//!   non-thread-safe runtime trains them in device order
//!   (`engine::train_sequential`).
//!
//! [`PjrtTrainer`] is the real backend: it drives the AOT train/eval
//! executables through the PJRT runtime. Its handles hold per-device
//! AdamW state and step counters across rounds (optimizer state is
//! local to a device, as in FedNLP-style systems), but they also
//! borrow the shared `Runtime`, whose PJRT client is not thread-safe —
//! so PJRT cohorts run sequentially. [`MockTrainer`] is a
//! deterministic FLOP-free stand-in used by coordinator
//! unit/property tests and the L3-only benchmarks; its handles are
//! `Send` and train in parallel.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::data::Dataset;
use crate::model::state::{init_opt, TensorMap};
use crate::runtime::session::SessionState;
use crate::runtime::{Masks, Runtime};
use crate::util::rng::Rng;

use super::engine::{train_parallel, train_sequential, ExecOpts,
                    TrainJob};

/// Result of one device's local epoch.
#[derive(Debug, Clone)]
pub struct LocalOutcome {
    pub trainable: TensorMap,
    pub mean_loss: f64,
    pub train_accuracy: f64,
    pub n_steps: usize,
}

/// Receives `(job_index, outcome)` pairs **in job-index order** — the
/// execution layer (`engine::train_parallel`) re-serializes worker
/// completions through its reorder buffer before calling the sink, so
/// downstream accounting and aggregation see an identical stream
/// regardless of which worker thread finished first.
pub type CohortSink<'s> =
    &'s mut dyn FnMut(usize, LocalOutcome) -> Result<()>;

/// A per-device local-training handle. Owns every piece of
/// device-local state so nothing on the coordinator is mutated during
/// training; implementations that are `Send` may be driven from
/// worker threads.
pub trait DeviceTrainer {
    /// Run one local epoch from `job.init` under `job.masks` over
    /// `job.shard` (at most `job.max_batches` batches).
    fn train_local(&mut self, job: &TrainJob<'_>) -> Result<LocalOutcome>;
}

/// Coordinator-facing training backend (real PJRT or mock).
pub trait Trainer {
    fn family(&self) -> &'static str;
    fn batch_size(&self) -> usize;
    /// Run phase ④ for one round's cohort. `jobs[i]` carries device
    /// `jobs[i].device_id`'s assignment; outcomes are delivered to
    /// `sink` as `(i, outcome)` in job-index order. Implementations
    /// may complete jobs on any thread (honoring `opts.threads` and
    /// the `opts.window` in-flight bound), but each device's outcome
    /// MUST be a pure function of `(job, that device's persistent
    /// state)` so results are identical at every `threads × window`
    /// setting.
    fn train_cohort(&mut self, jobs: &[TrainJob<'_>], opts: &ExecOpts,
                    sink: CohortSink<'_>) -> Result<()>;
    /// Evaluate a global model on `ds`; returns (mean_loss, accuracy).
    fn evaluate(&mut self, trainable: &TensorMap, masks: &Masks,
                ds: &Dataset) -> Result<(f64, f64)>;
}

// ---------------------------------------------------------------------------
// PJRT backend
// ---------------------------------------------------------------------------

/// One device's persistent PJRT-side state: AdamW moments, step
/// counter, and a device-keyed shuffle RNG (so the shuffle stream is
/// independent of the order devices train in).
struct PjrtDeviceState {
    opt: TensorMap,
    step: f32,
    rng: Rng,
}

/// Per-device handle borrowing the shared runtime. NOT `Send`: the
/// PJRT CPU client behind `rt` is not thread-safe, so PJRT cohorts
/// train sequentially.
struct PjrtDevice<'r> {
    rt: &'r Runtime,
    family: &'static str,
    state: PjrtDeviceState,
}

impl DeviceTrainer for PjrtDevice<'_> {
    fn train_local(&mut self, job: &TrainJob<'_>) -> Result<LocalOutcome> {
        let mut session =
            SessionState::from_maps(job.init, &self.state.opt)?;
        let shuffled = job.shard.shuffled(&mut self.state.rng);
        let batches = shuffled.batches(self.rt.manifest.dim.batch_size);
        let n = batches.len().min(job.max_batches.max(1));
        let mut loss_sum = 0f64;
        let mut correct = 0f64;
        let mut seen = 0usize;
        for (toks, labels) in batches.iter().take(n) {
            // detlint-allow: float-accum per-device step counter advances in batch order
            self.state.step += 1.0;
            let stats = self.rt.train_step(
                self.family, &mut session, &job.masks, toks, labels,
                job.lr, self.state.step,
            )?;
            // detlint-allow: float-accum one device's batches fold in fixed shard order
            loss_sum += stats.loss as f64;
            // detlint-allow: float-accum one device's batches fold in fixed shard order
            correct += stats.correct as f64;
            seen += labels.len();
        }
        let (trainable, new_opt) = session.to_maps()?;
        self.state.opt = new_opt;
        Ok(LocalOutcome {
            trainable,
            mean_loss: loss_sum / n as f64,
            train_accuracy: correct / seen.max(1) as f64,
            n_steps: n,
        })
    }
}

/// Real backend: PJRT executables + per-device optimizer state.
pub struct PjrtTrainer<'a> {
    rt: &'a Runtime,
    family: &'static str,
    seed: u64,
    devices: BTreeMap<usize, PjrtDeviceState>,
}

impl<'a> PjrtTrainer<'a> {
    pub fn new(rt: &'a Runtime, family: &'static str, seed: u64) -> Self {
        PjrtTrainer { rt, family, seed, devices: BTreeMap::new() }
    }

    fn state_for(&mut self, device_id: usize) -> PjrtDeviceState {
        let fam = self.rt.manifest.family(self.family);
        let seed = self.seed;
        self.devices.remove(&device_id).unwrap_or_else(|| {
            PjrtDeviceState {
                opt: init_opt(fam),
                step: 0.0,
                rng: Rng::new(seed)
                    .child("trainer")
                    .child(&format!("dev{device_id}")),
            }
        })
    }
}

impl Trainer for PjrtTrainer<'_> {
    fn family(&self) -> &'static str {
        self.family
    }

    fn batch_size(&self) -> usize {
        self.rt.manifest.dim.batch_size
    }

    fn train_cohort(&mut self, jobs: &[TrainJob<'_>], _opts: &ExecOpts,
                    sink: CohortSink<'_>) -> Result<()> {
        let mut handles: Vec<PjrtDevice<'_>> = jobs
            .iter()
            .map(|j| PjrtDevice {
                rt: self.rt,
                family: self.family,
                state: self.state_for(j.device_id),
            })
            .collect();
        let res = train_sequential(jobs, &mut handles, sink);
        for (job, h) in jobs.iter().zip(handles) {
            self.devices.insert(job.device_id, h.state);
        }
        res.map(|_| ())
    }

    fn evaluate(&mut self, trainable: &TensorMap, masks: &Masks,
                ds: &Dataset) -> Result<(f64, f64)> {
        self.rt.evaluate(self.family, trainable, masks, ds)
    }
}

// ---------------------------------------------------------------------------
// Mock backend
// ---------------------------------------------------------------------------

/// One mock device's persistent state + training rule. `Send`, so mock
/// cohorts exercise the parallel engine path.
///
/// Training nudges every tensor element by a fixed delta per local
/// batch and accumulates a "progress" scalar per slot-mass trained;
/// loss/accuracy are saturating functions of progress, so more
/// layers/ranks/steps → better numbers, mirroring the qualitative
/// behaviour the coordinator cares about. The outcome depends only on
/// the job and this device's own history — never on other devices —
/// which is what makes the parallel path bit-identical to sequential.
pub struct MockDevice {
    batch: usize,
    pub progress: f64,
}

impl DeviceTrainer for MockDevice {
    fn train_local(&mut self, job: &TrainJob<'_>) -> Result<LocalOutcome> {
        let mut out = job.init.clone();
        let active: f64 =
            job.masks.rank_mask.iter().map(|&m| m as f64).sum();
        let n = job
            .shard
            .len()
            .div_ceil(self.batch)
            .min(job.max_batches.max(1));
        // One deterministic nudge pass per local batch (work scales
        // with the epoch length, like a real backend's would).
        for _ in 0..n {
            for (_, v) in &mut out.entries {
                for x in v.iter_mut() {
                    // detlint-allow: float-accum fixed nudge applied in tensor-entry order
                    *x += 1e-3;
                }
            }
        }
        // detlint-allow: float-accum per-device progress scalar, single-owner handle
        self.progress += active * n as f64 * 0.01;
        Ok(LocalOutcome {
            trainable: out,
            mean_loss: 1.0 / (1.0 + 0.02 * self.progress),
            train_accuracy: 1.0 - 1.0 / (1.0 + 0.05 * self.progress),
            n_steps: n,
        })
    }
}

/// Deterministic FLOP-free backend for tests/benches.
pub struct MockTrainer {
    family: &'static str,
    batch: usize,
    devices: BTreeMap<usize, MockDevice>,
}

impl MockTrainer {
    pub fn new(family: &'static str) -> Self {
        MockTrainer { family, batch: 4, devices: BTreeMap::new() }
    }

    /// Σ progress over all devices (fleet-wide training effort).
    pub fn total_progress(&self) -> f64 {
        self.devices.values().map(|d| d.progress).sum()
    }

    pub fn accuracy(&self) -> f64 {
        1.0 - 1.0 / (1.0 + 0.05 * self.total_progress())
    }
}

impl Trainer for MockTrainer {
    fn family(&self) -> &'static str {
        self.family
    }

    fn batch_size(&self) -> usize {
        self.batch
    }

    fn train_cohort(&mut self, jobs: &[TrainJob<'_>], opts: &ExecOpts,
                    sink: CohortSink<'_>) -> Result<()> {
        let batch = self.batch;
        let mut handles: Vec<MockDevice> = jobs
            .iter()
            .map(|j| {
                self.devices
                    .remove(&j.device_id)
                    .unwrap_or(MockDevice { batch, progress: 0.0 })
            })
            .collect();
        let res = train_parallel(jobs, &mut handles, opts, sink);
        for (job, h) in jobs.iter().zip(handles) {
            self.devices.insert(job.device_id, h);
        }
        res.map(|_| ())
    }

    fn evaluate(&mut self, _trainable: &TensorMap, _masks: &Masks,
                _ds: &Dataset) -> Result<(f64, f64)> {
        Ok((1.0 / (1.0 + 0.02 * self.total_progress()), self.accuracy()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Example;
    use crate::model::TensorSpec;

    fn toy_dataset(n: usize) -> Dataset {
        Dataset {
            examples: (0..n)
                .map(|i| Example {
                    tokens: vec![1, 2, 3, 0],
                    label: (i % 2) as i32,
                })
                .collect(),
        }
    }

    fn toy_map() -> TensorMap {
        TensorMap::zeros(&[TensorSpec {
            name: "aq".into(),
            shape: vec![2, 2, 2],
        }])
    }

    fn job<'a>(device_id: usize, init: &'a TensorMap, masks: &Masks,
               shard: &'a Dataset, max_batches: usize) -> TrainJob<'a> {
        TrainJob {
            device_id,
            init,
            masks: masks.clone(),
            shard,
            lr: 1e-3,
            max_batches,
        }
    }

    fn run_one(t: &mut MockTrainer, device_id: usize, init: &TensorMap,
               masks: &Masks, shard: &Dataset, max_batches: usize)
               -> LocalOutcome {
        let jobs = vec![job(device_id, init, masks, shard, max_batches)];
        let mut got = None;
        let opts = ExecOpts { threads: 1, window: 0 };
        t.train_cohort(&jobs, &opts, &mut |_, o| {
            got = Some(o);
            Ok(())
        })
        .unwrap();
        got.unwrap()
    }

    #[test]
    fn mock_trainer_progresses_monotonically() {
        let mut t = MockTrainer::new("lora");
        let ds = toy_dataset(16);
        let masks = Masks {
            rank_mask: vec![1.0; 4],
            layer_mask: vec![1.0; 2],
        };
        let init = toy_map();
        let o1 = run_one(&mut t, 0, &init, &masks, &ds, 100);
        let a1 = t.accuracy();
        let o2 = run_one(&mut t, 0, &o1.trainable, &masks, &ds, 100);
        assert!(o2.mean_loss < o1.mean_loss);
        assert!(t.accuracy() > a1);
        assert_eq!(o1.n_steps, 4);
    }

    #[test]
    fn mock_trainer_respects_batch_cap() {
        let mut t = MockTrainer::new("lora");
        let ds = toy_dataset(64);
        let masks = Masks {
            rank_mask: vec![1.0; 4],
            layer_mask: vec![1.0; 2],
        };
        let init = toy_map();
        let o = run_one(&mut t, 0, &init, &masks, &ds, 3);
        assert_eq!(o.n_steps, 3);
    }

    #[test]
    fn more_active_slots_progress_faster() {
        let ds = toy_dataset(16);
        let wide =
            Masks { rank_mask: vec![1.0; 8], layer_mask: vec![1.0; 2] };
        let narrow = Masks {
            rank_mask: vec![1.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0],
            layer_mask: vec![1.0; 2],
        };
        let init = toy_map();
        let mut a = MockTrainer::new("lora");
        let mut b = MockTrainer::new("lora");
        run_one(&mut a, 0, &init, &wide, &ds, 100);
        run_one(&mut b, 0, &init, &narrow, &ds, 100);
        assert!(a.accuracy() > b.accuracy());
    }

    #[test]
    fn device_state_is_isolated_per_device() {
        // Training device 0 must not change device 1's loss.
        let ds = toy_dataset(16);
        let masks = Masks {
            rank_mask: vec![1.0; 4],
            layer_mask: vec![1.0; 2],
        };
        let init = toy_map();
        let mut t = MockTrainer::new("lora");
        run_one(&mut t, 0, &init, &masks, &ds, 100);
        run_one(&mut t, 0, &init, &masks, &ds, 100);
        let o1 = run_one(&mut t, 1, &init, &masks, &ds, 100);

        let mut fresh = MockTrainer::new("lora");
        let o1f = run_one(&mut fresh, 1, &init, &masks, &ds, 100);
        assert_eq!(o1.mean_loss, o1f.mean_loss,
                   "device 1 unaffected by device 0 history");
    }

    #[test]
    fn cohort_outcomes_identical_at_any_thread_count() {
        let ds = toy_dataset(32);
        let masks = Masks {
            rank_mask: vec![1.0; 4],
            layer_mask: vec![1.0; 2],
        };
        let init = toy_map();
        let run = |threads: usize| -> Vec<LocalOutcome> {
            let mut t = MockTrainer::new("lora");
            let jobs: Vec<TrainJob<'_>> = (0..12)
                .map(|i| job(i, &init, &masks, &ds, 4))
                .collect();
            let mut outs: Vec<Option<LocalOutcome>> =
                (0..jobs.len()).map(|_| None).collect();
            let opts = ExecOpts { threads, window: 0 };
            t.train_cohort(&jobs, &opts, &mut |i, o| {
                outs[i] = Some(o);
                Ok(())
            })
            .unwrap();
            outs.into_iter().map(|o| o.unwrap()).collect()
        };
        let seq = run(1);
        let par = run(4);
        for (a, b) in seq.iter().zip(&par) {
            assert_eq!(a.mean_loss, b.mean_loss);
            assert_eq!(a.trainable, b.trainable);
            assert_eq!(a.n_steps, b.n_steps);
        }
    }
}
