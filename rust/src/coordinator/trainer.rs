//! Local fine-tuning backends.
//!
//! [`PjrtTrainer`] is the real thing: it drives the AOT train/eval
//! executables through the PJRT runtime, keeping per-device AdamW
//! state and step counters across rounds (optimizer state is local to
//! a device, as in FedNLP-style systems). [`MockTrainer`] is a
//! deterministic stand-in used by coordinator unit/property tests and
//! the L3-only benchmarks — it exercises the identical server code
//! path with zero FLOPs.

use std::collections::HashMap;

use anyhow::Result;

use crate::data::Dataset;
use crate::model::state::{init_opt, TensorMap};
use crate::runtime::session::SessionState;
use crate::runtime::{Masks, Runtime};
use crate::util::rng::Rng;

/// Result of one device's local epoch.
#[derive(Debug, Clone)]
pub struct LocalOutcome {
    pub trainable: TensorMap,
    pub mean_loss: f64,
    pub train_accuracy: f64,
    pub n_steps: usize,
}

/// Local-training backend interface (real PJRT or mock).
pub trait Trainer {
    fn family(&self) -> &'static str;
    fn batch_size(&self) -> usize;
    /// Run one local epoch from `init`, under `masks`, over `shard`
    /// (at most `max_batches` batches).
    fn train_local(&mut self, device_id: usize, init: &TensorMap,
                   masks: &Masks, shard: &Dataset, lr: f32,
                   max_batches: usize) -> Result<LocalOutcome>;
    /// Evaluate a global model on `ds`; returns (mean_loss, accuracy).
    fn evaluate(&mut self, trainable: &TensorMap, masks: &Masks,
                ds: &Dataset) -> Result<(f64, f64)>;
}

/// Real backend: PJRT executables, per-device optimizer state.
pub struct PjrtTrainer<'a> {
    rt: &'a Runtime,
    family: &'static str,
    opt: HashMap<usize, TensorMap>,
    steps: HashMap<usize, f32>,
    rng: Rng,
}

impl<'a> PjrtTrainer<'a> {
    pub fn new(rt: &'a Runtime, family: &'static str, seed: u64) -> Self {
        PjrtTrainer {
            rt,
            family,
            opt: HashMap::new(),
            steps: HashMap::new(),
            rng: Rng::new(seed).child("trainer"),
        }
    }
}

impl Trainer for PjrtTrainer<'_> {
    fn family(&self) -> &'static str {
        self.family
    }

    fn batch_size(&self) -> usize {
        self.rt.manifest.dim.batch_size
    }

    fn train_local(&mut self, device_id: usize, init: &TensorMap,
                   masks: &Masks, shard: &Dataset, lr: f32,
                   max_batches: usize) -> Result<LocalOutcome> {
        let fam = self.rt.manifest.family(self.family).clone();
        let opt = self
            .opt
            .entry(device_id)
            .or_insert_with(|| init_opt(&fam));
        let step = self.steps.entry(device_id).or_insert(0.0);

        let mut session = SessionState::from_maps(init, opt)?;
        let shuffled = shard.shuffled(&mut self.rng);
        let batches = shuffled.batches(self.rt.manifest.dim.batch_size);
        let n = batches.len().min(max_batches.max(1));
        let (mut loss_sum, mut correct, mut seen) = (0f64, 0f64, 0usize);
        for (toks, labels) in batches.iter().take(n) {
            *step += 1.0;
            let stats = self.rt.train_step(
                self.family, &mut session, masks, toks, labels, lr, *step,
            )?;
            loss_sum += stats.loss as f64;
            correct += stats.correct as f64;
            seen += labels.len();
        }
        let (trainable, new_opt) = session.to_maps()?;
        *opt = new_opt;
        Ok(LocalOutcome {
            trainable,
            mean_loss: loss_sum / n as f64,
            train_accuracy: correct / seen.max(1) as f64,
            n_steps: n,
        })
    }

    fn evaluate(&mut self, trainable: &TensorMap, masks: &Masks,
                ds: &Dataset) -> Result<(f64, f64)> {
        self.rt.evaluate(self.family, trainable, masks, ds)
    }
}

/// Deterministic FLOP-free backend for tests/benches.
///
/// Training nudges active slots by a fixed delta and tracks a
/// "progress" scalar per slot-mass trained; accuracy is a saturating
/// function of progress, so more layers/ranks/steps → higher accuracy,
/// mirroring the qualitative behaviour the coordinator cares about.
pub struct MockTrainer {
    family: &'static str,
    batch: usize,
    pub progress: f64,
}

impl MockTrainer {
    pub fn new(family: &'static str) -> Self {
        MockTrainer { family, batch: 4, progress: 0.0 }
    }

    pub fn accuracy(&self) -> f64 {
        1.0 - 1.0 / (1.0 + 0.05 * self.progress)
    }
}

impl Trainer for MockTrainer {
    fn family(&self) -> &'static str {
        self.family
    }

    fn batch_size(&self) -> usize {
        self.batch
    }

    fn train_local(&mut self, _device_id: usize, init: &TensorMap,
                   masks: &Masks, shard: &Dataset, _lr: f32,
                   max_batches: usize) -> Result<LocalOutcome> {
        let mut out = init.clone();
        let active: f64 =
            masks.rank_mask.iter().map(|&m| m as f64).sum();
        let n = shard
            .len()
            .div_ceil(self.batch)
            .min(max_batches.max(1));
        // Nudge every active-slot tensor deterministically.
        for (_, v) in &mut out.entries {
            for x in v.iter_mut() {
                *x += 1e-3;
            }
        }
        self.progress += active * n as f64 * 0.01;
        Ok(LocalOutcome {
            trainable: out,
            mean_loss: 1.0 / (1.0 + 0.02 * self.progress),
            train_accuracy: self.accuracy(),
            n_steps: n,
        })
    }

    fn evaluate(&mut self, _trainable: &TensorMap, _masks: &Masks,
                _ds: &Dataset) -> Result<(f64, f64)> {
        Ok((1.0 / (1.0 + 0.02 * self.progress), self.accuracy()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Example;
    use crate::model::TensorSpec;

    fn toy_dataset(n: usize) -> Dataset {
        Dataset {
            examples: (0..n)
                .map(|i| Example {
                    tokens: vec![1, 2, 3, 0],
                    label: (i % 2) as i32,
                })
                .collect(),
        }
    }

    fn toy_map() -> TensorMap {
        TensorMap::zeros(&[TensorSpec {
            name: "aq".into(),
            shape: vec![2, 2, 2],
        }])
    }

    #[test]
    fn mock_trainer_progresses_monotonically() {
        let mut t = MockTrainer::new("lora");
        let ds = toy_dataset(16);
        let masks = Masks {
            rank_mask: vec![1.0; 4],
            layer_mask: vec![1.0; 2],
        };
        let init = toy_map();
        let o1 = t.train_local(0, &init, &masks, &ds, 1e-3, 100).unwrap();
        let a1 = t.accuracy();
        let o2 = t
            .train_local(0, &o1.trainable, &masks, &ds, 1e-3, 100)
            .unwrap();
        assert!(o2.mean_loss < o1.mean_loss);
        assert!(t.accuracy() > a1);
        assert_eq!(o1.n_steps, 4);
    }

    #[test]
    fn mock_trainer_respects_batch_cap() {
        let mut t = MockTrainer::new("lora");
        let ds = toy_dataset(64);
        let masks = Masks {
            rank_mask: vec![1.0; 4],
            layer_mask: vec![1.0; 2],
        };
        let o = t
            .train_local(0, &toy_map(), &masks, &ds, 1e-3, 3)
            .unwrap();
        assert_eq!(o.n_steps, 3);
    }

    #[test]
    fn more_active_slots_progress_faster() {
        let ds = toy_dataset(16);
        let wide = Masks { rank_mask: vec![1.0; 8], layer_mask: vec![1.0; 2] };
        let narrow = Masks { rank_mask: vec![1.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0], layer_mask: vec![1.0; 2] };
        let mut a = MockTrainer::new("lora");
        let mut b = MockTrainer::new("lora");
        a.train_local(0, &toy_map(), &wide, &ds, 1e-3, 100).unwrap();
        b.train_local(0, &toy_map(), &narrow, &ds, 1e-3, 100).unwrap();
        assert!(a.accuracy() > b.accuracy());
    }
}
