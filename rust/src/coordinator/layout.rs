//! The single slot-layout classifier shared by the wire codec
//! ([`super::serialize`]) and the eq. 17 aggregators
//! ([`super::aggregation`]).
//!
//! A trainable tensor's elements map to (layer, rank-slot) cells in
//! exactly one of three ways — [`Pattern`] — and both the bytes that
//! travel and the slots that fold are decided by that classification.
//! Before this module existed, `serialize` kept its own shape-only
//! copy of the rule: a square `[L, r, r]` tensor matched the rows arm
//! first, so B-side squares travelled row-major while the aggregator
//! (fixed in PR 2) folded them rank-last — the transmitted slots were
//! not the folded slots. Keeping one classifier makes that class of
//! drift impossible: encode, decode, byte tally, and fold all call
//! [`classify`].

use crate::model::TensorSpec;

/// How a tensor's elements map to (layer, rank-slot) cells.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Pattern {
    /// `[L, r, inner]` — slot index varies along axis 1.
    Rows { r: usize, inner: usize },
    /// `[L, inner, r]` — slot index varies along axis 2.
    Cols { r: usize, inner: usize },
    /// No (layer, slot) structure: travels whole, averaged over ALL
    /// devices (head).
    Full,
}

/// True when the manifest naming convention places the rank/width axis
/// *last*: the LoRA B-halves (`bq`, `bv`, …) and the adapter `down`
/// projection are `[L, inner, r]`; the A-halves (`aq`, `av`), adapter
/// `up` `[L, w, inner]` and the 2-D `bdown` bias `[L, w]` carry it
/// first (python/compile/model.py `lora_shapes`/`adapter_shapes`).
pub fn rank_axis_is_last(name: &str) -> bool {
    name == "down" || (name.starts_with('b') && name != "bdown")
}

/// Classify `spec` against the run's `(n_layers, rank_dim)`.
pub fn classify(spec: &TensorSpec, n_layers: usize, rank_dim: usize)
                -> Pattern {
    match spec.shape.as_slice() {
        // Square [L, r, r]: shape alone cannot tell which axis holds
        // the rank slots (Rows used to win unconditionally, silently
        // mis-masking B-side tensors whenever inner == rank_dim).
        // Disambiguate deterministically from the tensor spec's name.
        [l, a, b] if *l == n_layers && *a == rank_dim && *b == rank_dim => {
            if rank_axis_is_last(&spec.name) {
                Pattern::Cols { r: rank_dim, inner: *a }
            } else {
                Pattern::Rows { r: rank_dim, inner: *b }
            }
        }
        [l, a, b] if *l == n_layers && *a == rank_dim => {
            Pattern::Rows { r: rank_dim, inner: *b }
        }
        [l, a, b] if *l == n_layers && *b == rank_dim => {
            Pattern::Cols { r: rank_dim, inner: *a }
        }
        [l, a] if *l == n_layers && *a == rank_dim => {
            Pattern::Rows { r: rank_dim, inner: 1 }
        }
        _ => Pattern::Full,
    }
}

/// Number of elements of `spec` that are active (travel / fold) under
/// a device whose `[L * rank_dim]` slot mask is `mask`.
pub fn active_elems(spec: &TensorSpec, mask: &[f32], n_layers: usize,
                    rank_dim: usize) -> usize {
    match classify(spec, n_layers, rank_dim) {
        Pattern::Full => spec.numel(),
        Pattern::Rows { inner, .. } | Pattern::Cols { inner, .. } => {
            let active: usize =
                mask.iter().map(|&m| (m != 0.0) as usize).sum();
            active * inner
        }
    }
}

/// Visit the active elements of a tensor classified as `pat` in the
/// canonical wire/fold order: ascending layer, then ascending rank
/// slot within the layer, then ascending inner index within the slot.
/// `Full` visits every element in storage order. This single iterator
/// is what keeps encode, decode, and the fold walking the *same*
/// elements in the *same* order.
pub fn for_each_active(pat: Pattern, n_layers: usize, mask: &[f32],
                       mut visit: impl FnMut(usize)) {
    match pat {
        Pattern::Full => unreachable!("Full tensors have no mask walk"),
        Pattern::Rows { r, inner } => {
            for l in 0..n_layers {
                for j in 0..r {
                    if mask[l * r + j] == 0.0 {
                        continue;
                    }
                    let off = (l * r + j) * inner;
                    for e in off..off + inner {
                        visit(e);
                    }
                }
            }
        }
        Pattern::Cols { r, inner } => {
            for l in 0..n_layers {
                for j in 0..r {
                    if mask[l * r + j] == 0.0 {
                        continue;
                    }
                    let base = l * inner * r + j;
                    for i in 0..inner {
                        visit(base + i * r);
                    }
                }
            }
        }
    }
}

/// Zero-pad a rank-sloted tensor trained at a smaller rank dimension
/// up to the full `pat` layout (`r` slots per layer). This is THE
/// padding rule for heterogeneous-rank folding: serialize, both
/// engines, and the edge tier all route mismatched-rank tensors
/// through here, so a value trained in slot `(l, j)` always lands at
/// the same element the mask-gated eq. 17 fold reads for `(l, j)`.
///
/// `x` must hold `n_layers · r_src · inner` elements for some
/// `1 ≤ r_src ≤ r` (the source laid out exactly like `pat` but with
/// `r_src` slots per layer); slots `j ≥ r_src` are zero-filled.
/// Returns `None` when no such `r_src` exists (shape drift — the
/// caller decides whether that is an error). `Full` tensors carry no
/// slot structure and pass through only at their exact size.
pub fn pad_to_rank(pat: Pattern, n_layers: usize, x: Vec<f32>)
                   -> Option<Vec<f32>> {
    let (r, inner) = match pat {
        Pattern::Full => {
            return Some(x);
        }
        Pattern::Rows { r, inner } | Pattern::Cols { r, inner } => {
            (r, inner)
        }
    };
    let full = n_layers * r * inner;
    if x.len() == full {
        return Some(x);
    }
    let per_layer = n_layers * inner;
    if per_layer == 0 || x.len() % per_layer != 0 {
        return None;
    }
    let r_src = x.len() / per_layer;
    if r_src == 0 || r_src > r {
        return None;
    }
    let mut out = vec![0.0f32; full];
    match pat {
        Pattern::Full => unreachable!("handled above"),
        Pattern::Rows { .. } => {
            // [L, r_src, inner] → [L, r, inner]: slots contiguous.
            for l in 0..n_layers {
                for j in 0..r_src {
                    let src = (l * r_src + j) * inner;
                    let dst = (l * r + j) * inner;
                    out[dst..dst + inner]
                        .copy_from_slice(&x[src..src + inner]);
                }
            }
        }
        Pattern::Cols { .. } => {
            // [L, inner, r_src] → [L, inner, r]: slots strided.
            for l in 0..n_layers {
                for i in 0..inner {
                    let src = l * inner * r_src + i * r_src;
                    let dst = l * inner * r + i * r;
                    out[dst..dst + r_src]
                        .copy_from_slice(&x[src..src + r_src]);
                }
            }
        }
    }
    Some(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    const L: usize = 2;
    const R: usize = 3;
    const D: usize = 5;

    fn sq(name: &str) -> TensorSpec {
        TensorSpec { name: name.into(), shape: vec![L, R, R] }
    }

    #[test]
    fn classify_square_tensor_disambiguates_by_name() {
        assert_eq!(classify(&sq("aq"), L, R),
                   Pattern::Rows { r: R, inner: R });
        assert_eq!(classify(&sq("av"), L, R),
                   Pattern::Rows { r: R, inner: R });
        assert_eq!(classify(&sq("up"), L, R),
                   Pattern::Rows { r: R, inner: R });
        assert_eq!(classify(&sq("bq"), L, R),
                   Pattern::Cols { r: R, inner: R });
        assert_eq!(classify(&sq("bv"), L, R),
                   Pattern::Cols { r: R, inner: R });
        assert_eq!(classify(&sq("down"), L, R),
                   Pattern::Cols { r: R, inner: R });
        let wide = TensorSpec { name: "bq".into(),
                                shape: vec![L, D, R] };
        assert_eq!(classify(&wide, L, R),
                   Pattern::Cols { r: R, inner: D });
        let bias = TensorSpec { name: "bdown".into(),
                                shape: vec![L, R] };
        assert_eq!(classify(&bias, L, R),
                   Pattern::Rows { r: R, inner: 1 });
        let head = TensorSpec { name: "head_w".into(),
                                shape: vec![D, 4] };
        assert_eq!(classify(&head, L, R), Pattern::Full);
    }

    #[test]
    fn active_walk_matches_active_elems_and_never_repeats() {
        // One slot active per layer: slot 1 of layer 0, slot 2 of
        // layer 1.
        let mut mask = vec![0.0f32; L * R];
        mask[1] = 1.0;
        mask[R + 2] = 1.0;
        for spec in [sq("aq"), sq("bq")] {
            let pat = classify(&spec, L, R);
            let mut seen = Vec::new();
            for_each_active(pat, L, &mask, |e| seen.push(e));
            assert_eq!(seen.len(),
                       active_elems(&spec, &mask, L, R));
            let mut uniq = seen.clone();
            uniq.sort_unstable();
            uniq.dedup();
            assert_eq!(uniq.len(), seen.len(), "duplicate element");
            assert!(seen.iter().all(|&e| e < spec.numel()));
        }
        // Rows walks slot-contiguous storage; Cols strides by r.
        let mut rows = Vec::new();
        for_each_active(Pattern::Rows { r: R, inner: R }, L, &mask,
                        |e| rows.push(e));
        assert_eq!(rows[..R], [R, R + 1, R + 2]);
        let mut cols = Vec::new();
        for_each_active(Pattern::Cols { r: R, inner: R }, L, &mask,
                        |e| cols.push(e));
        assert_eq!(cols[..R], [1, 1 + R, 1 + 2 * R]);
    }

    #[test]
    fn pad_to_rank_places_slots_where_the_fold_reads_them() {
        // r_src = 2 of R = 3 slots, inner = D. Fill the source with
        // distinct values, pad, and check that every active (l, j)
        // element lands exactly where for_each_active visits it.
        let rows = Pattern::Rows { r: R, inner: D };
        let src: Vec<f32> = (0..L * 2 * D).map(|e| e as f32 + 1.0).collect();
        let padded = pad_to_rank(rows, L, src.clone()).unwrap();
        assert_eq!(padded.len(), L * R * D);
        for l in 0..L {
            for j in 0..2 {
                for i in 0..D {
                    assert_eq!(padded[(l * R + j) * D + i],
                               src[(l * 2 + j) * D + i]);
                }
            }
            // The padded slot is zero.
            for i in 0..D {
                assert_eq!(padded[(l * R + 2) * D + i], 0.0);
            }
        }

        let cols = Pattern::Cols { r: R, inner: D };
        let src: Vec<f32> = (0..L * D * 2).map(|e| e as f32 + 1.0).collect();
        let padded = pad_to_rank(cols, L, src.clone()).unwrap();
        assert_eq!(padded.len(), L * D * R);
        for l in 0..L {
            for i in 0..D {
                for j in 0..2 {
                    assert_eq!(padded[l * D * R + i * R + j],
                               src[l * D * 2 + i * 2 + j]);
                }
                assert_eq!(padded[l * D * R + i * R + 2], 0.0);
            }
        }
    }

    #[test]
    fn pad_to_rank_full_size_is_identity_and_drift_is_none() {
        let rows = Pattern::Rows { r: R, inner: D };
        let full: Vec<f32> = (0..L * R * D).map(|e| e as f32).collect();
        assert_eq!(pad_to_rank(rows, L, full.clone()), Some(full));
        // Not a multiple of L·inner → shape drift, not padding.
        assert_eq!(pad_to_rank(rows, L, vec![0.0; L * D + 1]), None);
        // r_src would exceed r → drift.
        assert_eq!(pad_to_rank(rows, L, vec![0.0; L * (R + 1) * D]),
                   None);
        // Empty source → drift (r_src = 0 has no slots to place).
        assert_eq!(pad_to_rank(rows, L, vec![]), None);
        // Full tensors pass through untouched.
        let head = vec![1.0f32; 7];
        assert_eq!(pad_to_rank(Pattern::Full, L, head.clone()),
                   Some(head));
    }
}
