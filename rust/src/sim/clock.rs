//! Completion-time model (eq. 12) and the virtual clock.

/// Per-device inputs to eq. (12) for one round.
#[derive(Debug, Clone)]
pub struct DeviceRound {
    pub device_id: usize,
    /// t̂: forward time for one batch through the full model [s].
    pub fwd_time_per_batch: f64,
    /// μ: backprop time per LoRA layer per batch [s].
    pub mu: f64,
    /// β: upload time per unit-rank LoRA layer [s].
    pub beta: f64,
    /// LoRA depth k (layers with backprop).
    pub depth: usize,
    /// Active ranks {r_l} of the transmitted layers.
    pub ranks: Vec<usize>,
    /// Local batches this round (epoch length on this device).
    pub n_batches: usize,
    /// Extra upload bytes not proportional to rank (e.g. the
    /// classification head), converted to seconds by the caller's β
    /// per byte — passed here directly in seconds.
    pub extra_upload_s: f64,
}

impl DeviceRound {
    /// eq. (12): t_i = n·(t̂ + k·μ) + Σ_l r_l·β  (+ constant head).
    pub fn completion_time(&self) -> f64 {
        let compute = self.n_batches as f64
            * (self.fwd_time_per_batch + self.depth as f64 * self.mu);
        let rank_sum: usize = self.ranks.iter().sum();
        compute + rank_sum as f64 * self.beta + self.extra_upload_s
    }
}

/// Result of simulating one round over all participants.
#[derive(Debug, Clone)]
pub struct RoundTiming {
    /// t^h = max_i t_i^h (0 for an empty round).
    pub round_time: f64,
    /// W^h = (1/n) Σ (t^h − t_i^h)  (eq. 13; 0 for an empty round).
    pub avg_waiting: f64,
    /// Slowest device id (the straggler); `usize::MAX` when the round
    /// had no participants.
    pub straggler: usize,
    pub per_device: Vec<(usize, f64)>,
}

/// Compute eq. (12)/(13) over the round's participants. A zero-device
/// round (possible in the async engine when a commit window closes
/// before any update lands) yields a zero-time, zero-waiting record
/// rather than panicking.
pub fn simulate_round(devices: &[DeviceRound]) -> RoundTiming {
    timing_from_pairs(
        devices
            .iter()
            .map(|d| (d.device_id, d.completion_time()))
            .collect(),
    )
}

/// Eq. (12)/(13) over precomputed `(device_id, completion_time)`
/// pairs. The async engine feeds this directly — stale folds carry a
/// completion time relative to the *current* commit window, which no
/// [`DeviceRound`] can express — and `simulate_round` delegates here so
/// the two engines share one timing arithmetic (same pair order ⇒
/// bit-identical result).
pub fn timing_from_pairs(per_device: Vec<(usize, f64)>) -> RoundTiming {
    if per_device.is_empty() {
        return RoundTiming {
            round_time: 0.0,
            avg_waiting: 0.0,
            straggler: usize::MAX,
            per_device,
        };
    }
    let (straggler, round_time) = per_device
        .iter()
        .cloned()
        .max_by(|a, b| a.1.total_cmp(&b.1))
        .unwrap();
    let n = per_device.len() as f64;
    let avg_waiting =
        per_device.iter().map(|(_, t)| round_time - t).sum::<f64>() / n;
    RoundTiming { round_time, avg_waiting, straggler, per_device }
}

/// Median of per-device completion times — the deadline basis for
/// semi-synchronous participation policies
/// (`coordinator/participation.rs`): a round's deadline is
/// `factor × median_completion(predicted)` over the cohort's eq. 12
/// predictions. Thin wrapper over [`crate::util::stats::percentile`]
/// so the crate keeps a single quantile implementation. An empty slice
/// yields 0 (no cohort ⇒ no deadline) instead of panicking — defensive
/// hardening: both engines run admission only on non-empty cohorts
/// today, but a policy calling this on an empty prediction set should
/// degrade gracefully, not abort the run.
pub fn median_completion(times: &[f64]) -> f64 {
    if times.is_empty() {
        return 0.0;
    }
    crate::util::stats::percentile(times, 50.0)
}

/// Accumulates virtual time across rounds.
#[derive(Debug, Clone, Default)]
pub struct VirtualClock {
    pub elapsed: f64,
    pub rounds: usize,
    waiting_sum: f64,
}

impl VirtualClock {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn advance(&mut self, timing: &RoundTiming) {
        // detlint-allow: float-accum one advance per round on the coordinator thread
        self.elapsed += timing.round_time;
        // detlint-allow: float-accum one advance per round on the coordinator thread
        self.waiting_sum += timing.avg_waiting;
        self.rounds += 1;
    }

    /// Mean of eq. (13) over all completed rounds (Fig. 12's metric).
    pub fn mean_waiting(&self) -> f64 {
        if self.rounds == 0 {
            0.0
        } else {
            self.waiting_sum / self.rounds as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dr(id: usize, mu: f64, depth: usize, ranks: Vec<usize>)
          -> DeviceRound {
        DeviceRound {
            device_id: id,
            fwd_time_per_batch: 0.01,
            mu,
            beta: 0.1,
            depth,
            ranks,
            n_batches: 10,
            extra_upload_s: 0.0,
        }
    }

    #[test]
    fn completion_time_matches_eq12() {
        let d = dr(0, 0.005, 4, vec![9, 10, 11, 12]);
        // 10 * (0.01 + 4*0.005) + 42 * 0.1 = 0.3 + 4.2
        assert!((d.completion_time() - 4.5).abs() < 1e-12);
    }

    #[test]
    fn waiting_time_matches_eq13() {
        let devices = vec![
            dr(0, 0.005, 2, vec![1, 2]), // 10*(0.01+0.01)+0.3 = 0.5
            dr(1, 0.010, 2, vec![1, 2]), // 10*(0.01+0.02)+0.3 = 0.6
        ];
        let t = simulate_round(&devices);
        assert!((t.round_time - 0.6).abs() < 1e-12);
        assert_eq!(t.straggler, 1);
        assert!((t.avg_waiting - 0.05).abs() < 1e-12);
    }

    #[test]
    fn waiting_nonnegative_and_zero_for_identical() {
        let devices = vec![dr(0, 0.005, 3, vec![4, 5, 6]); 5];
        let t = simulate_round(&devices);
        assert!(t.avg_waiting.abs() < 1e-12);
    }

    #[test]
    fn clock_accumulates() {
        let mut c = VirtualClock::new();
        let devices = vec![
            dr(0, 0.005, 2, vec![1, 1]),
            dr(1, 0.02, 2, vec![1, 1]),
        ];
        let t = simulate_round(&devices);
        c.advance(&t);
        c.advance(&t);
        assert_eq!(c.rounds, 2);
        assert!((c.elapsed - 2.0 * t.round_time).abs() < 1e-12);
        assert!((c.mean_waiting() - t.avg_waiting).abs() < 1e-12);
    }

    #[test]
    fn median_completion_is_the_middle_time() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(median_completion(&xs), 3.0);
        assert_eq!(median_completion(&[7.0]), 7.0);
        assert_eq!(median_completion(&[1.0, 2.0]), 1.5);
    }

    #[test]
    fn median_completion_edge_slices() {
        // Empty: no cohort ⇒ no deadline (0), not a panic.
        assert_eq!(median_completion(&[]), 0.0);
        // Single element is its own median.
        assert_eq!(median_completion(&[7.0]), 7.0);
        // Even length interpolates the two middle elements.
        assert_eq!(median_completion(&[4.0, 1.0]), 2.5);
        assert_eq!(median_completion(&[1.0, 2.0, 3.0, 10.0]), 2.5);
    }

    #[test]
    fn simulate_round_zero_devices_is_zero_time() {
        let t = simulate_round(&[]);
        assert_eq!(t.round_time, 0.0);
        assert_eq!(t.avg_waiting, 0.0);
        assert_eq!(t.straggler, usize::MAX);
        assert!(t.per_device.is_empty());
        // Advancing the clock over an empty round is a no-op in time
        // but still counts the round (mean_waiting denominators).
        let mut c = VirtualClock::new();
        c.advance(&t);
        assert_eq!(c.elapsed, 0.0);
        assert_eq!(c.rounds, 1);
    }

    #[test]
    fn mean_waiting_before_any_advance_is_zero() {
        let c = VirtualClock::new();
        assert_eq!(c.rounds, 0);
        assert_eq!(c.mean_waiting(), 0.0);
    }

    #[test]
    fn timing_from_pairs_matches_simulate_round() {
        let devices = vec![
            dr(0, 0.005, 2, vec![1, 2]),
            dr(3, 0.010, 2, vec![1, 2]),
        ];
        let a = simulate_round(&devices);
        let b = timing_from_pairs(
            devices
                .iter()
                .map(|d| (d.device_id, d.completion_time()))
                .collect(),
        );
        assert_eq!(a.round_time.to_bits(), b.round_time.to_bits());
        assert_eq!(a.avg_waiting.to_bits(), b.avg_waiting.to_bits());
        assert_eq!(a.straggler, b.straggler);
    }

    #[test]
    fn deeper_config_takes_longer() {
        let shallow = dr(0, 0.005, 2, vec![1, 2]).completion_time();
        let deep = dr(0, 0.005, 8, (1..=8).collect()).completion_time();
        assert!(deep > shallow);
    }
}
