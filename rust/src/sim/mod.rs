//! Virtual time: round completion and waiting-time accounting.
//!
//! The paper's headline metrics are wall-clock completion time to a
//! target accuracy (Fig. 7–10), average per-round waiting time
//! (eq. 13, Fig. 12) and communication traffic (Fig. 11). Gradient
//! math runs for real through PJRT, but *time* is virtual — computed
//! from eq. (12) with the calibrated device models — exactly the
//! quantity the paper's problem (16) optimizes (DESIGN.md §2).

pub mod clock;

pub use clock::{RoundTiming, VirtualClock};
