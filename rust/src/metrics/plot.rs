//! ASCII line plots for terminal output — accuracy-vs-time curves
//! (Figs. 7/9/10/13) render directly in `legend exp` summaries and the
//! examples, so the paper's figure *shapes* are visible without a
//! plotting stack.

/// One named series of (x, y) points.
#[derive(Debug, Clone)]
pub struct Series {
    pub name: String,
    pub points: Vec<(f64, f64)>,
}

/// Render series into a `width`×`height` character canvas with axes.
pub fn line_plot(series: &[Series], width: usize, height: usize,
                 x_label: &str, y_label: &str) -> String {
    assert!(width >= 16 && height >= 4);
    let pts: Vec<(f64, f64)> = series
        .iter()
        .flat_map(|s| s.points.iter().cloned())
        .filter(|(x, y)| x.is_finite() && y.is_finite())
        .collect();
    if pts.is_empty() {
        return "(no data)\n".to_string();
    }
    let (mut x0, mut x1, mut y0, mut y1) =
        (f64::MAX, f64::MIN, f64::MAX, f64::MIN);
    for &(x, y) in &pts {
        x0 = x0.min(x);
        x1 = x1.max(x);
        y0 = y0.min(y);
        y1 = y1.max(y);
    }
    if x1 <= x0 {
        x1 = x0 + 1.0;
    }
    if y1 <= y0 {
        y1 = y0 + 1.0;
    }

    let mut canvas = vec![vec![' '; width]; height];
    let glyphs = ['*', 'o', '+', 'x', '#', '@'];
    for (si, s) in series.iter().enumerate() {
        let g = glyphs[si % glyphs.len()];
        for &(x, y) in &s.points {
            if !x.is_finite() || !y.is_finite() {
                continue;
            }
            let cx = ((x - x0) / (x1 - x0) * (width - 1) as f64).round()
                as usize;
            let cy = ((y - y0) / (y1 - y0) * (height - 1) as f64).round()
                as usize;
            canvas[height - 1 - cy][cx.min(width - 1)] = g;
        }
    }

    let mut out = String::new();
    out.push_str(&format!("{y_label} ({y0:.2}..{y1:.2})\n"));
    for row in &canvas {
        out.push('|');
        out.extend(row.iter());
        out.push('\n');
    }
    out.push('+');
    out.extend(std::iter::repeat('-').take(width));
    out.push('\n');
    out.push_str(&format!("  {x_label} ({x0:.0}..{x1:.0})   "));
    for (si, s) in series.iter().enumerate() {
        out.push_str(&format!("{}={}  ", glyphs[si % glyphs.len()],
                              s.name));
    }
    out.push('\n');
    out
}

/// Convenience: accuracy-vs-sim-time curves from run records.
pub fn accuracy_plot(runs: &[super::RunRecord], width: usize,
                     height: usize) -> String {
    let series: Vec<Series> = runs
        .iter()
        .map(|r| Series {
            name: r.method.clone(),
            points: r
                .rounds
                .iter()
                .map(|x| (x.sim_time, x.test_acc))
                .collect(),
        })
        .collect();
    line_plot(&series, width, height, "virtual seconds", "test acc")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plots_contain_glyphs_and_legend() {
        let s = vec![
            Series {
                name: "a".into(),
                points: vec![(0.0, 0.0), (10.0, 1.0)],
            },
            Series {
                name: "b".into(),
                points: vec![(0.0, 1.0), (10.0, 0.0)],
            },
        ];
        let out = line_plot(&s, 40, 10, "t", "acc");
        assert!(out.contains('*') && out.contains('o'));
        assert!(out.contains("*=a") && out.contains("o=b"));
        assert_eq!(out.lines().count(), 13);
    }

    #[test]
    fn empty_series_is_graceful() {
        let out = line_plot(
            &[Series { name: "e".into(), points: vec![] }],
            20,
            5,
            "x",
            "y",
        );
        assert!(out.contains("no data"));
    }

    #[test]
    fn constant_series_does_not_divide_by_zero() {
        let s = vec![Series {
            name: "c".into(),
            points: vec![(1.0, 0.5), (1.0, 0.5)],
        }];
        let out = line_plot(&s, 20, 5, "x", "y");
        assert!(out.contains('*'));
    }
}
