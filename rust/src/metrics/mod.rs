//! Experiment metrics: per-round records, run summaries, CSV/JSON out.
//!
//! Every experiment harness (`legend exp --fig …`) produces a
//! [`RunRecord`] per (method, task) pair; the summary helpers compute
//! the paper's reported quantities — completion time to target
//! accuracy (Fig. 8), traffic to target accuracy (Fig. 11), mean
//! waiting time (Fig. 12) — directly from the records.

pub mod plot;

use std::fmt::Write as _;
use std::io::Write as _;

use crate::util::json::Value;

/// One federated round's observables.
#[derive(Debug, Clone, Default)]
pub struct RoundRecord {
    pub round: usize,
    /// Virtual seconds elapsed *after* this round.
    pub sim_time: f64,
    pub round_time: f64,
    pub avg_waiting: f64,
    pub up_bytes: usize,
    pub down_bytes: usize,
    pub train_loss: f64,
    pub test_acc: f64,
    pub test_loss: f64,
    /// Mean LoRA depth assigned this round (diagnostic). Computed
    /// from the configs of the updates that actually folded this
    /// round — the round's *active* plan — never from a run-start
    /// snapshot (`coordinator/engine.rs::mean_depth_of`).
    pub mean_depth: f64,
    /// LCD plan epoch this round was planned under: bumped each time
    /// a `--realloc-every` refit adopts new capacity estimates; 0
    /// forever when re-allocation is off. An async fold may carry an
    /// *older* epoch on its messages than the round records here.
    pub plan_epoch: usize,
    /// Devices that trained and reported this round (cohort minus
    /// deadline drops; equals the fleet size under full
    /// participation).
    pub participants: usize,
    /// Cohort devices dropped by the participation policy's deadline.
    pub dropped: usize,
}

/// A full (method, task) run.
#[derive(Debug, Clone, Default)]
pub struct RunRecord {
    pub method: String,
    pub task: String,
    pub rounds: Vec<RoundRecord>,
    /// Plan epochs adopted over the run (final Reallocator epoch):
    /// how many `--realloc-every` refits actually changed the plan
    /// inputs. 0 when re-allocation is off or every refit landed
    /// inside the hysteresis band.
    pub rank_realloc_epochs: usize,
}

impl RunRecord {
    pub fn new(method: &str, task: &str) -> Self {
        RunRecord {
            method: method.to_string(),
            task: task.to_string(),
            rounds: Vec::new(),
            rank_realloc_epochs: 0,
        }
    }

    pub fn final_accuracy(&self) -> f64 {
        self.rounds.last().map(|r| r.test_acc).unwrap_or(0.0)
    }

    pub fn best_accuracy(&self) -> f64 {
        self.rounds.iter().map(|r| r.test_acc).fold(0.0, f64::max)
    }

    /// Completion time to first reach `target` accuracy (Fig. 8's
    /// metric); `None` if never reached.
    pub fn time_to_accuracy(&self, target: f64) -> Option<f64> {
        self.rounds
            .iter()
            .find(|r| r.test_acc >= target)
            .map(|r| r.sim_time)
    }

    /// Cumulative up+down traffic when first reaching `target`
    /// (Fig. 11's metric).
    pub fn traffic_to_accuracy(&self, target: f64) -> Option<usize> {
        let mut total = 0usize;
        for r in &self.rounds {
            total += r.up_bytes + r.down_bytes;
            if r.test_acc >= target {
                return Some(total);
            }
        }
        None
    }

    pub fn total_traffic(&self) -> usize {
        self.rounds.iter().map(|r| r.up_bytes + r.down_bytes).sum()
    }

    /// Mean of eq. (13) over rounds (Fig. 12's metric).
    pub fn mean_waiting(&self) -> f64 {
        if self.rounds.is_empty() {
            return 0.0;
        }
        self.rounds.iter().map(|r| r.avg_waiting).sum::<f64>()
            / self.rounds.len() as f64
    }

    pub fn total_time(&self) -> f64 {
        self.rounds.last().map(|r| r.sim_time).unwrap_or(0.0)
    }

    /// Mean devices trained per round (participation diagnostic —
    /// equals the fleet size under full participation).
    pub fn mean_participation(&self) -> f64 {
        if self.rounds.is_empty() {
            return 0.0;
        }
        self.rounds.iter().map(|r| r.participants as f64).sum::<f64>()
            / self.rounds.len() as f64
    }

    /// Total cohort devices dropped by deadlines over the run.
    pub fn total_dropped(&self) -> usize {
        self.rounds.iter().map(|r| r.dropped).sum()
    }

    // ---- serialization ----------------------------------------------------

    pub const CSV_HEADER: &'static str = "method,task,round,sim_time,\
round_time,avg_waiting,up_bytes,down_bytes,train_loss,test_acc,\
test_loss,mean_depth,participants,dropped,plan_epoch";

    pub fn to_csv_rows(&self) -> String {
        let mut out = String::new();
        for r in &self.rounds {
            let _ = writeln!(
                out,
                "{},{},{},{:.3},{:.3},{:.3},{},{},{:.5},{:.5},{:.5},\
                 {:.2},{},{},{}",
                self.method,
                self.task,
                r.round,
                r.sim_time,
                r.round_time,
                r.avg_waiting,
                r.up_bytes,
                r.down_bytes,
                r.train_loss,
                r.test_acc,
                r.test_loss,
                r.mean_depth,
                r.participants,
                r.dropped,
                r.plan_epoch
            );
        }
        out
    }

    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("method", Value::Str(self.method.clone())),
            ("task", Value::Str(self.task.clone())),
            (
                "rank_realloc_epochs",
                Value::Num(self.rank_realloc_epochs as f64),
            ),
            (
                "rounds",
                Value::Arr(
                    self.rounds
                        .iter()
                        .map(|r| {
                            Value::obj(vec![
                                ("round", Value::Num(r.round as f64)),
                                ("sim_time", Value::Num(r.sim_time)),
                                ("test_acc", Value::Num(r.test_acc)),
                                ("train_loss", Value::Num(r.train_loss)),
                                (
                                    "up_bytes",
                                    Value::Num(r.up_bytes as f64),
                                ),
                                (
                                    "down_bytes",
                                    Value::Num(r.down_bytes as f64),
                                ),
                                (
                                    "avg_waiting",
                                    Value::Num(r.avg_waiting),
                                ),
                                (
                                    "participants",
                                    Value::Num(r.participants as f64),
                                ),
                                (
                                    "dropped",
                                    Value::Num(r.dropped as f64),
                                ),
                                (
                                    "plan_epoch",
                                    Value::Num(r.plan_epoch as f64),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// One canonical JSON document over a multi-job run's per-job
/// records, keyed `"job<id>"` in ascending job-id order — the
/// artifact the multi-job determinism oracle double-runs and diffs
/// across processes (`results/DETERMINISM_multijob.json`).
pub fn multi_job_json(
    records: &std::collections::BTreeMap<usize, RunRecord>,
) -> Value {
    Value::Obj(
        records
            .iter()
            .map(|(id, r)| (format!("job{id}"), r.to_json()))
            .collect(),
    )
}

/// Write a set of runs to `results/<name>.csv` (plus echo a summary).
pub fn write_csv(name: &str, runs: &[RunRecord])
                 -> std::io::Result<String> {
    std::fs::create_dir_all("results")?;
    let path = format!("results/{name}.csv");
    let mut f = std::fs::File::create(&path)?;
    writeln!(f, "{}", RunRecord::CSV_HEADER)?;
    for run in runs {
        write!(f, "{}", run.to_csv_rows())?;
    }
    Ok(path)
}

/// Pretty summary table of runs against a target accuracy — the rows
/// the paper reports in Figs. 8/11/12.
pub fn summary_table(runs: &[RunRecord], target: f64) -> String {
    let mut out = String::new();
    let _ = writeln!(
        out,
        "{:<16} {:<6} {:>9} {:>12} {:>12} {:>11} {:>10} {:>8}",
        "method", "task", "final_acc", "t_to_target", "traffic_MB",
        "wait_avg_s", "rounds", "part"
    );
    for r in runs {
        let t = r
            .time_to_accuracy(target)
            .map(|t| format!("{t:.0}s"))
            .unwrap_or_else(|| "—".to_string());
        let traffic = r
            .traffic_to_accuracy(target)
            .map(|b| format!("{:.1}", b as f64 / 1e6))
            .unwrap_or_else(|| {
                format!("({:.1})", r.total_traffic() as f64 / 1e6)
            });
        let _ = writeln!(
            out,
            "{:<16} {:<6} {:>9.4} {:>12} {:>12} {:>11.1} {:>10} {:>8.1}",
            r.method,
            r.task,
            r.final_accuracy(),
            t,
            traffic,
            r.mean_waiting(),
            r.rounds.len(),
            r.mean_participation()
        );
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run_with_accs(accs: &[f64]) -> RunRecord {
        let mut r = RunRecord::new("m", "t");
        let mut t = 0.0;
        for (i, &a) in accs.iter().enumerate() {
            t += 10.0;
            r.rounds.push(RoundRecord {
                round: i,
                sim_time: t,
                round_time: 10.0,
                avg_waiting: 2.0,
                up_bytes: 100,
                down_bytes: 50,
                test_acc: a,
                participants: 8,
                dropped: 2,
                ..Default::default()
            });
        }
        r
    }

    #[test]
    fn participation_summaries() {
        let r = run_with_accs(&[0.1, 0.2, 0.3]);
        assert!((r.mean_participation() - 8.0).abs() < 1e-12);
        assert_eq!(r.total_dropped(), 6);
        assert_eq!(RunRecord::default().mean_participation(), 0.0);
    }

    #[test]
    fn time_to_accuracy_first_crossing() {
        let r = run_with_accs(&[0.5, 0.7, 0.9, 0.85]);
        assert_eq!(r.time_to_accuracy(0.7), Some(20.0));
        assert_eq!(r.time_to_accuracy(0.95), None);
    }

    #[test]
    fn traffic_accumulates_until_crossing() {
        let r = run_with_accs(&[0.5, 0.7, 0.9]);
        assert_eq!(r.traffic_to_accuracy(0.9), Some(450));
        assert_eq!(r.total_traffic(), 450);
    }

    #[test]
    fn waiting_mean() {
        let r = run_with_accs(&[0.1, 0.2]);
        assert!((r.mean_waiting() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn csv_shape() {
        let r = run_with_accs(&[0.5]);
        let rows = r.to_csv_rows();
        assert_eq!(rows.lines().count(), 1);
        assert_eq!(
            rows.lines().next().unwrap().split(',').count(),
            RunRecord::CSV_HEADER.split(',').count()
        );
    }

    #[test]
    fn multi_job_json_keys_by_job_id_in_order() {
        let mut records = std::collections::BTreeMap::new();
        records.insert(1usize, run_with_accs(&[0.6]));
        records.insert(0usize, run_with_accs(&[0.5, 0.7]));
        let v = multi_job_json(&records);
        let parsed =
            crate::util::json::Value::parse(&v.to_string()).unwrap();
        assert_eq!(parsed.get("job0").get("rounds").as_arr().unwrap().len(),
                   2);
        assert_eq!(parsed.get("job1").get("rounds").as_arr().unwrap().len(),
                   1);
        // BTreeMap keying ⇒ the serialized document lists job0 before
        // job1, so a byte diff across processes is meaningful.
        let text = v.to_string();
        assert!(text.find("job0").unwrap() < text.find("job1").unwrap());
    }

    #[test]
    fn json_roundtrips() {
        let mut r = run_with_accs(&[0.5, 0.6]);
        r.rank_realloc_epochs = 3;
        r.rounds[1].plan_epoch = 2;
        let v = r.to_json();
        let parsed =
            crate::util::json::Value::parse(&v.to_string()).unwrap();
        assert_eq!(parsed.get("method").as_str(), Some("m"));
        let rounds = parsed.get("rounds").as_arr().unwrap();
        assert_eq!(rounds.len(), 2);
        // Both traffic directions survive the JSON path (the codec's
        // byte-honest tallies are checked against these leaves).
        assert_eq!(rounds[0].get("up_bytes").as_f64(), Some(100.0));
        assert_eq!(rounds[0].get("down_bytes").as_f64(), Some(50.0));
        // Plan epochs survive both levels of the JSON path.
        assert_eq!(parsed.get("rank_realloc_epochs").as_f64(),
                   Some(3.0));
        assert_eq!(rounds[0].get("plan_epoch").as_f64(), Some(0.0));
        assert_eq!(rounds[1].get("plan_epoch").as_f64(), Some(2.0));
    }
}
