//! `legend` — CLI for the LEGEND federated fine-tuning reproduction.
//!
//! Subcommands:
//!   run    one federated run:   legend run --method legend --task sst2
//!          participation: --participation full|sample|count|deadline
//!          (--sample-frac F, --sample-count K, --deadline-factor F),
//!          phase-④ worker threads: --threads N (0 = auto),
//!          aggregation fold shards: --agg-shards S (0 = auto,
//!          1 = inline), in-flight window: --window W (0 = unbounded;
//!          bounds per-round transient memory to O(model + W)),
//!          edge-aggregation tier: --edge-aggregators E (cohort folds
//!          across E concurrent edge folds, merged at the root),
//!          lazy fleet: --lazy derives devices on demand so a
//!          million-device fleet costs O(cohort) memory. Results are
//!          bit-identical at every threads × agg-shards × window ×
//!          edge-aggregators setting, lazy or eager.
//!          Async rounds: --async switches to the staleness-windowed
//!          engine (devices fold whenever they finish, weighted by
//!          1/(1+τ)^α); --staleness-alpha A (α ≥ 0) and
//!          --max-staleness S tune it. --async --max-staleness 0
//!          reproduces the synchronous engine bitwise.
//!          Update codec: --codec none|int8|int4 quantizes uplink
//!          updates (per-tensor affine delta vs the assigned global,
//!          dequantized once before the fold; see docs/TRANSPORT.md).
//!          --codec none reproduces today's wire bitwise.
//!          Periodic re-allocation: --realloc-every K re-fits the LCD
//!          plan from the live capacity EWMAs every K rounds (frozen
//!          between refits; --realloc-hysteresis H keeps a fit that
//!          moved less than H bitwise — see docs/ADAPTIVE.md).
//!          --realloc-every 0 reproduces the static-plan engine
//!          bitwise.
//!          Multi-job: --jobs N runs N tenants of one shared fleet
//!          through the capacity-aware scheduler (disjoint per-round
//!          cohorts, admission control; job j uses seed + j);
//!          --job-rate R caps each job's coordinator ingest at R
//!          admitted updates/round (token bucket, burst = refill = R;
//!          0 = unlimited). --jobs 1 reproduces the single-job engine
//!          bitwise. See docs/MULTIJOB.md.
//!   exp    regenerate a paper figure: legend exp --fig fig7 (or --all)
//!   fleet  describe the simulated 80-device testbed (Table 1)
//!   data   describe the synthetic datasets (Table 2)
//!   kernel run the Pallas LoRA kernel artifact once (L1 smoke)
//!
//! Requires `make artifacts` first (python runs once, never again).

use anyhow::{anyhow, Result};

use legend::coordinator::participation;
use legend::coordinator::FedConfig;
use legend::data::grammar;
use legend::device::{Fleet, FleetConfig};
use legend::exp::{figures, ExpEnv};
use legend::metrics::{self};
use legend::util::cli::Args;
use legend::util::rng::Rng;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn fed_config_from(args: &Args) -> Result<FedConfig> {
    let d = FedConfig::default();
    let cfg = FedConfig {
        task: args.get_or("task", &d.task),
        rounds: args.get_parse("rounds", d.rounds)?,
        eval_every: args.get_parse("eval-every", d.eval_every)?,
        lr0: args.get_parse("lr", d.lr0)?,
        seed: args.get_parse("seed", d.seed)?,
        train_size: args.get_parse("train-size", d.train_size)?,
        test_size: args.get_parse("test-size", d.test_size)?,
        alpha: args.get_parse("alpha", d.alpha)?,
        max_batches: args.get_parse("max-batches", d.max_batches)?,
        target_acc: args.get_parse("target-acc", d.target_acc)?,
        threads: args.get_parse("threads", d.threads)?,
        agg_shards: args.get_parse("agg-shards", d.agg_shards)?,
        window: args.get_parse("window", d.window)?,
        edge_aggregators: args
            .get_parse("edge-aggregators", d.edge_aggregators)?,
        lazy_fleet: args.flag("lazy"),
        async_mode: args.flag("async"),
        staleness_alpha: args
            .get_parse("staleness-alpha", d.staleness_alpha)?,
        max_staleness: args.get_parse("max-staleness", d.max_staleness)?,
        realloc_every: args.get_parse("realloc-every", d.realloc_every)?,
        realloc_hysteresis: args
            .get_parse("realloc-hysteresis", d.realloc_hysteresis)?,
        codec: legend::coordinator::Codec::by_name(&args.get_choice(
            "codec", d.codec.name(), &["none", "int8", "int4"])?)?,
        verbose: !args.flag("quiet"),
    };
    if !cfg.staleness_alpha.is_finite() || cfg.staleness_alpha < 0.0 {
        return Err(anyhow!(
            "--staleness-alpha must be a finite value ≥ 0, got {}",
            cfg.staleness_alpha
        ));
    }
    if !cfg.realloc_hysteresis.is_finite() || cfg.realloc_hysteresis < 0.0
    {
        return Err(anyhow!(
            "--realloc-hysteresis must be a finite value ≥ 0, got {}",
            cfg.realloc_hysteresis
        ));
    }
    Ok(cfg)
}

fn participation_from(args: &Args)
                      -> Result<Box<dyn participation::Participation>> {
    let name = args.get_choice("participation", "full",
                               &["full", "sample", "count", "deadline"])?;
    let frac = args.get_parse("sample-frac", 0.3f64)?;
    let count = args.get_parse("sample-count", 10usize)?;
    let factor = args.get_parse("deadline-factor", 1.5f64)?;
    participation::by_name(&name, frac, count, factor)
        .map_err(|e| anyhow!(e))
}

fn run() -> Result<()> {
    let args = Args::from_env();
    let artifacts = args.get_or("artifacts", "artifacts");
    match args.subcommand.as_deref() {
        Some("run") => {
            let cfg = fed_config_from(&args)?;
            let method = args.get_or("method", "legend");
            let devices = args.get_parse("devices", 10usize)?;
            let jobs = args.get_parse("jobs", 1usize)?;
            let job_rate = args.get_parse("job-rate", 0usize)?;
            if jobs == 0 {
                return Err(anyhow!("--jobs must be ≥ 1"));
            }
            if jobs > 1 {
                // Multi-tenant path: N policies (one per job), one
                // shared fleet, disjoint cohorts each round.
                let mut parts = Vec::with_capacity(jobs);
                for _ in 0..jobs {
                    parts.push(participation_from(&args)?);
                }
                args.reject_unknown()?;
                let env = ExpEnv::load(&artifacts)?;
                let fleet_cfg = FleetConfig::sized(devices);
                let report = env.run_method_multi(
                    &method, &cfg, &fleet_cfg, jobs, job_rate, parts)?;
                let recs: Vec<_> =
                    report.records.values().cloned().collect();
                for (id, rec) in &report.records {
                    let path = metrics::write_csv(
                        &format!("run_{method}_{}_job{id}", cfg.task),
                        std::slice::from_ref(rec))?;
                    println!("wrote {path}");
                }
                println!("\n{}",
                         metrics::summary_table(&recs, cfg.target_acc));
                let t = &report.fleet_traffic;
                println!(
                    "fleet traffic: {} B down / {} B up / {} msgs \
                     ({} jobs)",
                    t.downlink, t.uplink, t.messages,
                    report.records.len()
                );
                return Ok(());
            }
            let mut part = participation_from(&args)?;
            args.reject_unknown()?;
            let env = ExpEnv::load(&artifacts)?;
            let fleet_cfg = FleetConfig::sized(devices);
            let rec = env.run_method_with(&method, &cfg, &fleet_cfg,
                                          part.as_mut())?;
            let path =
                metrics::write_csv(&format!("run_{method}_{}", cfg.task),
                                   std::slice::from_ref(&rec))?;
            println!("\n{}", metrics::summary_table(
                std::slice::from_ref(&rec), cfg.target_acc));
            println!("wrote {path}");
            Ok(())
        }
        Some("exp") => {
            let env = ExpEnv::load(&artifacts)?;
            let fig = args.get_or("fig", "");
            let all = args.flag("all");
            let opts = figures::Options {
                devices: args.get_parse("devices", 12usize)?,
                rounds: args.get_parse("rounds", 0usize)?, // 0 = per-fig default
                quick: args.flag("quick"),
                seed: args.get_parse("seed", 1u64)?,
            };
            args.reject_unknown()?;
            if all {
                figures::run_all(&env, &opts)?;
            } else if fig.is_empty() {
                return Err(anyhow!(
                    "pass --fig figN (3,4,5,7,8,9,10,11,12,13) or --all"
                ));
            } else {
                figures::run_one(&env, &fig, &opts)?;
            }
            Ok(())
        }
        Some("fleet") => {
            let devices = args.get_parse("devices", 80usize)?;
            let _ = args.flag("describe");
            args.reject_unknown()?;
            let fleet = Fleet::new(FleetConfig::sized(devices));
            print!("{}", fleet.describe());
            Ok(())
        }
        Some("data") => {
            let _ = args.flag("describe");
            args.reject_unknown()?;
            let env = ExpEnv::load(&artifacts)?;
            println!(
                "{:<8} {:>8} {:>8}  partition     kind",
                "task", "#train", "#test"
            );
            let mut rng = Rng::new(1);
            for t in env.spec.task_names() {
                let (tr, te) = grammar::paper_scaled_sizes(t, 0.02);
                let iid = matches!(t, "gsm" | "mmlu");
                let ds = grammar::generate(&env.spec, t, 64, &mut rng)?;
                println!(
                    "{:<8} {:>8} {:>8}  {:<12} {} classes (e.g. {:?}…)",
                    t,
                    tr,
                    te,
                    if iid { "i.i.d." } else { "non-i.i.d." },
                    env.spec.task(t).map_err(|e| anyhow!("{e}"))?.n_classes,
                    &ds.examples[0].tokens[..6]
                );
            }
            Ok(())
        }
        Some("report") => {
            let dir = args.get_or("results", "results");
            let out = args.get_or("out", "results/REPORT.md");
            args.reject_unknown()?;
            let md = legend::exp::report::build_report(&dir)?;
            std::fs::write(&out, &md)?;
            println!("{md}");
            println!("wrote {out}");
            Ok(())
        }
        Some("kernel") => {
            args.reject_unknown()?;
            let mut env = ExpEnv::load(&artifacts)?;
            let dims =
                legend::runtime::KernelDims::from_manifest(&artifacts)?;
            let mut rng = Rng::new(42);
            let mut gen = |n: usize| -> Vec<f32> {
                (0..n).map(|_| rng.normal() as f32 * 0.3).collect()
            };
            let x = gen(dims.m * dims.k);
            let w = gen(dims.k * dims.n);
            let a = gen(dims.r * dims.k);
            let b = gen(dims.n * dims.r);
            let mask = vec![1.0; dims.r];
            let y = env.rt.run_kernel(&x, &w, &a, &b, &mask, 1.0, &dims)?;
            println!(
                "pallas lora_linear [{}x{}]·[{}x{}] + rank-{} bypass → \
                 {} outputs, ‖y‖₂ = {:.3}",
                dims.m, dims.k, dims.k, dims.n, dims.r, y.len(),
                y.iter().map(|v| (v * v) as f64).sum::<f64>().sqrt()
            );
            Ok(())
        }
        other => {
            Err(anyhow!(
                "unknown subcommand {other:?}; try run | exp | fleet | \
                 data | kernel | report"
            ))
        }
    }
}
