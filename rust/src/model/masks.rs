//! Mask construction — how one artifact serves every LoRA
//! configuration (DESIGN.md "masking trick").
//!
//! A device's LoRA configuration `R_i^h = {r_{i,l} | l ∈ [L-k, L-1]}`
//! (§4.4) is encoded as two f32 mask tensors fed to the train/eval
//! executables:
//!   * `layer_mask [L]`   — 1 where the device holds a LoRA layer;
//!   * `rank_mask  [L, r_max]` — row l has `r_l` ones then zeros.
//! The same encoding expresses the Fig. 3 position variants (S/M/D/A)
//! and FedAdapter widths.

/// Which transformer layers carry the trainable module.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum LayerSet {
    /// Deepest `k` layers `[L-k, L-1]` — LEGEND's LoRA depth.
    Depth(usize),
    /// An explicit set (Fig. 3's Layers-S/M/D variants).
    Explicit(Vec<usize>),
    /// All layers (FedLoRA/HetLoRA).
    All,
}

impl LayerSet {
    /// Indices of active layers, ascending.
    pub fn indices(&self, n_layers: usize) -> Vec<usize> {
        match self {
            LayerSet::Depth(k) => {
                let k = (*k).min(n_layers);
                (n_layers - k..n_layers).collect()
            }
            LayerSet::Explicit(v) => {
                let mut v: Vec<usize> =
                    v.iter().cloned().filter(|&l| l < n_layers).collect();
                v.sort_unstable();
                v.dedup();
                v
            }
            LayerSet::All => (0..n_layers).collect(),
        }
    }

    pub fn layer_mask(&self, n_layers: usize) -> Vec<f32> {
        let mut m = vec![0f32; n_layers];
        for l in self.indices(n_layers) {
            m[l] = 1.0;
        }
        m
    }

    pub fn count(&self, n_layers: usize) -> usize {
        self.indices(n_layers).len()
    }
}

/// A full device configuration: active layers + per-layer rank.
#[derive(Debug, Clone, PartialEq)]
pub struct LoraConfig {
    pub layers: LayerSet,
    /// Global per-layer rank distribution, indexed by absolute layer
    /// id (length L). Only entries of active layers matter.
    pub ranks: Vec<usize>,
}

impl LoraConfig {
    /// Uniform rank r on the given layers (FedLoRA/HetLoRA style).
    pub fn uniform(layers: LayerSet, r: usize, n_layers: usize) -> Self {
        LoraConfig { layers, ranks: vec![r; n_layers] }
    }

    /// Flattened row-major `[L, r_max]` rank mask.
    pub fn rank_mask(&self, n_layers: usize, r_max: usize) -> Vec<f32> {
        let active = self.layers.layer_mask(n_layers);
        let mut m = vec![0f32; n_layers * r_max];
        for l in 0..n_layers {
            if active[l] == 0.0 {
                continue;
            }
            let r = self.ranks[l].min(r_max);
            for j in 0..r {
                m[l * r_max + j] = 1.0;
            }
        }
        m
    }

    pub fn layer_mask(&self, n_layers: usize) -> Vec<f32> {
        self.layers.layer_mask(n_layers)
    }

    /// Active ranks (for eq. 12 upload term + Fig. 11 traffic).
    pub fn active_ranks(&self, n_layers: usize) -> Vec<usize> {
        self.layers
            .indices(n_layers)
            .iter()
            .map(|&l| self.ranks[l])
            .collect()
    }

    /// Total rank Σ r_l over active layers (constraint eq. 11).
    pub fn total_rank(&self, n_layers: usize) -> usize {
        self.active_ranks(n_layers).iter().sum()
    }

    pub fn depth(&self, n_layers: usize) -> usize {
        self.layers.count(n_layers)
    }

    /// Largest rank any active layer uses, floored at 1 — the smallest
    /// rank dimension a trained update can be stored in without losing
    /// active slots (the heterogeneous-rank trim/pad convention in
    /// `coordinator/layout.rs::pad_to_rank`).
    pub fn max_active_rank(&self, n_layers: usize) -> usize {
        self.active_ranks(n_layers)
            .into_iter()
            .max()
            .unwrap_or(1)
            .max(1)
    }

    /// Layers the backward pass must traverse: gradients flow from the
    /// output down to the SHALLOWEST adapted layer, so position — not
    /// just count — sets the compute cost (§2.2, Fig. 3b: Layers-S is
    /// slower than Layers-D despite equal layer counts).
    pub fn backprop_depth(&self, n_layers: usize) -> usize {
        self.layers
            .indices(n_layers)
            .first()
            .map(|&lo| n_layers - lo)
            .unwrap_or(0)
    }
}

/// The paper's global rank distribution (Alg. 1 line 4): an arithmetic
/// sequence `r_l = r_{l-1} + λ`, scaled down if it would exceed the
/// total budget ψ over all L layers.
pub fn arithmetic_ranks(n_layers: usize, lambda: usize, r0: usize,
                        psi: usize, r_max: usize) -> Vec<usize> {
    let mut ranks: Vec<usize> = (0..n_layers)
        .map(|l| (r0 + l * lambda).min(r_max))
        .collect();
    let mut total: usize = ranks.iter().sum();
    // Greedily trim from the shallowest layers until within budget —
    // preserves the non-decreasing property (eq. 10) and keeps deep
    // layers at high rank (§2.4's insight).
    let mut l = 0;
    while total > psi {
        if ranks[l] > 1 {
            ranks[l] -= 1;
            total -= 1;
        } else {
            l = (l + 1) % n_layers;
            if ranks.iter().all(|&r| r <= 1) {
                break;
            }
            continue;
        }
        if l + 1 < n_layers && ranks[l] > ranks[l + 1] {
            l += 1;
        }
    }
    ranks
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn depth_takes_deepest_layers() {
        let m = LayerSet::Depth(4).layer_mask(12);
        assert_eq!(&m[..8], &[0.0; 8]);
        assert_eq!(&m[8..], &[1.0; 4]);
        assert_eq!(LayerSet::Depth(4).indices(12), vec![8, 9, 10, 11]);
    }

    #[test]
    fn depth_clamps_to_model() {
        assert_eq!(LayerSet::Depth(99).count(12), 12);
    }

    #[test]
    fn explicit_set_sorted_deduped_clamped() {
        let s = LayerSet::Explicit(vec![5, 4, 4, 99]);
        assert_eq!(s.indices(12), vec![4, 5]);
    }

    #[test]
    fn rank_mask_rows_match_ranks() {
        let cfg = LoraConfig {
            layers: LayerSet::Depth(2),
            ranks: vec![0, 0, 3, 4],
        };
        let m = cfg.rank_mask(4, 6);
        // layers 0,1 inactive.
        assert!(m[..12].iter().all(|&x| x == 0.0));
        assert_eq!(&m[12..18], &[1.0, 1.0, 1.0, 0.0, 0.0, 0.0]);
        assert_eq!(&m[18..24], &[1.0, 1.0, 1.0, 1.0, 0.0, 0.0]);
        assert_eq!(cfg.active_ranks(4), vec![3, 4]);
        assert_eq!(cfg.total_rank(4), 7);
    }

    #[test]
    fn arithmetic_ranks_monotone_and_within_budget() {
        for (l, lam, r0, psi, rmax) in
            [(12, 1, 1, 78, 16), (12, 1, 1, 40, 16), (24, 2, 2, 100, 16)]
        {
            let r = arithmetic_ranks(l, lam, r0, psi, rmax);
            assert_eq!(r.len(), l);
            for w in r.windows(2) {
                assert!(w[0] <= w[1], "non-monotone {r:?}");
            }
            assert!(r.iter().sum::<usize>() <= psi, "{r:?} exceeds {psi}");
            assert!(r.iter().all(|&x| x >= 1 && x <= rmax));
        }
    }

    #[test]
    fn arithmetic_unconstrained_is_pure_sequence() {
        let r = arithmetic_ranks(12, 1, 1, 1000, 16);
        assert_eq!(r, (1..=12).collect::<Vec<_>>());
    }

    #[test]
    fn uniform_config() {
        let cfg = LoraConfig::uniform(LayerSet::All, 8, 12);
        assert_eq!(cfg.total_rank(12), 96);
        assert_eq!(cfg.depth(12), 12);
    }

    #[test]
    fn max_active_rank_tracks_active_layers_only() {
        let cfg = LoraConfig {
            layers: LayerSet::Depth(2),
            ranks: vec![99, 0, 3, 4],
        };
        // Layer 0's rank 99 is inactive and must not count.
        assert_eq!(cfg.max_active_rank(4), 4);
        // No active layers (or all-zero ranks) floor at 1.
        let none = LoraConfig {
            layers: LayerSet::Explicit(vec![]),
            ranks: vec![5; 4],
        };
        assert_eq!(none.max_active_rank(4), 1);
        let zeros = LoraConfig {
            layers: LayerSet::All,
            ranks: vec![0; 4],
        };
        assert_eq!(zeros.max_active_rank(4), 1);
    }
}
