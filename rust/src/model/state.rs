//! Flat-tensor state containers for trainable parameters + optimizer
//! moments, with initialization matching python/compile/model.py.

use super::{FamilySpec, Manifest, TensorSpec};
use crate::util::rng::Rng;

/// An ordered map of named flat f32 tensors (order = manifest order).
#[derive(Debug, Clone, PartialEq)]
pub struct TensorMap {
    pub entries: Vec<(TensorSpec, Vec<f32>)>,
}

impl TensorMap {
    pub fn zeros(specs: &[TensorSpec]) -> TensorMap {
        TensorMap {
            entries: specs
                .iter()
                .map(|s| (s.clone(), vec![0f32; s.numel()]))
                .collect(),
        }
    }

    pub fn get(&self, name: &str) -> Option<&[f32]> {
        self.entries
            .iter()
            .find(|(s, _)| s.name == name)
            .map(|(_, v)| v.as_slice())
    }

    pub fn get_mut(&mut self, name: &str) -> Option<&mut Vec<f32>> {
        self.entries
            .iter_mut()
            .find(|(s, _)| s.name == name)
            .map(|(_, v)| v)
    }

    pub fn spec(&self, name: &str) -> Option<&TensorSpec> {
        self.entries.iter().map(|(s, _)| s).find(|s| s.name == name)
    }

    pub fn numel(&self) -> usize {
        self.entries.iter().map(|(_, v)| v.len()).sum()
    }

    pub fn names(&self) -> Vec<&str> {
        self.entries.iter().map(|(s, _)| s.name.as_str()).collect()
    }

    /// Max |x| over all tensors — used by divergence watchdogs.
    pub fn max_abs(&self) -> f32 {
        self.entries
            .iter()
            .flat_map(|(_, v)| v.iter())
            .fold(0f32, |acc, x| acc.max(x.abs()))
    }
}

/// Initialize the trainable state of a family, matching the python
/// init exactly in distribution (A ~ N(0, 1/√d) on all slots, B = 0,
/// head ~ N(0, 1/√d); adapters: down ~ N(0, 1/√d), up = 0).
pub fn init_trainable(m: &Manifest, fam: &FamilySpec, rng: &mut Rng)
                      -> TensorMap {
    let d = m.dim.d_model as f64;
    let std = 1.0 / d.sqrt();
    let mut out = TensorMap::zeros(&fam.trainable);
    for (spec, buf) in &mut out.entries {
        let gaussian = match (fam.name.as_str(), spec.name.as_str()) {
            ("lora", "aq" | "av" | "head_w") => true,
            ("adapter", "down" | "head_w") => true,
            _ => false,
        };
        if gaussian {
            for x in buf.iter_mut() {
                *x = (rng.normal() * std) as f32;
            }
        }
    }
    out
}

/// Zero AdamW state for a family.
pub fn init_opt(fam: &FamilySpec) -> TensorMap {
    let specs: Vec<TensorSpec> = fam
        .opt_order
        .iter()
        .map(|n| fam.opt_spec(n).expect("opt name mirrors trainable"))
        .collect();
    TensorMap::zeros(&specs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::tests::manifest_dir;

    #[test]
    fn init_matches_layout() {
        let Some(dir) = manifest_dir() else { return };
        let m = Manifest::load(&dir).unwrap();
        let mut rng = Rng::new(1);
        let t = init_trainable(&m, &m.lora, &mut rng);
        assert_eq!(t.entries.len(), 6);
        // B factors zero-initialized, A factors not.
        assert!(t.get("bq").unwrap().iter().all(|&x| x == 0.0));
        assert!(t.get("av").unwrap().iter().any(|&x| x != 0.0));
        let o = init_opt(&m.lora);
        assert_eq!(o.entries.len(), 12);
        assert_eq!(o.numel(), 2 * t.numel());
    }

    #[test]
    fn tensor_map_access() {
        let specs = vec![
            TensorSpec { name: "a".into(), shape: vec![2, 3] },
            TensorSpec { name: "b".into(), shape: vec![4] },
        ];
        let mut tm = TensorMap::zeros(&specs);
        assert_eq!(tm.numel(), 10);
        tm.get_mut("b").unwrap()[0] = -7.0;
        assert_eq!(tm.get("b").unwrap()[0], -7.0);
        assert_eq!(tm.max_abs(), 7.0);
        assert!(tm.get("c").is_none());
    }

    #[test]
    fn adapter_init_near_identity() {
        let Some(dir) = manifest_dir() else { return };
        let m = Manifest::load(&dir).unwrap();
        let mut rng = Rng::new(2);
        let t = init_trainable(&m, &m.adapter, &mut rng);
        assert!(t.get("up").unwrap().iter().all(|&x| x == 0.0));
        assert!(t.get("down").unwrap().iter().any(|&x| x != 0.0));
    }
}
