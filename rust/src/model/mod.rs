//! Model-state management: artifact manifest, tensor state, masks,
//! and traffic accounting.
//!
//! The AOT pipeline (`python/compile/aot.py`) writes a manifest that
//! pins the exact tensor names/shapes/orderings of every executable's
//! inputs and outputs; this module is the rust mirror. All federated
//! state (global LoRA layers, per-device optimizer state) lives here
//! as flat `f32` buffers in manifest order — the PJRT runtime turns
//! them into literals at the call boundary.

pub mod masks;
pub mod state;

use crate::util::json::Value;

/// One tensor's name + shape.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn bytes(&self) -> usize {
        self.numel() * 4
    }
}

/// Model dimensions from the manifest (mirror of python ModelConfig).
#[derive(Debug, Clone)]
pub struct ModelDim {
    pub n_layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub d_ffn: usize,
    pub vocab_size: usize,
    pub seq_len: usize,
    pub n_classes: usize,
    pub r_max: usize,
    pub adapter_w_max: usize,
    pub batch_size: usize,
    pub eval_batch: usize,
    pub lora_alpha: f64,
}

/// Input/output ordering of one executable.
#[derive(Debug, Clone)]
pub struct StepIo {
    pub artifact: String,
    pub inputs: Vec<String>,
    pub outputs: Vec<String>,
}

/// One model family (lora | adapter): trainable layout + step IO.
#[derive(Debug, Clone)]
pub struct FamilySpec {
    pub name: String,
    pub trainable: Vec<TensorSpec>,
    pub opt_order: Vec<String>,
    pub train: StepIo,
    pub eval: StepIo,
}

impl FamilySpec {
    pub fn trainable_spec(&self, name: &str) -> Option<&TensorSpec> {
        self.trainable.iter().find(|t| t.name == name)
    }

    /// Spec for an optimizer tensor ("m_x"/"v_x" share x's shape).
    pub fn opt_spec(&self, opt_name: &str) -> Option<TensorSpec> {
        let base = opt_name.strip_prefix("m_")
            .or_else(|| opt_name.strip_prefix("v_"))?;
        let t = self.trainable_spec(base)?;
        Some(TensorSpec { name: opt_name.to_string(), shape: t.shape.clone() })
    }
}

/// The parsed artifacts/manifest.json.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: String,
    pub dim: ModelDim,
    pub base: Vec<TensorSpec>,
    pub base_bytes: usize,
    pub lora: FamilySpec,
    pub adapter: FamilySpec,
}

#[derive(Debug, thiserror::Error)]
pub enum ModelError {
    #[error("manifest: {0}")]
    Manifest(String),
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
    #[error("json: {0}")]
    Json(#[from] crate::util::json::ParseError),
}

fn specs_from(v: &Value, what: &str) -> Result<Vec<TensorSpec>, ModelError> {
    let arr = v.as_arr().ok_or_else(|| {
        ModelError::Manifest(format!("{what}: expected array"))
    })?;
    arr.iter()
        .map(|e| {
            Ok(TensorSpec {
                name: e
                    .get("name")
                    .as_str()
                    .ok_or_else(|| {
                        ModelError::Manifest(format!("{what}: missing name"))
                    })?
                    .to_string(),
                shape: e.get("shape").as_usize_vec().ok_or_else(|| {
                    ModelError::Manifest(format!("{what}: missing shape"))
                })?,
            })
        })
        .collect()
}

fn names_from(v: &Value, what: &str) -> Result<Vec<String>, ModelError> {
    v.as_arr()
        .ok_or_else(|| ModelError::Manifest(format!("{what}: not array")))?
        .iter()
        .map(|s| {
            s.as_str().map(str::to_string).ok_or_else(|| {
                ModelError::Manifest(format!("{what}: non-string"))
            })
        })
        .collect()
}

fn step_io(v: &Value, what: &str) -> Result<StepIo, ModelError> {
    Ok(StepIo {
        artifact: v
            .get("artifact")
            .as_str()
            .ok_or_else(|| {
                ModelError::Manifest(format!("{what}: missing artifact"))
            })?
            .to_string(),
        inputs: names_from(v.get("inputs"), what)?,
        outputs: names_from(v.get("outputs"), what)?,
    })
}

fn family(v: &Value, name: &str) -> Result<FamilySpec, ModelError> {
    Ok(FamilySpec {
        name: name.to_string(),
        trainable: specs_from(v.get("trainable"), "trainable")?,
        opt_order: names_from(v.get("opt"), "opt")?,
        train: step_io(v.get("train"), "train")?,
        eval: step_io(v.get("eval"), "eval")?,
    })
}

impl Manifest {
    pub fn load(dir: &str) -> Result<Manifest, ModelError> {
        let path = format!("{dir}/manifest.json");
        let text = std::fs::read_to_string(&path)?;
        let v = Value::parse(&text)?;
        let m = v.get("model");
        let need = |k: &str| -> Result<usize, ModelError> {
            m.get(k).as_usize().ok_or_else(|| {
                ModelError::Manifest(format!("model.{k} missing"))
            })
        };
        let dim = ModelDim {
            n_layers: need("n_layers")?,
            d_model: need("d_model")?,
            n_heads: need("n_heads")?,
            d_ffn: need("d_ffn")?,
            vocab_size: need("vocab_size")?,
            seq_len: need("seq_len")?,
            n_classes: need("n_classes")?,
            r_max: need("r_max")?,
            adapter_w_max: need("adapter_w_max")?,
            batch_size: need("batch_size")?,
            eval_batch: v.get("eval_batch").as_usize().unwrap_or(64),
            lora_alpha: m.get("lora_alpha").as_f64().unwrap_or(16.0),
        };
        Ok(Manifest {
            dir: dir.to_string(),
            dim,
            base: specs_from(v.get("base"), "base")?,
            base_bytes: v.get("base_bytes").as_usize().unwrap_or(0),
            lora: family(v.get("families").get("lora"), "lora")?,
            adapter: family(v.get("families").get("adapter"), "adapter")?,
        })
    }

    pub fn family(&self, name: &str) -> &FamilySpec {
        match name {
            "lora" => &self.lora,
            "adapter" => &self.adapter,
            other => panic!("unknown family {other}"),
        }
    }

    pub fn artifact_path(&self, artifact: &str) -> String {
        format!("{}/{artifact}", self.dir)
    }

    /// Load base_weights.bin (little-endian f32, BASE_ORDER concat).
    pub fn load_base_weights(&self) -> Result<Vec<Vec<f32>>, ModelError> {
        let path = format!("{}/base_weights.bin", self.dir);
        let bytes = std::fs::read(&path)?;
        let total: usize = self.base.iter().map(|t| t.numel()).sum();
        if bytes.len() != total * 4 {
            return Err(ModelError::Manifest(format!(
                "base_weights.bin is {} bytes, manifest wants {}",
                bytes.len(),
                total * 4
            )));
        }
        let mut out = Vec::with_capacity(self.base.len());
        let mut off = 0usize;
        for spec in &self.base {
            let n = spec.numel();
            let mut buf = Vec::with_capacity(n);
            for i in 0..n {
                let b = &bytes[(off + i) * 4..(off + i) * 4 + 4];
                buf.push(f32::from_le_bytes([b[0], b[1], b[2], b[3]]));
            }
            off += n;
            out.push(buf);
        }
        Ok(out)
    }

    // ---- traffic accounting (Fig. 11) ------------------------------------

    /// Bytes to transmit ONE rank of ONE transformer layer's LoRA
    /// (A row [d] + B column [d], for both the q and v projections).
    pub fn unit_rank_bytes(&self) -> usize {
        4 * (2 * self.dim.d_model) * 2
    }

    /// Bytes for the (always-trainable) classification head.
    pub fn head_bytes(&self) -> usize {
        4 * (self.dim.d_model * self.dim.n_classes + self.dim.n_classes)
    }

    /// Upload bytes for a device transmitting LoRA ranks `ranks` on its
    /// active layers plus the head.
    pub fn lora_upload_bytes(&self, ranks: &[usize]) -> usize {
        let rank_sum: usize = ranks.iter().sum();
        rank_sum * self.unit_rank_bytes() + self.head_bytes()
    }

    /// Bytes for an adapter of width `w` on one layer (down col + up
    /// row + bias scalar per width unit).
    pub fn adapter_unit_width_bytes(&self) -> usize {
        4 * (2 * self.dim.d_model + 1)
    }

    pub fn adapter_upload_bytes(&self, widths: &[usize]) -> usize {
        let w_sum: usize = widths.iter().sum();
        w_sum * self.adapter_unit_width_bytes() + self.head_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    pub(crate) fn manifest_dir() -> Option<String> {
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
        if std::path::Path::new(&format!("{dir}/manifest.json")).exists() {
            Some(dir.to_string())
        } else {
            None
        }
    }

    #[test]
    fn loads_real_manifest_when_present() {
        let Some(dir) = manifest_dir() else { return };
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.dim.n_layers, 12);
        assert!(m.dim.r_max >= 12);
        assert_eq!(m.base.len(), 20);
        assert_eq!(m.lora.trainable.len(), 6);
        assert_eq!(m.adapter.trainable.len(), 5);
        assert_eq!(m.lora.opt_order.len(), 12);
        // Train IO: base + trainable + opt + masks/batch/scalars.
        assert_eq!(
            m.lora.train.inputs.len(),
            20 + 6 + 12 + 6,
            "{:?}",
            m.lora.train.inputs
        );
        assert_eq!(m.lora.train.outputs.len(), 6 + 12 + 2);
        // base file matches manifest.
        let base = m.load_base_weights().unwrap();
        assert_eq!(base.len(), 20);
        assert_eq!(base[0].len(), m.dim.vocab_size * m.dim.d_model);
    }

    #[test]
    fn traffic_accounting() {
        let Some(dir) = manifest_dir() else { return };
        let m = Manifest::load(&dir).unwrap();
        let d = m.dim.d_model;
        assert_eq!(m.unit_rank_bytes(), 16 * d);
        // rank 8 on 4 layers, plus head.
        let bytes = m.lora_upload_bytes(&[8, 8, 8, 8]);
        assert_eq!(bytes, 32 * 16 * d + m.head_bytes());
        // More ranks → more bytes.
        assert!(m.lora_upload_bytes(&[9, 10, 11, 12]) > bytes);
    }

    #[test]
    fn opt_spec_mirrors_trainable() {
        let Some(dir) = manifest_dir() else { return };
        let m = Manifest::load(&dir).unwrap();
        let s = m.lora.opt_spec("m_aq").unwrap();
        assert_eq!(s.shape,
                   m.lora.trainable_spec("aq").unwrap().shape);
        assert!(m.lora.opt_spec("bogus").is_none());
    }
}
