//! LEGEND: adaptive parameter-efficient federated fine-tuning on
//! heterogeneous devices — reproduction library.
//!
//! Three-layer architecture (DESIGN.md §1):
//!  * this crate is **L3**, the coordination system — the parameter
//!    server round loop, the LCD configuration algorithm (Alg. 1),
//!    layer-wise aggregation, the heterogeneous device fleet and WiFi
//!    simulators, datasets, metrics;
//!  * **L2** (JAX model, python/compile/model.py) and **L1** (Pallas
//!    fused LoRA kernel) are compiled ONCE to HLO text by
//!    `make artifacts` and executed from [`runtime`] via PJRT —
//!    python never runs at federated-training time.

pub mod coordinator;
pub mod data;
pub mod device;
pub mod exp;
pub mod metrics;
pub mod model;
pub mod runtime;
pub mod sim;
pub mod util;
