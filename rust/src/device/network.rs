//! WiFi uplink model.
//!
//! §6.1: devices sit in four groups at 2/8/14/20 m from the routers;
//! iperf3-measured bandwidth fluctuates between 1 and 30 Mb/s from
//! channel noise and contention. We model each device's uplink as an
//! AR(1) process in log-bandwidth around a distance-dependent mean
//! (log-distance path loss), clipped to the measured [1, 30] Mb/s
//! envelope. Upload dominates (the paper only models upload time β).

use crate::util::rng::Rng;

/// Distances of the four WiFi groups [m] (§6.1).
pub const GROUP_DISTANCES_M: [f64; 4] = [2.0, 8.0, 14.0, 20.0];

/// Envelope measured by iperf3 in the paper [Mb/s].
pub const BW_MIN_MBPS: f64 = 1.0;
pub const BW_MAX_MBPS: f64 = 30.0;

/// Path-loss exponent for the mean-bandwidth vs distance curve.
const PATH_LOSS_EXP: f64 = 0.9;
/// AR(1) persistence of log-bandwidth between rounds.
const AR_RHO: f64 = 0.7;
/// Innovation std-dev of log-bandwidth (≈ ±40% swings round-to-round).
const AR_SIGMA: f64 = 0.35;

/// Mean uplink bandwidth at a given router distance [Mb/s].
pub fn mean_bandwidth_mbps(distance_m: f64) -> f64 {
    let bw = BW_MAX_MBPS * (distance_m / GROUP_DISTANCES_M[0])
        .powf(-PATH_LOSS_EXP);
    bw.clamp(BW_MIN_MBPS, BW_MAX_MBPS)
}

/// Stationary std-dev of the AR(1) log-bandwidth deviation.
pub fn stat_sigma() -> f64 {
    AR_SIGMA / (1.0 - AR_RHO * AR_RHO).sqrt()
}

/// Round-0 deviation from its unit-normal innovation (stationary start).
pub fn ar1_init(eps0: f64) -> f64 {
    stat_sigma() * eps0
}

/// One AR(1) round of the deviation: x_t from x_{t-1} and the round's
/// unit-normal innovation.
pub fn ar1_step(x: f64, eps: f64) -> f64 {
    AR_RHO * x + AR_SIGMA * eps
}

/// Per-device AR(1) fading state.
#[derive(Debug, Clone)]
pub struct NetworkModel {
    /// WiFi group index (0..4).
    pub group: usize,
    log_mean: f64,
    log_bw: f64,
}

impl NetworkModel {
    pub fn new(group: usize, rng: &mut Rng) -> Self {
        assert!(group < GROUP_DISTANCES_M.len());
        let log_mean = mean_bandwidth_mbps(GROUP_DISTANCES_M[group]).ln();
        // Start at steady state.
        let stationary_sigma =
            AR_SIGMA / (1.0 - AR_RHO * AR_RHO).sqrt();
        let log_bw = log_mean + stationary_sigma * rng.normal();
        NetworkModel { group, log_mean, log_bw }
    }

    /// Build the fading state from a known log-bandwidth deviation `x`
    /// around the group mean. Where `new`/`step` mutate draw by draw,
    /// this is pure in `(group, x)` — the entry point for the fleet's
    /// counter-based closed-form derivation, with `x` produced by
    /// [`ar1_init`]/[`ar1_step`] over a per-device innovation stream.
    pub fn from_deviation(group: usize, x: f64) -> Self {
        assert!(group < GROUP_DISTANCES_M.len());
        let log_mean = mean_bandwidth_mbps(GROUP_DISTANCES_M[group]).ln();
        NetworkModel { group, log_mean, log_bw: log_mean + x }
    }

    /// Advance one round of fading; returns the new bandwidth [Mb/s].
    pub fn step(&mut self, rng: &mut Rng) -> f64 {
        self.log_bw = AR_RHO * self.log_bw
            + (1.0 - AR_RHO) * self.log_mean
            + AR_SIGMA * rng.normal();
        self.bandwidth_mbps()
    }

    /// Current uplink bandwidth [Mb/s], clipped to the iperf3 envelope.
    pub fn bandwidth_mbps(&self) -> f64 {
        self.log_bw.exp().clamp(BW_MIN_MBPS, BW_MAX_MBPS)
    }

    /// Time to upload `bytes` at the current bandwidth [s].
    pub fn upload_time_s(&self, bytes: usize) -> f64 {
        (bytes as f64 * 8.0) / (self.bandwidth_mbps() * 1e6)
    }

    /// β of eq. (12): upload time for ONE unit-rank LoRA layer [s].
    pub fn beta(&self, unit_rank_bytes: usize) -> f64 {
        self.upload_time_s(unit_rank_bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_bandwidth_decreases_with_distance() {
        let bws: Vec<f64> = GROUP_DISTANCES_M
            .iter()
            .map(|&d| mean_bandwidth_mbps(d))
            .collect();
        for w in bws.windows(2) {
            assert!(w[0] > w[1], "{bws:?}");
        }
        assert!(bws[0] <= BW_MAX_MBPS && bws[3] >= BW_MIN_MBPS);
    }

    #[test]
    fn fading_stays_in_envelope() {
        let mut rng = Rng::new(11);
        for group in 0..4 {
            let mut net = NetworkModel::new(group, &mut rng);
            for _ in 0..500 {
                let bw = net.step(&mut rng);
                assert!((BW_MIN_MBPS..=BW_MAX_MBPS).contains(&bw));
            }
        }
    }

    #[test]
    fn fading_is_temporally_correlated() {
        let mut rng = Rng::new(12);
        let mut net = NetworkModel::new(1, &mut rng);
        let xs: Vec<f64> =
            (0..2000).map(|_| net.step(&mut rng).ln()).collect();
        let n = xs.len() - 1;
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let mut num = 0.0;
        let mut den = 0.0;
        for i in 0..n {
            // detlint-allow: float-accum statistical test, seeded sequential loop
            num += (xs[i] - mean) * (xs[i + 1] - mean);
        }
        for x in &xs {
            // detlint-allow: float-accum statistical test, seeded sequential loop
            den += (x - mean) * (x - mean);
        }
        let rho = num / den;
        assert!(rho > 0.4, "lag-1 autocorr {rho} too low for AR(1)");
    }

    #[test]
    fn deviation_form_tracks_absolute_recursion() {
        // x_t = ρ·x_{t-1} + σ·ε reproduces (up to float reassociation)
        // the absolute-form step() driven by the same innovations.
        let mut rng = Rng::new(21);
        let mut abs = NetworkModel::new(2, &mut rng);
        // Replay the init draw to recover ε_0 for the deviation form.
        let mut replay = Rng::new(21);
        let eps0 = replay.normal();
        let mut x = ar1_init(eps0);
        for _ in 0..50 {
            let eps = replay.normal();
            abs.step(&mut rng);
            x = ar1_step(x, eps);
            let dev = NetworkModel::from_deviation(2, x);
            assert!(
                (dev.bandwidth_mbps() - abs.bandwidth_mbps()).abs() < 1e-9,
                "deviation form drifted from absolute form"
            );
        }
        // Zero deviation sits exactly on the group mean.
        let at_mean = NetworkModel::from_deviation(1, 0.0);
        let want = mean_bandwidth_mbps(GROUP_DISTANCES_M[1]);
        assert!((at_mean.bandwidth_mbps() - want).abs() < 1e-12);
    }

    #[test]
    fn upload_time_scales_with_bytes() {
        let mut rng = Rng::new(13);
        let net = NetworkModel::new(0, &mut rng);
        let t1 = net.upload_time_s(1_000_000);
        let t2 = net.upload_time_s(2_000_000);
        assert!((t2 / t1 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn near_group_faster_than_far_group_on_average() {
        let mut rng = Rng::new(14);
        let mut near = NetworkModel::new(0, &mut rng);
        let mut far = NetworkModel::new(3, &mut rng);
        let (mut a, mut b) = (0.0, 0.0);
        for _ in 0..300 {
            // detlint-allow: float-accum statistical test, seeded sequential loop
            a += near.step(&mut rng);
            // detlint-allow: float-accum statistical test, seeded sequential loop
            b += far.step(&mut rng);
        }
        assert!(a > b, "near {a} should beat far {b}");
    }
}
