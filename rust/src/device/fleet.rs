//! The heterogeneous fleet (§6.1), eager and lazy.
//!
//! Composition follows the paper: 30 Jetson TX2 + 40 Jetson NX + 10
//! Jetson AGX, shuffled into four WiFi groups of 20. DVFS modes are
//! resampled every `mode_reshuffle_rounds` (=20) rounds to reflect
//! resources varying over time; WiFi fading advances every round.
//! Devices also report *measured* μ̂/β̂ with observation noise so the
//! PS-side capacity estimator (eq. 8–9) has real work to do.
//!
//! Every stochastic per-device quantity is a pure function of
//! `(seed, device_id, round)` evaluated through counter-based RNG
//! cells ([`Rng::cell`]) in [`FleetCore`]. Two views share that
//! derivation:
//!
//! * [`Fleet`] — eager: materializes all `Device`s (the paper's
//!   80-device testbed; cheap at small n, O(fleet) memory).
//! * [`LazyFleet`] — derives a device only when the cohort touches it;
//!   `advance_round` is O(1) and memory stays O(cohort) at any
//!   population size (the million-device configuration).
//!
//! Both are bit-identical under [`FleetView`]: same `(seed, round)` ⇒
//! same profiles, fading state, and μ̂/β̂ observations.

use std::collections::BTreeMap;

use super::network::{self, NetworkModel};
use super::profile::{ComputeProfile, DeviceClass};
use crate::util::rng::{IndexPerm, Rng};

/// Fleet construction parameters.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    pub n_tx2: usize,
    pub n_nx: usize,
    pub n_agx: usize,
    /// Rounds between DVFS mode resampling (§6.1: every 20 rounds).
    pub mode_reshuffle_rounds: usize,
    /// Relative std-dev of the measurement noise on reported μ̂/β̂.
    pub obs_noise: f64,
    pub seed: u64,
}

impl FleetConfig {
    /// The paper's 80-device testbed.
    pub fn paper() -> Self {
        FleetConfig {
            n_tx2: 30,
            n_nx: 40,
            n_agx: 10,
            mode_reshuffle_rounds: 20,
            obs_noise: 0.05,
            seed: 1,
        }
    }

    /// The 10-device pre-test setup used for Figs. 3–5 (§2.2).
    pub fn pretest() -> Self {
        FleetConfig { n_tx2: 4, n_nx: 4, n_agx: 2, ..Self::paper() }
    }

    /// Arbitrary size, class mix proportional to the paper's 30/40/10
    /// (largest-remainder apportionment, so counts track n·w/80 to
    /// within one device at every size and always sum to n).
    pub fn sized(n: usize) -> Self {
        let weights = [30usize, 40, 10]; // Tx2, Nx, Agx out of 80
        let mut counts = [0usize; 3];
        let mut order: Vec<(usize, usize)> = Vec::with_capacity(3);
        for (c, &w) in weights.iter().enumerate() {
            counts[c] = n * w / 80;
            order.push((n * w % 80, c));
        }
        // Hand the ≤ 2 leftover seats to the largest remainders
        // (ties broken by class order, so the result is deterministic).
        order.sort_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
        let mut left = n - counts.iter().sum::<usize>();
        for &(_, c) in &order {
            if left == 0 {
                break;
            }
            counts[c] += 1;
            left -= 1;
        }
        FleetConfig {
            n_tx2: counts[0],
            n_nx: counts[1],
            n_agx: counts[2],
            ..Self::paper()
        }
    }

    pub fn total(&self) -> usize {
        self.n_tx2 + self.n_nx + self.n_agx
    }
}

/// One simulated device.
#[derive(Debug, Clone)]
pub struct Device {
    pub id: usize,
    pub compute: ComputeProfile,
    pub net: NetworkModel,
}

impl Device {
    /// True μ [s/layer/batch] — ground truth the estimator chases.
    pub fn true_mu(&self) -> f64 {
        self.compute.mu()
    }

    /// Measured μ̂ with observation noise (what the device reports).
    pub fn measured_mu(&self, rng: &mut Rng, noise: f64) -> f64 {
        self.true_mu() * (1.0 + noise * rng.normal()).max(0.1)
    }

    /// True β [s per unit-rank LoRA layer].
    pub fn true_beta(&self, unit_rank_bytes: usize) -> f64 {
        self.net.beta(unit_rank_bytes)
    }

    pub fn measured_beta(&self, unit_rank_bytes: usize, rng: &mut Rng,
                         noise: f64) -> f64 {
        self.true_beta(unit_rank_bytes) * (1.0 + noise * rng.normal()).max(0.1)
    }
}

/// Uniform interface the engines run against: the eager [`Fleet`] and
/// the on-demand [`LazyFleet`] answer every query bit-identically for
/// the same `(seed, round)` — the determinism contract that lets the
/// property suite pin one against the other.
pub trait FleetView {
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Advance to the next round: WiFi fading every round, DVFS mode
    /// resample every `mode_reshuffle_rounds`.
    fn advance_round(&mut self);

    /// Noisy status report (μ̂, β̂) for device `i` this round.
    fn observe(&mut self, i: usize, unit_rank_bytes: usize) -> (f64, f64);

    /// True μ [s/layer/batch] of device `i` this round.
    fn true_mu(&self, i: usize) -> f64;

    /// True β [s per unit-rank LoRA layer] of device `i` this round.
    fn true_beta(&self, i: usize, unit_rank_bytes: usize) -> f64;

    /// Forward time per batch [s] of device `i` this round.
    fn forward_time(&self, i: usize, n_layers: usize) -> f64;
}

/// The pure derivation shared by both fleet views: every per-device
/// quantity is computed from counter-based cells of a root stream, so
/// device `i`'s round-`t` state never depends on any other device or
/// on how many queries came before it.
///
/// Stream layout (all cells of `Rng::new(seed).child("fleet")`):
///
/// * `child("perm")`     — keys of the class-layout permutation
/// * `cell("mode", i, epoch)` — DVFS mode draw, `epoch = t / reshuffle`
/// * `cell("fade", i, t)`     — AR(1) innovation ε of round `t`
/// * `cell("obs",  i, t≪32|k)` — noise of the k-th observation in `t`
#[derive(Debug, Clone)]
struct FleetCore {
    config: FleetConfig,
    n: usize,
    root: Rng,
    perm: IndexPerm,
    /// Per-device count of `observe` calls within the current round —
    /// repeated same-round observations must draw fresh noise, so the
    /// call index is part of the cell address. Cleared every round;
    /// holds only devices actually observed, so it stays O(cohort).
    obs_calls: BTreeMap<usize, u64>,
}

impl FleetCore {
    fn new(config: FleetConfig) -> FleetCore {
        let root = Rng::new(config.seed).child("fleet");
        let n = config.total();
        let perm = IndexPerm::new(n, &mut root.child("perm"));
        FleetCore { config, n, root, perm, obs_calls: BTreeMap::new() }
    }

    /// Class of device `i`: the permutation shuffles the sorted layout
    /// (Tx2 block, then Nx, then Agx) so class counts stay exact.
    fn class_of(&self, i: usize) -> DeviceClass {
        let pos = self.perm.apply(i);
        if pos < self.config.n_tx2 {
            DeviceClass::Tx2
        } else if pos < self.config.n_tx2 + self.config.n_nx {
            DeviceClass::Nx
        } else {
            DeviceClass::Agx
        }
    }

    /// Equal-size WiFi groups: 4 groups of n/4 (paper: 4 × 20).
    fn group_of(&self, i: usize) -> usize {
        ((i * 4) / self.n.max(1)).min(3)
    }

    /// DVFS mode of device `i` at `round` — constant within a
    /// reshuffle epoch, redrawn when the epoch changes.
    fn mode_of(&self, i: usize, round: usize) -> usize {
        let rr = self.config.mode_reshuffle_rounds;
        let epoch = if rr > 0 { round / rr } else { 0 };
        let n_modes = self.class_of(i).n_modes();
        self.root.cell("mode", i as u64, epoch as u64).range(0, n_modes)
    }

    /// Unit-normal AR(1) innovation of device `i` at round `t`.
    fn fade_eps(&self, i: usize, t: usize) -> f64 {
        self.root.cell("fade", i as u64, t as u64).normal()
    }

    /// Log-bandwidth deviation of device `i` at `round`, by running
    /// the AR(1) recursion from its stationary start — O(round) per
    /// query but pure, which is what keeps `advance_round` O(1).
    fn deviation_of(&self, i: usize, round: usize) -> f64 {
        let mut x = network::ar1_init(self.fade_eps(i, 0));
        for t in 1..=round {
            x = network::ar1_step(x, self.fade_eps(i, t));
        }
        x
    }

    fn device_at(&self, i: usize, round: usize) -> Device {
        Device {
            id: i,
            compute: ComputeProfile::new(self.class_of(i), self.mode_of(i, round)),
            net: NetworkModel::from_deviation(
                self.group_of(i),
                self.deviation_of(i, round),
            ),
        }
    }

    /// Unit-normal (ε_μ, ε_β) for the next observation of device `i`
    /// this round.
    fn observe_noise(&mut self, i: usize, round: usize) -> (f64, f64) {
        let k = self.obs_calls.entry(i).or_insert(0);
        let stream = ((round as u64) << 32) | (*k & 0xFFFF_FFFF);
        *k += 1;
        let mut r = self.root.cell("obs", i as u64, stream);
        (r.normal(), r.normal())
    }

    fn measured(d: &Device, unit_rank_bytes: usize, noise: f64,
                eps: (f64, f64)) -> (f64, f64) {
        (
            d.true_mu() * (1.0 + noise * eps.0).max(0.1),
            d.true_beta(unit_rank_bytes) * (1.0 + noise * eps.1).max(0.1),
        )
    }

    fn clear_round(&mut self) {
        self.obs_calls.clear();
    }
}

/// The eagerly materialized population.
#[derive(Debug, Clone)]
pub struct Fleet {
    pub devices: Vec<Device>,
    pub config: FleetConfig,
    core: FleetCore,
    /// Incrementally stepped AR(1) log-bandwidth deviations — the same
    /// recursion [`FleetCore::deviation_of`] replays from scratch, so
    /// the eager and lazy fading states agree bit for bit.
    deviations: Vec<f64>,
    round: usize,
}

impl Fleet {
    pub fn new(config: FleetConfig) -> Fleet {
        let core = FleetCore::new(config.clone());
        let n = core.n;
        let deviations: Vec<f64> =
            (0..n).map(|i| network::ar1_init(core.fade_eps(i, 0))).collect();
        let devices = (0..n).map(|i| core.device_at(i, 0)).collect();
        Fleet { devices, config, core, deviations, round: 0 }
    }

    /// Table 1-style description (used by `legend fleet --describe`).
    pub fn describe(&self) -> String {
        let mut out = String::new();
        out.push_str(
            "class              count  AI perf      GPU              modes\n");
        for class in DeviceClass::ALL {
            let count =
                self.devices.iter().filter(|d| d.compute.class == class)
                    .count();
            out.push_str(&format!(
                "{:<18} {:>5}  {:<11} {:<16} {}\n",
                class.name(),
                count,
                match class {
                    DeviceClass::Tx2 => "1.33 TFLOPS",
                    DeviceClass::Nx => "21 TOPS",
                    DeviceClass::Agx => "22 TOPS",
                },
                class.gpu(),
                class.n_modes(),
            ));
        }
        let mus: Vec<f64> =
            self.devices.iter().map(|d| d.true_mu()).collect();
        let (mn, mx) = (
            mus.iter().cloned().fold(f64::MAX, f64::min),
            mus.iter().cloned().fold(0.0, f64::max),
        );
        out.push_str(&format!(
            "μ spread: {:.1} ms .. {:.1} ms ({:.0}×)\n",
            mn * 1e3,
            mx * 1e3,
            mx / mn
        ));
        out
    }
}

impl FleetView for Fleet {
    fn len(&self) -> usize {
        self.devices.len()
    }

    fn advance_round(&mut self) {
        self.round += 1;
        self.core.clear_round();
        for (i, d) in self.devices.iter_mut().enumerate() {
            self.deviations[i] = network::ar1_step(
                self.deviations[i],
                self.core.fade_eps(i, self.round),
            );
            d.net = NetworkModel::from_deviation(
                self.core.group_of(i),
                self.deviations[i],
            );
            d.compute.mode = self.core.mode_of(i, self.round);
        }
    }

    fn observe(&mut self, i: usize, unit_rank_bytes: usize) -> (f64, f64) {
        let eps = self.core.observe_noise(i, self.round);
        FleetCore::measured(
            &self.devices[i],
            unit_rank_bytes,
            self.config.obs_noise,
            eps,
        )
    }

    fn true_mu(&self, i: usize) -> f64 {
        self.devices[i].true_mu()
    }

    fn true_beta(&self, i: usize, unit_rank_bytes: usize) -> f64 {
        self.devices[i].true_beta(unit_rank_bytes)
    }

    fn forward_time(&self, i: usize, n_layers: usize) -> f64 {
        self.devices[i].compute.forward_time(n_layers)
    }
}

/// The on-demand population: no per-device storage at all. Each query
/// derives the requested device's state closed-form from
/// `(seed, device_id, round)`, so a million-device fleet costs the
/// same as an empty one until the cohort touches it.
#[derive(Debug, Clone)]
pub struct LazyFleet {
    pub config: FleetConfig,
    core: FleetCore,
    round: usize,
}

impl LazyFleet {
    pub fn new(config: FleetConfig) -> LazyFleet {
        let core = FleetCore::new(config.clone());
        LazyFleet { config, core, round: 0 }
    }

    /// Materialize device `i` at the current round (for inspection —
    /// the engines only go through [`FleetView`]).
    pub fn device_at(&self, i: usize) -> Device {
        self.core.device_at(i, self.round)
    }
}

impl FleetView for LazyFleet {
    fn len(&self) -> usize {
        self.core.n
    }

    fn advance_round(&mut self) {
        self.round += 1;
        self.core.clear_round();
    }

    fn observe(&mut self, i: usize, unit_rank_bytes: usize) -> (f64, f64) {
        let d = self.core.device_at(i, self.round);
        let eps = self.core.observe_noise(i, self.round);
        FleetCore::measured(&d, unit_rank_bytes, self.config.obs_noise, eps)
    }

    fn true_mu(&self, i: usize) -> f64 {
        self.core.device_at(i, self.round).true_mu()
    }

    fn true_beta(&self, i: usize, unit_rank_bytes: usize) -> f64 {
        self.core.device_at(i, self.round).true_beta(unit_rank_bytes)
    }

    fn forward_time(&self, i: usize, n_layers: usize) -> f64 {
        self.core
            .device_at(i, self.round)
            .compute
            .forward_time(n_layers)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_fleet_composition() {
        let f = Fleet::new(FleetConfig::paper());
        assert_eq!(f.len(), 80);
        let count = |c: DeviceClass| {
            f.devices.iter().filter(|d| d.compute.class == c).count()
        };
        assert_eq!(count(DeviceClass::Tx2), 30);
        assert_eq!(count(DeviceClass::Nx), 40);
        assert_eq!(count(DeviceClass::Agx), 10);
        // Four equal groups.
        for g in 0..4 {
            assert_eq!(
                f.devices.iter().filter(|d| d.net.group == g).count(),
                20
            );
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = Fleet::new(FleetConfig::paper());
        let b = Fleet::new(FleetConfig::paper());
        for (x, y) in a.devices.iter().zip(&b.devices) {
            assert_eq!(x.compute.class, y.compute.class);
            assert_eq!(x.compute.mode, y.compute.mode);
        }
    }

    #[test]
    fn modes_reshuffle_on_schedule() {
        let mut f = Fleet::new(FleetConfig::paper());
        let before: Vec<usize> =
            f.devices.iter().map(|d| d.compute.mode).collect();
        for _ in 0..19 {
            f.advance_round();
        }
        let mid: Vec<usize> =
            f.devices.iter().map(|d| d.compute.mode).collect();
        assert_eq!(before, mid, "modes must hold for 19 rounds");
        f.advance_round(); // round 20 → reshuffle
        let after: Vec<usize> =
            f.devices.iter().map(|d| d.compute.mode).collect();
        assert_ne!(before, after, "modes must reshuffle at round 20");
    }

    #[test]
    fn observation_noise_centered_on_truth() {
        let mut f = Fleet::new(FleetConfig::pretest());
        let truth = f.devices[0].true_mu();
        let n = 2000;
        let mean: f64 = (0..n)
            .map(|_| f.observe(0, 1024).0)
            .sum::<f64>()
            / n as f64;
        assert!(
            (mean / truth - 1.0).abs() < 0.02,
            "mean {mean} vs truth {truth}"
        );
    }

    #[test]
    fn repeated_observations_draw_fresh_noise() {
        let mut f = Fleet::new(FleetConfig::pretest());
        let a = f.observe(0, 1024);
        let b = f.observe(0, 1024);
        assert_ne!(a, b, "same-round observations must not repeat");
        // But the call sequence is reproducible from the seed.
        let mut g = Fleet::new(FleetConfig::pretest());
        assert_eq!(a, g.observe(0, 1024));
        assert_eq!(b, g.observe(0, 1024));
    }

    #[test]
    fn sized_fleet_has_requested_total() {
        for n in [10, 16, 40, 80] {
            assert_eq!(Fleet::new(FleetConfig::sized(n)).len(), n);
        }
    }

    #[test]
    fn sized_fleet_tracks_paper_proportions() {
        // Largest-remainder apportionment: every class count is within
        // one device of the exact n·w/80 share, and totals are exact —
        // including sizes not divisible by 80.
        for n in 1..=300usize {
            let c = FleetConfig::sized(n);
            assert_eq!(c.total(), n, "total mismatch at n={n}");
            for (count, w) in
                [(c.n_tx2, 30.0), (c.n_nx, 40.0), (c.n_agx, 10.0)]
            {
                let exact = n as f64 * w / 80.0;
                assert!(
                    (count as f64 - exact).abs() < 1.0,
                    "n={n}: count {count} vs exact share {exact}"
                );
            }
        }
        // Spot-check the paper-adjacent sizes.
        let c = FleetConfig::sized(80);
        assert_eq!((c.n_tx2, c.n_nx, c.n_agx), (30, 40, 10));
        let c = FleetConfig::sized(100);
        assert_eq!((c.n_tx2, c.n_nx, c.n_agx), (38, 50, 12));
        let c = FleetConfig::sized(10);
        assert_eq!((c.n_tx2, c.n_nx, c.n_agx), (4, 5, 1));
    }

    #[test]
    fn lazy_fleet_matches_eager_bitwise() {
        let cfg = FleetConfig::pretest();
        let mut eager = Fleet::new(cfg.clone());
        let mut lazy = LazyFleet::new(cfg);
        for round in 0..25 {
            for i in 0..eager.len() {
                let d = lazy.device_at(i);
                assert_eq!(d.compute.class, eager.devices[i].compute.class);
                assert_eq!(d.compute.mode, eager.devices[i].compute.mode,
                           "mode drift at round {round} device {i}");
                assert_eq!(d.net.bandwidth_mbps().to_bits(),
                           eager.devices[i].net.bandwidth_mbps().to_bits(),
                           "fading drift at round {round} device {i}");
                assert_eq!(eager.observe(i, 1024), lazy.observe(i, 1024));
            }
            eager.advance_round();
            lazy.advance_round();
        }
    }

    #[test]
    fn lazy_advance_round_is_population_independent() {
        // advance_round must not touch per-device state: a fleet of a
        // million devices advances as cheaply as one of ten, and the
        // answer for a probed device is unchanged by fleet size probes
        // of other devices.
        let mut big = LazyFleet::new(FleetConfig {
            seed: 7,
            ..FleetConfig::sized(1_000_000)
        });
        for _ in 0..5 {
            big.advance_round();
        }
        let a = big.device_at(123_456).net.bandwidth_mbps();
        let b = big.device_at(123_456).net.bandwidth_mbps();
        assert_eq!(a.to_bits(), b.to_bits());
    }
}
