//! The 80-device heterogeneous fleet (§6.1).
//!
//! Composition follows the paper: 30 Jetson TX2 + 40 Jetson NX + 10
//! Jetson AGX, shuffled into four WiFi groups of 20. DVFS modes are
//! resampled every `mode_reshuffle_rounds` (=20) rounds to reflect
//! resources varying over time; WiFi fading advances every round.
//! Devices also report *measured* μ̂/β̂ with observation noise so the
//! PS-side capacity estimator (eq. 8–9) has real work to do.

use super::network::NetworkModel;
use super::profile::{ComputeProfile, DeviceClass};
use crate::util::rng::Rng;

/// Fleet construction parameters.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    pub n_tx2: usize,
    pub n_nx: usize,
    pub n_agx: usize,
    /// Rounds between DVFS mode resampling (§6.1: every 20 rounds).
    pub mode_reshuffle_rounds: usize,
    /// Relative std-dev of the measurement noise on reported μ̂/β̂.
    pub obs_noise: f64,
    pub seed: u64,
}

impl FleetConfig {
    /// The paper's 80-device testbed.
    pub fn paper() -> Self {
        FleetConfig {
            n_tx2: 30,
            n_nx: 40,
            n_agx: 10,
            mode_reshuffle_rounds: 20,
            obs_noise: 0.05,
            seed: 1,
        }
    }

    /// The 10-device pre-test setup used for Figs. 3–5 (§2.2).
    pub fn pretest() -> Self {
        FleetConfig { n_tx2: 4, n_nx: 4, n_agx: 2, ..Self::paper() }
    }

    /// Arbitrary size, class mix proportional to the paper's.
    pub fn sized(n: usize) -> Self {
        let n_tx2 = (n * 30) / 80;
        let n_agx = ((n * 10) / 80).max(1);
        let n_nx = n - n_tx2 - n_agx;
        FleetConfig { n_tx2, n_nx, n_agx, ..Self::paper() }
    }

    pub fn total(&self) -> usize {
        self.n_tx2 + self.n_nx + self.n_agx
    }
}

/// One simulated device.
#[derive(Debug, Clone)]
pub struct Device {
    pub id: usize,
    pub compute: ComputeProfile,
    pub net: NetworkModel,
}

impl Device {
    /// True μ [s/layer/batch] — ground truth the estimator chases.
    pub fn true_mu(&self) -> f64 {
        self.compute.mu()
    }

    /// Measured μ̂ with observation noise (what the device reports).
    pub fn measured_mu(&self, rng: &mut Rng, noise: f64) -> f64 {
        self.true_mu() * (1.0 + noise * rng.normal()).max(0.1)
    }

    /// True β [s per unit-rank LoRA layer].
    pub fn true_beta(&self, unit_rank_bytes: usize) -> f64 {
        self.net.beta(unit_rank_bytes)
    }

    pub fn measured_beta(&self, unit_rank_bytes: usize, rng: &mut Rng,
                         noise: f64) -> f64 {
        self.true_beta(unit_rank_bytes) * (1.0 + noise * rng.normal()).max(0.1)
    }
}

/// The simulated population.
#[derive(Debug, Clone)]
pub struct Fleet {
    pub devices: Vec<Device>,
    pub config: FleetConfig,
    rng: Rng,
    round: usize,
}

impl Fleet {
    pub fn new(config: FleetConfig) -> Fleet {
        let mut rng = Rng::new(config.seed).child("fleet");
        let mut classes = Vec::with_capacity(config.total());
        classes.extend(std::iter::repeat(DeviceClass::Tx2).take(config.n_tx2));
        classes.extend(std::iter::repeat(DeviceClass::Nx).take(config.n_nx));
        classes.extend(std::iter::repeat(DeviceClass::Agx).take(config.n_agx));
        // Randomly shuffle devices into WiFi groups (§6.1).
        rng.shuffle(&mut classes);
        let n = classes.len();
        let devices = classes
            .into_iter()
            .enumerate()
            .map(|(id, class)| {
                let mode = rng.range(0, class.n_modes());
                // Equal-size groups: 4 groups of n/4 (paper: 4 × 20).
                let group = (id * 4) / n.max(1);
                Device {
                    id,
                    compute: ComputeProfile::new(class, mode),
                    net: NetworkModel::new(group.min(3), &mut rng),
                }
            })
            .collect();
        Fleet { devices, config, rng, round: 0 }
    }

    pub fn len(&self) -> usize {
        self.devices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.devices.is_empty()
    }

    /// Advance to the next round: WiFi fading every round, DVFS mode
    /// resample every `mode_reshuffle_rounds`.
    pub fn advance_round(&mut self) {
        self.round += 1;
        let reshuffle = self.config.mode_reshuffle_rounds > 0
            && self.round % self.config.mode_reshuffle_rounds == 0;
        for d in &mut self.devices {
            d.net.step(&mut self.rng);
            if reshuffle {
                let m = d.compute.class.n_modes();
                d.compute.mode = self.rng.range(0, m);
            }
        }
    }

    /// Noisy status report (μ̂, β̂) for device `i` this round.
    pub fn observe(&mut self, i: usize, unit_rank_bytes: usize)
                   -> (f64, f64) {
        let noise = self.config.obs_noise;
        let d = &self.devices[i];
        let mu = d.true_mu() * (1.0 + noise * self.rng.normal()).max(0.1);
        let beta = d.true_beta(unit_rank_bytes)
            * (1.0 + noise * self.rng.normal()).max(0.1);
        (mu, beta)
    }

    /// Table 1-style description (used by `legend fleet --describe`).
    pub fn describe(&self) -> String {
        let mut out = String::new();
        out.push_str(
            "class              count  AI perf      GPU              modes\n");
        for class in DeviceClass::ALL {
            let count =
                self.devices.iter().filter(|d| d.compute.class == class)
                    .count();
            out.push_str(&format!(
                "{:<18} {:>5}  {:<11} {:<16} {}\n",
                class.name(),
                count,
                match class {
                    DeviceClass::Tx2 => "1.33 TFLOPS",
                    DeviceClass::Nx => "21 TOPS",
                    DeviceClass::Agx => "22 TOPS",
                },
                class.gpu(),
                class.n_modes(),
            ));
        }
        let mus: Vec<f64> =
            self.devices.iter().map(|d| d.true_mu()).collect();
        let (mn, mx) = (
            mus.iter().cloned().fold(f64::MAX, f64::min),
            mus.iter().cloned().fold(0.0, f64::max),
        );
        out.push_str(&format!(
            "μ spread: {:.1} ms .. {:.1} ms ({:.0}×)\n",
            mn * 1e3,
            mx * 1e3,
            mx / mn
        ));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_fleet_composition() {
        let f = Fleet::new(FleetConfig::paper());
        assert_eq!(f.len(), 80);
        let count = |c: DeviceClass| {
            f.devices.iter().filter(|d| d.compute.class == c).count()
        };
        assert_eq!(count(DeviceClass::Tx2), 30);
        assert_eq!(count(DeviceClass::Nx), 40);
        assert_eq!(count(DeviceClass::Agx), 10);
        // Four equal groups.
        for g in 0..4 {
            assert_eq!(
                f.devices.iter().filter(|d| d.net.group == g).count(),
                20
            );
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = Fleet::new(FleetConfig::paper());
        let b = Fleet::new(FleetConfig::paper());
        for (x, y) in a.devices.iter().zip(&b.devices) {
            assert_eq!(x.compute.class, y.compute.class);
            assert_eq!(x.compute.mode, y.compute.mode);
        }
    }

    #[test]
    fn modes_reshuffle_on_schedule() {
        let mut f = Fleet::new(FleetConfig::paper());
        let before: Vec<usize> =
            f.devices.iter().map(|d| d.compute.mode).collect();
        for _ in 0..19 {
            f.advance_round();
        }
        let mid: Vec<usize> =
            f.devices.iter().map(|d| d.compute.mode).collect();
        assert_eq!(before, mid, "modes must hold for 19 rounds");
        f.advance_round(); // round 20 → reshuffle
        let after: Vec<usize> =
            f.devices.iter().map(|d| d.compute.mode).collect();
        assert_ne!(before, after, "modes must reshuffle at round 20");
    }

    #[test]
    fn observation_noise_centered_on_truth() {
        let mut f = Fleet::new(FleetConfig::pretest());
        let truth = f.devices[0].true_mu();
        let n = 2000;
        let mean: f64 = (0..n)
            .map(|_| f.observe(0, 1024).0)
            .sum::<f64>()
            / n as f64;
        assert!(
            (mean / truth - 1.0).abs() < 0.02,
            "mean {mean} vs truth {truth}"
        );
    }

    #[test]
    fn sized_fleet_has_requested_total() {
        for n in [10, 16, 40, 80] {
            assert_eq!(Fleet::new(FleetConfig::sized(n)).len(), n);
        }
    }
}
