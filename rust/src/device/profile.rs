//! Per-device compute model, calibrated to the paper's measurements.
//!
//! Table 1 gives the device classes; §6.1 states the spread between
//! the fastest (AGX mode 0) and slowest (TX2 lowest mode) reaches
//! ~100×. Fig. 4 calibrates the absolute scale: on the reference
//! device each additional LoRA layer costs ≈5 ms per batch (backprop)
//! and ≈107 MB of memory, and depth 12 vs depth 1 is a 252% latency
//! increase — which pins forward ≈ backward-per-layer ratios.

/// Jetson device class (Table 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DeviceClass {
    Tx2,
    Nx,
    Agx,
}

impl DeviceClass {
    pub const ALL: [DeviceClass; 3] =
        [DeviceClass::Tx2, DeviceClass::Nx, DeviceClass::Agx];

    pub fn name(self) -> &'static str {
        match self {
            DeviceClass::Tx2 => "Jetson TX2",
            DeviceClass::Nx => "Jetson NX",
            DeviceClass::Agx => "Jetson AGX Xavier",
        }
    }

    /// Relative AI performance (Table 1: 1.33 TFLOPS / 21 TOPS /
    /// 22 TOPS), normalized to AGX = 1.0.
    pub fn rel_perf(self) -> f64 {
        match self {
            DeviceClass::Tx2 => 1.33 / 22.0,
            DeviceClass::Nx => 21.0 / 22.0,
            DeviceClass::Agx => 1.0,
        }
    }

    /// Number of configurable DVFS power modes (§6.1: TX2 has 4,
    /// NX/AGX have 8).
    pub fn n_modes(self) -> usize {
        match self {
            DeviceClass::Tx2 => 4,
            DeviceClass::Nx => 8,
            DeviceClass::Agx => 8,
        }
    }

    pub fn gpu(self) -> &'static str {
        match self {
            DeviceClass::Tx2 => "256-core Pascal",
            DeviceClass::Nx => "384-core Volta",
            DeviceClass::Agx => "512-core Volta",
        }
    }

    pub fn cpu(self) -> &'static str {
        match self {
            DeviceClass::Tx2 => "Denver 2 and ARM 4",
            DeviceClass::Nx => "6-core Carmel ARM 8",
            DeviceClass::Agx => "8-core Carmel ARM 8",
        }
    }

    pub fn rom(self) -> &'static str {
        match self {
            DeviceClass::Tx2 => "8 GB LPDDR4",
            DeviceClass::Nx => "8 GB LPDDR4x",
            DeviceClass::Agx => "32 GB LPDDR4x",
        }
    }
}

/// Calibration constants (DESIGN.md §3, from Fig. 4).
pub mod calib {
    /// Per-LoRA-layer backprop time on AGX mode 0 [s] (Fig. 4a: ≈5 ms
    /// per extra layer).
    pub const MU_REF_S: f64 = 0.005;
    /// Forward-pass time per transformer layer relative to one layer's
    /// backprop μ. Depth 1 → 12 is a 252% latency increase (Fig. 4a):
    /// lat(k) = L·fwd + k·μ; (12f·L? ) solving 12μ·? — with L=12,
    /// (FWD·12 + 12μ)/(FWD·12 + μ) = 3.52 → FWD ≈ 0.26·μ.
    pub const FWD_FRAC: f64 = 0.26;
    /// Memory per additional LoRA layer [MB] (Fig. 4b).
    pub const MEM_PER_LAYER_MB: f64 = 107.0;
    /// Baseline memory (frozen model + activations) [MB]; Fig. 4b's
    /// depth-12 total is 221% over depth-1, pinning the base.
    pub const MEM_BASE_MB: f64 = 530.0;
    /// Slowest-mode slowdown factor (so AGX mode 0 vs TX2 lowest mode
    /// reaches the ~100× the paper reports: 16.5× class × 6× mode).
    pub const MODE_SPREAD: f64 = 6.0;
}

/// Per-device compute state: class + current DVFS mode.
#[derive(Debug, Clone)]
pub struct ComputeProfile {
    pub class: DeviceClass,
    pub mode: usize,
}

impl ComputeProfile {
    pub fn new(class: DeviceClass, mode: usize) -> Self {
        assert!(mode < class.n_modes(), "mode {mode} out of range");
        ComputeProfile { class, mode }
    }

    /// Slowdown multiplier of the current DVFS mode (mode 0 = 1.0,
    /// highest mode = `MODE_SPREAD`), geometric interpolation.
    pub fn mode_factor(&self) -> f64 {
        let m = self.class.n_modes();
        if m <= 1 {
            return 1.0;
        }
        calib::MODE_SPREAD.powf(self.mode as f64 / (m - 1) as f64)
    }

    /// μ: time to backprop one transformer layer's LoRA for ONE batch
    /// [s] (eq. 12's per-layer unit).
    pub fn mu(&self) -> f64 {
        calib::MU_REF_S / self.class.rel_perf() * self.mode_factor()
    }

    /// t̂: forward-pass time for ONE batch through all `n_layers` [s].
    pub fn forward_time(&self, n_layers: usize) -> f64 {
        calib::FWD_FRAC * self.mu() * n_layers as f64
    }

    /// Per-batch fine-tuning latency at LoRA depth `k` [s] — the
    /// quantity Fig. 4(a) plots.
    pub fn batch_latency(&self, n_layers: usize, k: usize) -> f64 {
        self.forward_time(n_layers) + k as f64 * self.mu()
    }

    /// Peak fine-tuning memory at LoRA depth `k` [MB] (Fig. 4b).
    pub fn memory_mb(k: usize) -> f64 {
        calib::MEM_BASE_MB + k as f64 * calib::MEM_PER_LAYER_MB
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn class_ratios_match_table1() {
        assert!(DeviceClass::Agx.rel_perf() > DeviceClass::Nx.rel_perf());
        assert!(
            DeviceClass::Nx.rel_perf() / DeviceClass::Tx2.rel_perf() > 10.0
        );
    }

    #[test]
    fn hundredfold_spread_between_extremes() {
        let fast = ComputeProfile::new(DeviceClass::Agx, 0);
        let slow = ComputeProfile::new(
            DeviceClass::Tx2,
            DeviceClass::Tx2.n_modes() - 1,
        );
        let ratio = slow.mu() / fast.mu();
        assert!(
            (50.0..200.0).contains(&ratio),
            "spread {ratio} should be ~100x (paper §6.1)"
        );
    }

    #[test]
    fn latency_linear_in_depth_with_5ms_slope() {
        let p = ComputeProfile::new(DeviceClass::Agx, 0);
        let l1 = p.batch_latency(12, 1);
        let l12 = p.batch_latency(12, 12);
        let slope = (l12 - l1) / 11.0;
        assert!((slope - 0.005).abs() < 1e-9, "slope {slope}");
        // Fig. 4a: depth 12 ≈ 252% over depth 1.
        let inc = (l12 - l1) / l1;
        assert!((2.0..4.5).contains(&inc), "increase {inc}");
    }

    #[test]
    fn memory_matches_fig4b() {
        let m1 = ComputeProfile::memory_mb(1);
        let m12 = ComputeProfile::memory_mb(12);
        assert!((m12 - m1 - 11.0 * 107.0).abs() < 1e-9);
        // Fig. 4b: ~221% growth from depth 1 to 12.
        let growth = (m12 - m1) / m1;
        assert!((1.5..2.5).contains(&growth), "growth {growth}");
    }

    #[test]
    fn mode_factor_monotone() {
        for class in DeviceClass::ALL {
            let mut last = 0.0;
            for m in 0..class.n_modes() {
                let f = ComputeProfile::new(class, m).mode_factor();
                assert!(f > last);
                last = f;
            }
        }
    }
}
