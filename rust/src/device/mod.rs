//! Heterogeneous device simulation.
//!
//! The paper's testbed is 80 NVIDIA Jetson kits (30 TX2 / 40 NX / 10
//! AGX, Table 1) with DVFS modes reshuffled every 20 rounds and WiFi
//! links whose bandwidth fluctuates between 1 and 30 Mb/s (§6.1).
//! Offline we reproduce that testbed as a calibrated simulator
//! (DESIGN.md §2–3): [`profile`] models per-device compute (μ, t̂ of
//! eq. 12), [`network`] models the WiFi uplink (β of eq. 12), and
//! [`fleet`] assembles the 80-device population. Gradient *math* runs
//! for real through the PJRT runtime; *time* comes from here.

pub mod fleet;
pub mod network;
pub mod profile;

pub use fleet::{Device, Fleet, FleetConfig, FleetView, LazyFleet};
pub use network::NetworkModel;
pub use profile::{ComputeProfile, DeviceClass};
