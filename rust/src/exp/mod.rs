//! Experiment harness: regenerates every table/figure in the paper's
//! evaluation (DESIGN.md §4 maps them). Each `figN` runner produces
//! `results/figN*.csv` plus a printed summary with the same rows the
//! paper reports.

pub mod figures;
pub mod report;

use anyhow::{anyhow, Result};

use crate::coordinator::participation::{Full, Participation};
use crate::coordinator::strategy::{self, Strategy};
use crate::coordinator::trainer::PjrtTrainer;
use crate::coordinator::{
    run_federated_with, FedConfig, JobScheduler, JobSpec, ModelMeta,
    MultiJobReport, RateLimit,
};
use crate::data::Spec;
use crate::device::{Fleet, FleetConfig, FleetView, LazyFleet};
use crate::metrics::RunRecord;
use crate::model::state::{init_trainable, TensorMap};
use crate::runtime::Runtime;
use crate::util::rng::Rng;

/// Shared environment: runtime + grammar spec + model meta.
pub struct ExpEnv {
    pub rt: Runtime,
    pub spec: Spec,
    pub meta: ModelMeta,
    pub artifacts_dir: String,
}

impl ExpEnv {
    pub fn load(artifacts_dir: &str) -> Result<ExpEnv> {
        let rt = Runtime::load(artifacts_dir)?;
        let spec = Spec::load(&format!("{artifacts_dir}/vocab.json"))
            .map_err(|e| anyhow!("{e}"))?;
        let meta = ModelMeta::from_manifest(&rt.manifest);
        Ok(ExpEnv {
            rt,
            spec,
            meta,
            artifacts_dir: artifacts_dir.to_string(),
        })
    }

    /// Fresh global trainable state for a family (same init per seed,
    /// so methods start from identical models).
    pub fn fresh_global(&self, family: &str, seed: u64) -> TensorMap {
        let mut rng = Rng::new(seed).child("global-init");
        init_trainable(&self.rt.manifest, self.rt.manifest.family(family),
                       &mut rng)
    }

    /// Run one (strategy, task) experiment with the real PJRT trainer
    /// (full participation, the paper's setting).
    pub fn run_strategy(&self, strategy: &mut dyn Strategy,
                        cfg: &FedConfig, fleet_cfg: &FleetConfig)
                        -> Result<RunRecord> {
        self.run_strategy_with(strategy, cfg, fleet_cfg, &mut Full)
    }

    /// Same, with an explicit participation policy.
    pub fn run_strategy_with(&self, strategy: &mut dyn Strategy,
                             cfg: &FedConfig, fleet_cfg: &FleetConfig,
                             participation: &mut dyn Participation)
                             -> Result<RunRecord> {
        let family: &'static str = match strategy.family() {
            "adapter" => "adapter",
            _ => "lora",
        };
        // Lazy fleets derive devices on demand — bit-identical to the
        // eager build (property-tested), but O(cohort) memory.
        let fc = FleetConfig { seed: cfg.seed, ..fleet_cfg.clone() };
        let mut fleet: Box<dyn FleetView> = if cfg.lazy_fleet {
            Box::new(LazyFleet::new(fc))
        } else {
            Box::new(Fleet::new(fc))
        };
        let mut trainer = PjrtTrainer::new(&self.rt, family, cfg.seed);
        let global = self.fresh_global(family, cfg.seed);
        run_federated_with(cfg, fleet.as_mut(), strategy, &mut trainer,
                           &self.meta, &self.spec, global, participation)
    }

    /// Run a named method (CLI entry).
    pub fn run_method(&self, method: &str, cfg: &FedConfig,
                      fleet_cfg: &FleetConfig) -> Result<RunRecord> {
        self.run_method_with(method, cfg, fleet_cfg, &mut Full)
    }

    /// Run a named method under a participation policy (CLI entry).
    pub fn run_method_with(&self, method: &str, cfg: &FedConfig,
                           fleet_cfg: &FleetConfig,
                           participation: &mut dyn Participation)
                           -> Result<RunRecord> {
        let mut s = strategy::by_name(
            method,
            self.meta.n_layers,
            self.meta.r_max,
            self.meta.w_max,
        )
        .ok_or_else(|| anyhow!("unknown method {method:?}"))?;
        self.run_strategy_with(s.as_mut(), cfg, fleet_cfg, participation)
    }

    /// Run a named method as `n_jobs` concurrent tenants of one shared
    /// fleet via the multi-job scheduler (docs/MULTIJOB.md). Job `j`
    /// clones the base config with `seed = base.seed + j`, so tenants
    /// differ while the whole run stays a pure function of the base
    /// seed. `rate > 0` gives every job an ingest token bucket with
    /// `burst = refill = rate`; `parts` supplies one participation
    /// policy per job (length must equal `n_jobs`).
    pub fn run_method_multi(&self, method: &str, base: &FedConfig,
                            fleet_cfg: &FleetConfig, n_jobs: usize,
                            rate: usize,
                            parts: Vec<Box<dyn Participation>>)
                            -> Result<MultiJobReport> {
        if parts.len() != n_jobs {
            return Err(anyhow!(
                "need {n_jobs} participation policies, got {}",
                parts.len()
            ));
        }
        let mut sched = JobScheduler::new(
            self.meta.clone(),
            self.spec.clone(),
            fleet_cfg.total(),
        );
        for (j, part) in parts.into_iter().enumerate() {
            let mut cfg = base.clone();
            cfg.seed = base.seed + j as u64;
            let s = strategy::by_name(
                method,
                self.meta.n_layers,
                self.meta.r_max,
                self.meta.w_max,
            )
            .ok_or_else(|| anyhow!("unknown method {method:?}"))?;
            let family: &'static str = match s.family() {
                "adapter" => "adapter",
                _ => "lora",
            };
            let trainer = PjrtTrainer::new(&self.rt, family, cfg.seed);
            let global = self.fresh_global(family, cfg.seed);
            let mut spec = JobSpec::new(cfg);
            if rate > 0 {
                spec.rate = Some(RateLimit { burst: rate, refill: rate });
            }
            sched
                .admit(spec, s, Box::new(trainer), part, global)
                .map_err(|e| anyhow!("job {j} rejected: {e}"))?;
        }
        // All tenants share one fleet, seeded by the base config so the
        // device population is independent of the job count.
        let fc = FleetConfig { seed: base.seed, ..fleet_cfg.clone() };
        let mut fleet: Box<dyn FleetView> = if base.lazy_fleet {
            Box::new(LazyFleet::new(fc))
        } else {
            Box::new(Fleet::new(fc))
        };
        sched.run(fleet.as_mut())
    }
}

/// The paper's "target accuracy" convention (§6.1 Metrics): the
/// minimum best-accuracy across the compared methods.
pub fn shared_target(runs: &[RunRecord]) -> f64 {
    runs.iter()
        .map(|r| r.best_accuracy())
        .fold(f64::MAX, f64::min)
        .min(1.0)
        * 0.995 // tolerance so the weakest method itself crosses it
}

/// Speedup table vs the slowest method (Fig. 8's "N×" annotations).
pub fn speedups(runs: &[RunRecord], target: f64) -> Vec<(String, f64)> {
    let times: Vec<(String, Option<f64>)> = runs
        .iter()
        .map(|r| (r.method.clone(), r.time_to_accuracy(target)))
        .collect();
    let worst = times
        .iter()
        .filter_map(|(_, t)| *t)
        .fold(0.0f64, f64::max);
    times
        .into_iter()
        .map(|(m, t)| (m, t.map(|t| worst / t).unwrap_or(f64::NAN)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::RoundRecord;

    fn rec(method: &str, accs: &[f64]) -> RunRecord {
        let mut r = RunRecord::new(method, "t");
        for (i, &a) in accs.iter().enumerate() {
            r.rounds.push(RoundRecord {
                round: i,
                sim_time: (i + 1) as f64 * 10.0,
                test_acc: a,
                ..Default::default()
            });
        }
        r
    }

    #[test]
    fn shared_target_is_min_of_best() {
        let runs =
            vec![rec("a", &[0.5, 0.9]), rec("b", &[0.4, 0.7, 0.6])];
        let t = shared_target(&runs);
        assert!(t <= 0.7 && t > 0.69);
    }

    #[test]
    fn speedups_relative_to_slowest() {
        let fast = rec("fast", &[0.8, 0.9]);
        let slow = rec("slow", &[0.1, 0.2, 0.5, 0.8]);
        // fast crosses 0.75 at t=10, slow at t=40 → 4× and 1×.
        let s = speedups(&[fast, slow], 0.75);
        assert_eq!(s[0].0, "fast");
        assert!((s[0].1 - 4.0).abs() < 1e-9, "{:?}", s);
        assert!((s[1].1 - 1.0).abs() < 1e-9);
    }
}
