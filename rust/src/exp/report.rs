//! Markdown report generation from results/ CSVs.
//!
//! `legend exp` writes raw per-round CSVs; this module re-reads them
//! and produces the paper-style comparison tables (speedup ×, traffic
//! savings %, waiting reduction %) that EXPERIMENTS.md quotes —
//! regenerate with `legend report`.

use std::fmt::Write as _;

use anyhow::{anyhow, Result};

use crate::metrics::{RoundRecord, RunRecord};

use super::{shared_target, speedups};

/// Parse a results CSV written by `metrics::write_csv` back into runs.
pub fn parse_csv(text: &str) -> Result<Vec<RunRecord>> {
    let mut lines = text.lines();
    let header = lines.next().ok_or_else(|| anyhow!("empty csv"))?;
    let cols: Vec<&str> = header.split(',').collect();
    let idx = |name: &str| -> Result<usize> {
        cols.iter()
            .position(|c| *c == name)
            .ok_or_else(|| anyhow!("missing column {name}"))
    };
    let (im, it, ir, ist, irt, iw, iu, id_, itl, ia, itsl, imd) = (
        idx("method")?,
        idx("task")?,
        idx("round")?,
        idx("sim_time")?,
        idx("round_time")?,
        idx("avg_waiting")?,
        idx("up_bytes")?,
        idx("down_bytes")?,
        idx("train_loss")?,
        idx("test_acc")?,
        idx("test_loss")?,
        idx("mean_depth")?,
    );
    // Participation columns are optional: CSVs written before the
    // RoundEngine predate them (0 = unknown).
    let opt_col = |name: &str| cols.iter().position(|c| *c == name);
    let (ip, idp) = (opt_col("participants"), opt_col("dropped"));
    let mut runs: Vec<RunRecord> = Vec::new();
    for (ln, line) in lines.enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let f: Vec<&str> = line.split(',').collect();
        if f.len() != cols.len() {
            return Err(anyhow!("line {}: {} fields", ln + 2, f.len()));
        }
        let parse_f = |i: usize| -> Result<f64> {
            f[i].parse()
                .map_err(|e| anyhow!("line {}: {e}", ln + 2))
        };
        let rec = RoundRecord {
            round: parse_f(ir)? as usize,
            sim_time: parse_f(ist)?,
            round_time: parse_f(irt)?,
            avg_waiting: parse_f(iw)?,
            up_bytes: parse_f(iu)? as usize,
            down_bytes: parse_f(id_)? as usize,
            train_loss: parse_f(itl)?,
            test_acc: parse_f(ia)?,
            test_loss: parse_f(itsl)?,
            mean_depth: parse_f(imd)?,
            // Absent column → 0 (pre-engine CSV); present but
            // malformed → error, like every other column.
            participants: match ip {
                None => 0,
                Some(i) => f[i].parse().map_err(|e| {
                    anyhow!("line {}: {e}", ln + 2)
                })?,
            },
            dropped: match idp {
                None => 0,
                Some(i) => f[i].parse().map_err(|e| {
                    anyhow!("line {}: {e}", ln + 2)
                })?,
            },
        };
        let (method, task) = (f[im], f[it]);
        match runs
            .iter_mut()
            .find(|r| r.method == method && r.task == task)
        {
            Some(r) => r.rounds.push(rec),
            None => {
                let mut r = RunRecord::new(method, task);
                r.rounds.push(rec);
                runs.push(r);
            }
        }
    }
    Ok(runs)
}

/// Paper-style comparison block for one experiment's runs, with the
/// first run (conventionally LEGEND) as the reference.
pub fn comparison_markdown(title: &str, runs: &[RunRecord]) -> String {
    let mut out = String::new();
    let target = shared_target(runs);
    let _ = writeln!(out, "### {title} (target acc {target:.3})\n");
    let _ = writeln!(
        out,
        "| method | final acc | t→target | speedup | traffic→target | \
         saved | wait avg | reduced |"
    );
    let _ = writeln!(out, "|---|---|---|---|---|---|---|---|");
    let sp = speedups(runs, target);
    let ref_run = &runs[0];
    let (rt, rb, rw) = (
        ref_run.time_to_accuracy(target),
        ref_run.traffic_to_accuracy(target),
        ref_run.mean_waiting(),
    );
    let _ = (rt, rb, rw);
    for (r, (_, speed)) in runs.iter().zip(&sp) {
        let t = r.time_to_accuracy(target);
        let b = r.traffic_to_accuracy(target);
        let w = r.mean_waiting();
        // Savings vs THIS method from the reference (first) run.
        let saved = match (ref_run.traffic_to_accuracy(target), b) {
            (Some(rb), Some(b)) if b > 0 => {
                format!("{:+.1}%", (1.0 - rb as f64 / b as f64) * -100.0)
            }
            _ => "—".into(),
        };
        let reduced = if w > 0.0 {
            format!("{:+.1}%", (1.0 - ref_run.mean_waiting() / w) * -100.0)
        } else {
            "—".into()
        };
        let _ = writeln!(
            out,
            "| {} | {:.3} | {} | {:.2}× | {} | {} | {:.1}s | {} |",
            r.method,
            r.best_accuracy(),
            t.map(|t| format!("{t:.0}s")).unwrap_or("—".into()),
            speed,
            b.map(|b| format!("{:.1}MB", b as f64 / 1e6))
                .unwrap_or("—".into()),
            saved,
            w,
            reduced,
        );
    }
    out
}

/// Build the full markdown report from every CSV under `dir`.
pub fn build_report(dir: &str) -> Result<String> {
    let mut out = String::from("# Experiment report (generated)\n\n");
    let mut paths: Vec<_> = std::fs::read_dir(dir)?
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.extension().map(|x| x == "csv").unwrap_or(false))
        .collect();
    paths.sort();
    for p in paths {
        let text = std::fs::read_to_string(&p)?;
        let runs = parse_csv(&text)?;
        if runs.is_empty() {
            continue;
        }
        let title = p
            .file_stem()
            .and_then(|s| s.to_str())
            .unwrap_or("experiment");
        out.push_str(&comparison_markdown(title, &runs));
        out.push('\n');
        out.push_str("```\n");
        out.push_str(&crate::metrics::plot::accuracy_plot(&runs, 64, 12));
        out.push_str("```\n\n");
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::write_csv;

    fn sample_runs() -> Vec<RunRecord> {
        let mut a = RunRecord::new("LEGEND", "sst2");
        let mut b = RunRecord::new("FedLoRA", "sst2");
        for i in 0..5 {
            a.rounds.push(RoundRecord {
                round: i,
                sim_time: (i + 1) as f64 * 10.0,
                round_time: 10.0,
                avg_waiting: 2.0,
                up_bytes: 100,
                down_bytes: 100,
                train_loss: 1.0 / (i + 1) as f64,
                test_acc: 0.2 * (i + 1) as f64,
                test_loss: 1.0,
                mean_depth: 8.0,
                participants: 10,
                dropped: 0,
            });
            b.rounds.push(RoundRecord {
                round: i,
                sim_time: (i + 1) as f64 * 25.0,
                round_time: 25.0,
                avg_waiting: 8.0,
                up_bytes: 300,
                down_bytes: 300,
                train_loss: 1.2 / (i + 1) as f64,
                test_acc: 0.18 * (i + 1) as f64,
                test_loss: 1.0,
                mean_depth: 12.0,
                participants: 10,
                dropped: 0,
            });
        }
        vec![a, b]
    }

    #[test]
    fn csv_roundtrip_through_parser() {
        let runs = sample_runs();
        let path = write_csv("test_report_roundtrip", &runs).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let parsed = parse_csv(&text).unwrap();
        assert_eq!(parsed.len(), 2);
        assert_eq!(parsed[0].method, "LEGEND");
        assert_eq!(parsed[0].rounds.len(), 5);
        assert!((parsed[0].rounds[2].sim_time - 30.0).abs() < 1e-9);
        assert_eq!(parsed[1].rounds[4].up_bytes, 300);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn markdown_contains_speedup_row() {
        let runs = sample_runs();
        let md = comparison_markdown("unit", &runs);
        assert!(md.contains("| LEGEND |"));
        assert!(md.contains("| FedLoRA |"));
        assert!(md.contains('×'));
    }

    #[test]
    fn rejects_malformed_csv() {
        assert!(parse_csv("not,a,header\n1,2").is_err());
        assert!(parse_csv("").is_err());
    }
}
