//! Per-figure experiment runners (DESIGN.md §4 experiment index).
//!
//! Scaling note: the paper's testbed is 80 Jetsons; this simulator
//! runs every gradient for real on ONE cpu core, so the default fleet
//! is 16 devices with the paper's 3:4:1 class mix and shortened
//! epochs. Pass `--devices 80` to `legend exp` to reproduce at the
//! paper's population size (the virtual-clock metrics are computed
//! identically either way).

use anyhow::{anyhow, Result};

use crate::coordinator::strategy::{FixedLayers, FixedRankDist, Strategy};
use crate::coordinator::FedConfig;
use crate::device::profile::{ComputeProfile, DeviceClass};
use crate::device::FleetConfig;
use crate::metrics::{self, RunRecord};
use crate::model::masks::LayerSet;

use super::{shared_target, speedups, ExpEnv};

/// Harness options from the CLI.
#[derive(Debug, Clone)]
pub struct Options {
    pub devices: usize,
    /// 0 → per-figure default.
    pub rounds: usize,
    /// Shrink everything for a smoke pass.
    pub quick: bool,
    pub seed: u64,
}

impl Default for Options {
    fn default() -> Self {
        Options { devices: 10, rounds: 0, quick: false, seed: 1 }
    }
}

impl Options {
    fn rounds_or(&self, default: usize) -> usize {
        if self.rounds > 0 {
            self.rounds
        } else if self.quick {
            (default / 4).max(2)
        } else {
            default
        }
    }

    fn fleet(&self, n: usize) -> FleetConfig {
        FleetConfig { seed: self.seed, ..FleetConfig::sized(n) }
    }

    fn cfg(&self, task: &str, rounds: usize) -> FedConfig {
        let (train, test) = if self.quick {
            (256, 64)
        } else {
            match task {
                "qqp" | "mnli" => (1024, 256),
                "mmlu" | "gsm" => (768, 256),
                _ => (1024, 256),
            }
        };
        FedConfig {
            task: task.into(),
            rounds,
            train_size: train,
            test_size: test,
            alpha: if matches!(task, "mmlu" | "gsm") { -1.0 } else { 10.0 },
            max_batches: if self.quick { 2 } else { 6 },
            seed: self.seed,
            verbose: true,
            ..Default::default()
        }
    }
}

pub fn run_one(env: &ExpEnv, fig: &str, opts: &Options) -> Result<()> {
    match fig {
        "fig3" => fig3_position(env, opts),
        "fig4" => fig4_depth(env, opts),
        "fig5" => fig5_rankdist(env, opts),
        "fig7" | "fig8" | "fig11" | "fig12" => fig7_main(env, opts),
        "fig9" => fig9_mmlu(env, opts),
        "fig10" => fig10_gsm(env, opts),
        "fig13" => fig13_ablation(env, opts),
        other => Err(anyhow!("unknown figure {other:?}")),
    }
}

pub fn run_all(env: &ExpEnv, opts: &Options) -> Result<()> {
    for fig in ["fig7", "fig13", "fig9", "fig10", "fig3", "fig4", "fig5"] {
        println!("\n================ {fig} ================");
        run_one(env, fig, opts)?;
    }
    Ok(())
}

fn finish(name: &str, runs: &[RunRecord]) -> Result<()> {
    let target = shared_target(runs);
    let path = metrics::write_csv(name, runs)?;
    println!("\n--- {name} (target acc {target:.3}) ---");
    print!("{}", metrics::summary_table(runs, target));
    for (m, s) in speedups(runs, target) {
        println!("  speedup[{m}] = {s:.2}×");
    }
    println!("wrote {path}");
    Ok(())
}

// ---------------------------------------------------------------------------
// §2 pre-tests
// ---------------------------------------------------------------------------

/// Fig. 3 — importance of LoRA position: Layers-A/S/M/D on SST-2 with
/// 10 devices.
fn fig3_position(env: &ExpEnv, opts: &Options) -> Result<()> {
    let l = env.meta.n_layers;
    let third = l / 3;
    let variants: Vec<(&str, LayerSet)> = vec![
        ("Layers-A", LayerSet::All),
        ("Layers-S", LayerSet::Explicit((0..third).collect())),
        ("Layers-M", LayerSet::Explicit((third..2 * third).collect())),
        ("Layers-D", LayerSet::Depth(third)),
    ];
    let cfg = opts.cfg("sst2", opts.rounds_or(14));
    let fleet = opts.fleet(10); // §2.2: 10-device pre-test
    let mut runs = Vec::new();
    for (label, layers) in variants {
        let mut s = FixedLayers {
            label: label.into(),
            layers,
            rank: 8,
        };
        runs.push(env.run_strategy(&mut s, &cfg, &fleet)?);
    }
    finish("fig3_position", &runs)
}

/// Fig. 4 — importance of LoRA depth: accuracy + per-batch latency +
/// memory for depths 1..L.
fn fig4_depth(env: &ExpEnv, opts: &Options) -> Result<()> {
    let depths: Vec<usize> = if opts.quick {
        vec![1, 6, 12]
    } else {
        vec![1, 2, 3, 6, 9, 12]
    };
    let cfg = opts.cfg("sst2", opts.rounds_or(10));
    let fleet = opts.fleet(10);
    let mut runs = Vec::new();
    println!("depth  latency_ms  memory_MB   (cost model, AGX mode 0)");
    let agx = ComputeProfile::new(DeviceClass::Agx, 0);
    for &k in &depths {
        println!(
            "{:>5}  {:>10.1}  {:>9.0}",
            k,
            agx.batch_latency(env.meta.n_layers, k) * 1e3,
            ComputeProfile::memory_mb(k)
        );
        let mut s = FixedLayers {
            label: format!("Depth-{k}"),
            layers: LayerSet::Depth(k),
            rank: 8,
        };
        runs.push(env.run_strategy(&mut s, &cfg, &fleet)?);
    }
    finish("fig4_depth", &runs)
}

/// Fig. 5 — rank distribution: (a) which position benefits from extra
/// rank; (b) Uniform vs Inc vs Dec under a similar total budget.
fn fig5_rankdist(env: &ExpEnv, opts: &Options) -> Result<()> {
    let l = env.meta.n_layers;
    let r_max = env.meta.r_max;
    let cfg = opts.cfg("sst2", opts.rounds_or(10));
    let fleet = opts.fleet(10);

    // (a) rank gain per position: r=8 → r=16 on S/M/D/A.
    if !opts.quick {
        let third = l / 3;
        let positions: Vec<(&str, LayerSet)> = vec![
            ("Layers-A", LayerSet::All),
            ("Layers-S", LayerSet::Explicit((0..third).collect())),
            ("Layers-M", LayerSet::Explicit((third..2 * third).collect())),
            ("Layers-D", LayerSet::Depth(third)),
        ];
        let mut runs = Vec::new();
        for (label, layers) in positions {
            for rank in [8usize, 16] {
                let mut s = FixedLayers {
                    label: format!("{label}-r{rank}"),
                    layers: layers.clone(),
                    rank,
                };
                runs.push(env.run_strategy(&mut s, &cfg, &fleet)?);
            }
        }
        // Print the per-position gain the paper reports.
        println!("\nrank 8 → 16 accuracy gain per position:");
        for pair in runs.chunks(2) {
            println!(
                "  {:<12} {:+.4}",
                pair[0].method.trim_end_matches("-r8"),
                pair[1].best_accuracy() - pair[0].best_accuracy()
            );
        }
        finish("fig5a_rankgain", &runs)?;
    }

    // (b) Uniform / Inc / Dec under ≈equal total rank.
    let mut runs = Vec::new();
    let variants: Vec<FixedRankDist> = vec![
        FixedRankDist::uniform(l, 6),         // 72 total
        FixedRankDist::increasing(l, r_max),  // 78 total
        FixedRankDist::decreasing(l, r_max),  // 78 total
    ];
    for mut v in variants {
        runs.push(env.run_strategy(&mut v, &cfg, &fleet)?);
    }
    finish("fig5b_distributions", &runs)
}

// ---------------------------------------------------------------------------
// §6.2 main results
// ---------------------------------------------------------------------------

const METHODS: [&str; 4] = ["legend", "fedadapter", "hetlora", "fedlora"];

fn methods_on_tasks(env: &ExpEnv, opts: &Options, tasks: &[&str],
                    rounds: usize, stem: &str) -> Result<()> {
    for task in tasks {
        let cfg = opts.cfg(task, rounds);
        let fleet = opts.fleet(opts.devices);
        let mut runs = Vec::new();
        for m in METHODS {
            println!("--- {stem}: {m} on {task} ---");
            runs.push(env.run_method(m, &cfg, &fleet)?);
        }
        finish(&format!("{stem}_{task}"), &runs)?;
        // Companion summaries (Figs. 8/11/12 are views of these runs).
        let target = shared_target(&runs);
        println!("completion time / traffic / waiting @ target:");
        for r in &runs {
            println!(
                "  {:<14} t={:>8}  traffic={:>9}  wait={:>7.1}s",
                r.method,
                r.time_to_accuracy(target)
                    .map(|t| format!("{t:.0}s"))
                    .unwrap_or("—".into()),
                r.traffic_to_accuracy(target)
                    .map(|b| format!("{:.1}MB", b as f64 / 1e6))
                    .unwrap_or("—".into()),
                r.mean_waiting()
            );
        }
    }
    Ok(())
}

/// Figs. 7/8/11/12 — the four methods on the GLUE-syn tasks.
fn fig7_main(env: &ExpEnv, opts: &Options) -> Result<()> {
    let tasks: &[&str] = if opts.quick {
        &["sst2"]
    } else {
        &["sst2", "qnli", "qqp", "mnli"]
    };
    methods_on_tasks(env, opts, tasks, opts.rounds_or(15), "fig7")
}

/// Fig. 9 — massive multitask understanding (mmlu-syn).
fn fig9_mmlu(env: &ExpEnv, opts: &Options) -> Result<()> {
    methods_on_tasks(env, opts, &["mmlu"], opts.rounds_or(15), "fig9")
}

/// Fig. 10 — mathematical reasoning (gsm-syn).
fn fig10_gsm(env: &ExpEnv, opts: &Options) -> Result<()> {
    methods_on_tasks(env, opts, &["gsm"], opts.rounds_or(18), "fig10")
}

/// Fig. 13 — ablation: LEGEND vs w/o LD vs w/o RD on SST-2 + QNLI.
fn fig13_ablation(env: &ExpEnv, opts: &Options) -> Result<()> {
    let tasks: &[&str] =
        if opts.quick { &["sst2"] } else { &["sst2", "qnli"] };
    for task in tasks {
        let cfg = opts.cfg(task, opts.rounds_or(12));
        let fleet = opts.fleet(opts.devices);
        let mut runs = Vec::new();
        for m in ["legend", "legend-no-ld", "legend-no-rd"] {
            println!("--- fig13: {m} on {task} ---");
            runs.push(env.run_method(m, &cfg, &fleet)?);
        }
        finish(&format!("fig13_{task}"), &runs)?;
    }
    Ok(())
}

/// A named strategy for external callers (examples/benches).
pub fn position_variant(label: &str, n_layers: usize)
                        -> Option<Box<dyn Strategy>> {
    let third = n_layers / 3;
    let layers = match label {
        "Layers-A" => LayerSet::All,
        "Layers-S" => LayerSet::Explicit((0..third).collect()),
        "Layers-M" => LayerSet::Explicit((third..2 * third).collect()),
        "Layers-D" => LayerSet::Depth(third),
        _ => return None,
    };
    Some(Box::new(FixedLayers { label: label.into(), layers, rank: 8 }))
}
