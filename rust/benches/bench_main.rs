//! Benchmark harness (`cargo bench [-- filter]`).
//!
//! criterion is not available offline, so this is a small self-timed
//! harness: adaptive iteration count, warmup, mean/p50/p95 per bench.
//!
//! Coverage (DESIGN.md §4 bench column):
//!  * component hot paths: LCD (Alg. 1), slot-aware aggregation
//!    (eq. 17), capacity EMA, mask construction, Dirichlet partition,
//!    grammar generation, JSON manifest parse — the L3 costs behind
//!    every figure;
//!  * per-figure end-to-end rounds: fig3 variant, fig7 methods
//!    (legend/fedlora/hetlora/fedadapter), fig13 ablations — each one
//!    full coordinator round at the paper's 80-device scale,
//!    mock-trained (FLOP-free, isolates the coordination cost);
//!  * artifact-backed (skipped when artifacts/ absent): PJRT train
//!    step (L1+L2 hot path), eval batch, one real federated round.

// Measuring wall-clock time is this harness's entire job; timings are
// reported, never folded into simulation state, so the determinism
// contract's wall-clock ban does not apply here.
#![allow(clippy::disallowed_methods)]

use std::time::Instant;

use legend::coordinator::aggregation::{aggregate, DeviceUpdate,
                                       ShardedAggregator,
                                       StreamingAggregator};
use legend::coordinator::capacity::CapacityEstimator;
use legend::coordinator::engine::effective_threads;
use legend::coordinator::lcd::{self, LcdDevice, LcdParams};
use legend::coordinator::participation::{Full, Participation,
                                         UniformCount};
use legend::coordinator::strategy::{self};
use legend::coordinator::trainer::{MockTrainer, PjrtTrainer};
use legend::coordinator::{run_federated, run_federated_with, Codec,
                          FedConfig, ModelMeta};
use legend::data::{grammar, partition, Spec};
use legend::device::{Fleet, FleetConfig, FleetView, LazyFleet};
use legend::model::masks::{arithmetic_ranks, LayerSet, LoraConfig};
use legend::model::state::{init_opt, init_trainable, TensorMap};
use legend::model::TensorSpec;
use legend::runtime::session::SessionState;
use legend::runtime::{Masks, Runtime};
use legend::util::json::Value;
use legend::util::rng::Rng;

const L: usize = 12;
const R: usize = 16;
const D: usize = 128;

fn run_bench<F: FnMut()>(name: &str, budget_ms: u64, mut f: F) {
    f(); // warmup
    let t0 = Instant::now();
    f();
    let one = t0.elapsed().as_nanos().max(1);
    let budget = (budget_ms as u128) * 1_000_000;
    let iters = ((budget / one).clamp(3, 10_000)) as usize;
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as u64);
    }
    samples.sort_unstable();
    let mean = samples.iter().sum::<u64>() / samples.len() as u64;
    let p50 = samples[samples.len() / 2];
    let p95 = samples[samples.len() * 95 / 100];
    println!(
        "{name:<40} {:>12} {:>12} {:>12} {:>7}",
        fmt_ns(mean),
        fmt_ns(p50),
        fmt_ns(p95),
        samples.len()
    );
}

fn fmt_ns(ns: u64) -> String {
    if ns >= 1_000_000_000 {
        format!("{:.2} s", ns as f64 / 1e9)
    } else if ns >= 1_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else if ns >= 1_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else {
        format!("{ns} ns")
    }
}

/// Peak resident set size of this process in KiB (`VmHWM` from
/// `/proc/self/status`; 0 where procfs is unavailable). A process-wide
/// high-water mark: monotone over the run, so comparisons must order
/// the small case before the large one.
fn peak_rss_kb() -> u64 {
    std::fs::read_to_string("/proc/self/status")
        .ok()
        .and_then(|s| {
            s.lines()
                .find(|l| l.starts_with("VmHWM:"))
                .and_then(|l| l.split_whitespace().nth(1))
                .and_then(|v| v.parse().ok())
        })
        .unwrap_or(0)
}

fn toy_spec() -> Spec {
    let json = r#"{
      "vocab_size": 256, "seq_len": 16,
      "special": {"pad": 0, "cls": 1, "mask": 2, "sep": 3},
      "filler": [4, 50], "noise": [200, 256],
      "tasks": {
        "sst2": {"kind": "single", "n_classes": 2,
                 "banks": [[50, 80], [80, 110]],
                 "len_range": [5, 10], "bank_words": [2, 4],
                 "label_noise": 0.0}
      }
    }"#;
    Spec::from_json(&Value::parse(json).unwrap()).unwrap()
}

fn real_specs() -> Vec<TensorSpec> {
    vec![
        TensorSpec { name: "aq".into(), shape: vec![L, R, D] },
        TensorSpec { name: "bq".into(), shape: vec![L, D, R] },
        TensorSpec { name: "av".into(), shape: vec![L, R, D] },
        TensorSpec { name: "bv".into(), shape: vec![L, D, R] },
        TensorSpec { name: "head_w".into(), shape: vec![D, 4] },
        TensorSpec { name: "head_b".into(), shape: vec![4] },
    ]
}

fn random_updates(n: usize, seed: u64) -> Vec<DeviceUpdate> {
    let mut rng = Rng::new(seed);
    let specs = real_specs();
    (0..n)
        .map(|_| {
            let mut t = TensorMap::zeros(&specs);
            for (_, v) in &mut t.entries {
                for x in v.iter_mut() {
                    *x = rng.f32() - 0.5;
                }
            }
            DeviceUpdate {
                trainable: t,
                config: LoraConfig {
                    layers: LayerSet::Depth(rng.range_incl(1, L)),
                    ranks: arithmetic_ranks(L, 1, 1, 78, R),
                },
                weight: 1.0,
            }
        })
        .collect()
}

fn mock_round_once(method: &str, meta: &ModelMeta, spec: &Spec) {
    let mut s = strategy::by_name(method, meta.n_layers, meta.r_max,
                                  meta.w_max)
        .unwrap();
    let family = s.family();
    let mut fleet = Fleet::new(FleetConfig::paper());
    let mut trainer = MockTrainer::new(family);
    let cfg = FedConfig {
        rounds: 1,
        train_size: 2048,
        test_size: 64,
        ..Default::default()
    };
    let global = TensorMap::zeros(&[TensorSpec {
        name: "aq".into(),
        shape: vec![L, meta.rank_dim(family), 8],
    }]);
    let _ = run_federated(&cfg, &mut fleet, s.as_mut(), &mut trainer,
                          meta, spec, global)
        .unwrap();
}

fn main() {
    let filter = std::env::args()
        .skip(1)
        .find(|a| !a.starts_with("--"))
        .unwrap_or_default();
    println!(
        "{:<40} {:>12} {:>12} {:>12} {:>7}",
        "benchmark", "mean", "p50", "p95", "iters"
    );
    let want = |name: &str| filter.is_empty() || name.contains(&filter);

    // ---- component hot paths ----------------------------------------------
    if want("lcd_80") {
        let mut rng = Rng::new(1);
        let devices: Vec<LcdDevice> = (0..80)
            .map(|_| LcdDevice {
                capacity: legend::coordinator::capacity::Capacity {
                    mu: rng.uniform(0.005, 0.5),
                    beta: rng.uniform(0.01, 1.0),
                },
                fwd_time: 0.02,
                n_batches: 8,
                compute_budget: f64::MAX,
                comm_budget: usize::MAX,
                unit_rank_bytes: 2048,
            })
            .collect();
        let params = LcdParams::paper(L, R);
        run_bench("lcd_80_devices (Alg.1)", 300, || {
            std::hint::black_box(lcd::determine(&params, &devices));
        });
    }
    if want("aggregation") {
        let updates = random_updates(80, 2);
        let mut global = TensorMap::zeros(&real_specs());
        run_bench("aggregation_80x_full_size (eq.17)", 1500, || {
            aggregate(&mut global, &updates, L, R);
        });
    }
    if want("capacity") {
        run_bench("capacity_ema_80x100_rounds (eq.8-9)", 200, || {
            let mut est = CapacityEstimator::paper(80);
            for h in 0..100 {
                for i in 0..80 {
                    est.update(i, 0.01 + (h + i) as f64 * 1e-4, 0.1);
                }
            }
            std::hint::black_box(est.get(79));
        });
    }
    if want("masks") {
        let cfg = LoraConfig {
            layers: LayerSet::Depth(6),
            ranks: arithmetic_ranks(L, 1, 1, 78, R),
        };
        run_bench("mask_construction", 200, || {
            std::hint::black_box(cfg.rank_mask(L, R));
            std::hint::black_box(cfg.layer_mask(L));
        });
    }
    if want("partition") {
        let spec = toy_spec();
        let mut rng = Rng::new(3);
        let ds =
            grammar::generate(&spec, "sst2", 20_000, &mut rng).unwrap();
        run_bench("dirichlet_partition_20k_80dev", 800, || {
            let mut prng = Rng::new(4);
            std::hint::black_box(partition::split(
                &ds,
                80,
                partition::Partition::Dirichlet { alpha: 10.0 },
                2,
                4,
                &mut prng,
            ));
        });
    }
    if want("grammar") {
        let spec = toy_spec();
        run_bench("grammar_generate_1k_examples", 500, || {
            let mut rng = Rng::new(5);
            std::hint::black_box(
                grammar::generate(&spec, "sst2", 1000, &mut rng)
                    .unwrap(),
            );
        });
    }
    if want("json") {
        let text = std::fs::read_to_string("artifacts/manifest.json")
            .unwrap_or_else(|_| {
                r#"{"model":{"n_layers":12},"base":[]}"#.into()
            });
        run_bench("json_parse_manifest", 300, || {
            std::hint::black_box(Value::parse(&text).unwrap());
        });
    }

    // ---- per-figure coordinator rounds (mock, 80 devices) ------------------
    let meta = ModelMeta::synthetic(L, R, 32);
    let spec = toy_spec();
    for (bench, method) in [
        ("fig7_round_legend", "legend"),
        ("fig7_round_fedlora", "fedlora"),
        ("fig7_round_hetlora", "hetlora"),
        ("fig7_round_fedadapter", "fedadapter"),
        ("fig13_round_no_ld", "legend-no-ld"),
        ("fig13_round_no_rd", "legend-no-rd"),
    ] {
        if want(bench) {
            let name = format!("{bench} (80 dev, mock)");
            run_bench(&name, 1200, || {
                mock_round_once(method, &meta, &spec)
            });
        }
    }
    if want("fig3_round_layers_d") {
        run_bench("fig3_round_layers_d (10 dev, mock)", 600, || {
            let mut s = strategy::FixedLayers {
                label: "Layers-D".into(),
                layers: LayerSet::Depth(4),
                rank: 8,
            };
            let mut fleet = Fleet::new(FleetConfig::pretest());
            let mut trainer = MockTrainer::new("lora");
            let cfg = FedConfig {
                rounds: 1,
                train_size: 512,
                test_size: 64,
                ..Default::default()
            };
            let global = TensorMap::zeros(&[TensorSpec {
                name: "aq".into(),
                shape: vec![L, R, 8],
            }]);
            let _ = run_federated(&cfg, &mut fleet, &mut s, &mut trainer,
                                  &meta, &spec, global)
                .unwrap();
        });
    }

    // ---- engine: sequential vs parallel phase ④ ----------------------------
    // Full-size global tensors so each mock device does real memory
    // work; same seed at every thread count ⇒ identical RunRecords,
    // only the wall-clock changes. Engine cases accumulate their
    // sections here and BENCH_engine.json is written once at the end,
    // so a filtered run (e.g. `-- engine_lazy` in CI) still emits it.
    let mut engine_doc: Vec<(&str, Value)> = Vec::new();
    if want("engine") {
        let engine_round = |n_dev: usize, threads: usize| -> f64 {
            let mut s = strategy::by_name("legend", L, R, 32).unwrap();
            let mut fleet = Fleet::new(FleetConfig::sized(n_dev));
            let mut trainer = MockTrainer::new("lora");
            let cfg = FedConfig {
                rounds: 2,
                train_size: n_dev * 64,
                test_size: 64,
                threads,
                ..Default::default()
            };
            let global = TensorMap::zeros(&real_specs());
            let t0 = Instant::now();
            let _ = run_federated(&cfg, &mut fleet, s.as_mut(),
                                  &mut trainer, &meta, &spec, global)
                .unwrap();
            t0.elapsed().as_secs_f64() * 1e3
        };
        println!(
            "{:<40} {:>12} {:>12} {:>12} {:>7}",
            "engine_seq_vs_par", "seq", "par", "speedup", "devs"
        );
        let mut rows = Vec::new();
        for n_dev in [8usize, 64, 256] {
            let best = |threads: usize| {
                (0..3)
                    .map(|_| engine_round(n_dev, threads))
                    .fold(f64::MAX, f64::min)
            };
            let seq_ms = best(1);
            let par_ms = best(0);
            let speedup = seq_ms / par_ms.max(1e-9);
            println!(
                "{:<40} {:>9.1} ms {:>9.1} ms {:>11.2}× {:>7}",
                format!("engine_2_rounds_{n_dev}dev"),
                seq_ms,
                par_ms,
                speedup,
                n_dev
            );
            rows.push(Value::obj(vec![
                ("devices", Value::Num(n_dev as f64)),
                ("rounds", Value::Num(2.0)),
                ("seq_ms", Value::Num(seq_ms)),
                ("par_ms", Value::Num(par_ms)),
                ("speedup", Value::Num(speedup)),
            ]));
        }
        // ---- fold throughput: single-thread vs sharded eq. 17 ------------
        // One 256-device cohort of full-size mock updates fed straight
        // into the aggregator — the coordinator-side fold hot path,
        // isolated from training. The owned per-update maps are cloned
        // outside the timed region so both paths do identical work.
        let fold_updates = random_updates(256, 11);
        let fold_specs = real_specs();
        let shards = effective_threads(0).clamp(2, fold_specs.len());
        let fold_best = |n_shards: usize| -> f64 {
            (0..3)
                .map(|_| {
                    let owned: Vec<TensorMap> = fold_updates
                        .iter()
                        .map(|u| u.trainable.clone())
                        .collect();
                    let mut global = TensorMap::zeros(&fold_specs);
                    let t0 = Instant::now();
                    if n_shards <= 1 {
                        let mut agg =
                            StreamingAggregator::new(&global, L, R);
                        for (u, t) in fold_updates.iter().zip(&owned) {
                            agg.push(t, &u.config, u.weight);
                        }
                        agg.finish(&mut global);
                    } else {
                        let mut agg = ShardedAggregator::new(
                            &global, L, R, n_shards, 16,
                        );
                        for (u, t) in fold_updates.iter().zip(owned) {
                            agg.push(t, &u.config, u.weight).unwrap();
                        }
                        agg.finish(&mut global).unwrap();
                    }
                    std::hint::black_box(&global);
                    t0.elapsed().as_secs_f64() * 1e3
                })
                .fold(f64::MAX, f64::min)
        };
        let single_ms = fold_best(1);
        let sharded_ms = fold_best(shards);
        let fold_speedup = single_ms / sharded_ms.max(1e-9);
        println!(
            "{:<40} {:>9.1} ms {:>9.1} ms {:>11.2}× {:>7}",
            format!("engine_fold_256dev_{shards}shards"),
            single_ms,
            sharded_ms,
            fold_speedup,
            256
        );

        // ---- async vs barrier rounds --------------------------------------
        // Wall-clock of the staleness-windowed engine vs the eq. 12
        // barrier loop on the same fleet/seed (mock-trained, so this
        // isolates coordination cost), plus the virtual-time totals —
        // the async engine's whole point is that commit windows close
        // before the straggler.
        let engine_mode_run = |n_dev: usize, s_max: usize,
                               async_mode: bool| -> (f64, f64) {
            let mut s = strategy::by_name("legend", L, R, 32).unwrap();
            let mut fleet = Fleet::new(FleetConfig::sized(n_dev));
            let mut trainer = MockTrainer::new("lora");
            let cfg = FedConfig {
                rounds: 2,
                train_size: n_dev * 64,
                test_size: 64,
                async_mode,
                staleness_alpha: 0.5,
                max_staleness: s_max,
                ..Default::default()
            };
            let global = TensorMap::zeros(&real_specs());
            let t0 = Instant::now();
            let rec = run_federated(&cfg, &mut fleet, s.as_mut(),
                                    &mut trainer, &meta, &spec, global)
                .unwrap();
            (t0.elapsed().as_secs_f64() * 1e3, rec.total_time())
        };
        let best_mode = |s_max: usize, async_mode: bool| -> (f64, f64) {
            // Keep the (wall-clock, virtual-time) pair of the fastest
            // rep together — virtual time is deterministic across
            // reps today, but mixing metrics from different reps
            // would be silently wrong if that ever changes.
            (0..3)
                .map(|_| engine_mode_run(64, s_max, async_mode))
                .fold((f64::MAX, f64::MAX), |acc, x| {
                    if x.0 < acc.0 {
                        x
                    } else {
                        acc
                    }
                })
        };
        let (barrier_ms, barrier_vt) = best_mode(0, false);
        let (async_ms, async_vt) = best_mode(2, true);
        println!(
            "{:<40} {:>9.1} ms {:>9.1} ms {:>6.1}s→{:>5.1}s {:>4}",
            "engine_async_vs_barrier_64dev",
            barrier_ms,
            async_ms,
            barrier_vt,
            async_vt,
            64
        );

        engine_doc.push(("fleets", Value::Arr(rows)));
        engine_doc.push((
            "fold",
            Value::obj(vec![
                ("devices", Value::Num(256.0)),
                ("shards", Value::Num(shards as f64)),
                ("single_ms", Value::Num(single_ms)),
                ("sharded_ms", Value::Num(sharded_ms)),
                ("speedup", Value::Num(fold_speedup)),
            ]),
        ));
        engine_doc.push((
            "async",
            Value::obj(vec![
                ("devices", Value::Num(64.0)),
                ("rounds", Value::Num(2.0)),
                ("max_staleness", Value::Num(2.0)),
                ("staleness_alpha", Value::Num(0.5)),
                ("barrier_ms", Value::Num(barrier_ms)),
                ("async_ms", Value::Num(async_ms)),
                ("barrier_virtual_s", Value::Num(barrier_vt)),
                ("async_virtual_s", Value::Num(async_vt)),
            ]),
        ));
    }

    // ---- engine: lazy million-device fleet + edge tier ---------------------
    // Peak-RSS comparison: a full 80-device eager round vs a
    // 1,000,000-device lazy fleet sampling a 1,000-device cohort
    // through the edge-aggregation tier. VmHWM is a process-wide
    // high-water mark (monotone), so the eager case runs first and the
    // lazy case can only read equal or higher; the acceptance bound is
    // lazy ≤ 10× eager.
    if want("engine_lazy") {
        let scale_run = |fleet: &mut dyn FleetView,
                         participation: &mut dyn Participation,
                         cohort: usize,
                         edges: usize|
         -> f64 {
            let mut s = strategy::by_name("legend", L, R, 32).unwrap();
            let mut trainer = MockTrainer::new("lora");
            let cfg = FedConfig {
                rounds: 2,
                train_size: 64 * cohort,
                test_size: 64,
                window: 16,
                edge_aggregators: edges,
                ..Default::default()
            };
            let global = TensorMap::zeros(&real_specs());
            let t0 = Instant::now();
            let _ = run_federated_with(&cfg, fleet, s.as_mut(),
                                       &mut trainer, &meta, &spec,
                                       global, participation)
                .unwrap();
            t0.elapsed().as_secs_f64() * 1e3
        };
        let mut eager = Fleet::new(FleetConfig::sized(80));
        let eager_ms = scale_run(&mut eager, &mut Full, 80, 1);
        let eager_rss = peak_rss_kb();
        drop(eager);
        let mut lazy = LazyFleet::new(FleetConfig::sized(1_000_000));
        let lazy_ms = scale_run(&mut lazy,
                                &mut UniformCount { count: 1_000 },
                                1_000, 4);
        let lazy_rss = peak_rss_kb();
        let ratio = lazy_rss as f64 / eager_rss.max(1) as f64;
        println!(
            "{:<40} {:>9.1} ms {:>9.1} ms {:>8} KiB {:>6.2}×",
            "engine_lazy_1m_fleet_1k_cohort",
            eager_ms,
            lazy_ms,
            lazy_rss,
            ratio
        );
        engine_doc.push((
            "lazy",
            Value::obj(vec![
                ("eager_devices", Value::Num(80.0)),
                ("lazy_devices", Value::Num(1_000_000.0)),
                ("cohort", Value::Num(1_000.0)),
                ("rounds", Value::Num(2.0)),
                ("edge_aggregators", Value::Num(4.0)),
                ("eager_round_ms", Value::Num(eager_ms)),
                ("lazy_round_ms", Value::Num(lazy_ms)),
                ("eager_peak_rss_kb", Value::Num(eager_rss as f64)),
                ("lazy_peak_rss_kb", Value::Num(lazy_rss as f64)),
                ("rss_ratio", Value::Num(ratio)),
            ]),
        ));
    }

    // ---- codec: per-codec bytes-on-wire ------------------------------------
    // The same fixed-seed 2-round / 64-device run under each --codec;
    // up/down come from the transport's byte-honest tallies (framing
    // headers and STATUS_BYTES included), so the ratio is what the wire
    // actually saves, not a nominal payload estimate. The tallies are
    // covered by the determinism contract, so the byte leaves are
    // exact across runners. Acceptance (docs/TRANSPORT.md): int8+delta
    // cuts total bytes-on-wire by >= 35% vs codec=none —
    // scripts/bench_diff.py holds `int8_savings_ratio` to that bound.
    if want("engine_codec") {
        let codec_run = |codec: Codec| -> (usize, usize) {
            let mut s = strategy::by_name("legend", L, R, 32).unwrap();
            let mut fleet = Fleet::new(FleetConfig::sized(64));
            let mut trainer = MockTrainer::new("lora");
            let cfg = FedConfig {
                rounds: 2,
                train_size: 64 * 64,
                test_size: 64,
                codec,
                ..Default::default()
            };
            let global = TensorMap::zeros(&real_specs());
            let rec = run_federated(&cfg, &mut fleet, s.as_mut(),
                                    &mut trainer, &meta, &spec, global)
                .unwrap();
            let up = rec.rounds.iter().map(|r| r.up_bytes).sum();
            let down = rec.rounds.iter().map(|r| r.down_bytes).sum();
            (up, down)
        };
        let (none_up, none_down) = codec_run(Codec::None);
        let (int8_up, int8_down) = codec_run(Codec::Int8);
        let (int4_up, int4_down) = codec_run(Codec::Int4);
        let none_total = none_up + none_down;
        let savings = |up: usize, down: usize| -> f64 {
            1.0 - (up + down) as f64 / none_total as f64
        };
        let int8_savings = savings(int8_up, int8_down);
        let int4_savings = savings(int4_up, int4_down);
        println!(
            "{:<40} {:>10} B {:>10} B {:>9.1}% {:>6}",
            "engine_codec_int8_vs_none_64dev",
            none_total,
            int8_up + int8_down,
            int8_savings * 100.0,
            64
        );
        println!(
            "{:<40} {:>10} B {:>10} B {:>9.1}% {:>6}",
            "engine_codec_int4_vs_none_64dev",
            none_total,
            int4_up + int4_down,
            int4_savings * 100.0,
            64
        );
        engine_doc.push((
            "codec",
            Value::obj(vec![
                ("devices", Value::Num(64.0)),
                ("rounds", Value::Num(2.0)),
                ("none_up_bytes", Value::Num(none_up as f64)),
                ("none_down_bytes", Value::Num(none_down as f64)),
                ("int8_up_bytes", Value::Num(int8_up as f64)),
                ("int8_down_bytes", Value::Num(int8_down as f64)),
                ("int4_up_bytes", Value::Num(int4_up as f64)),
                ("int4_down_bytes", Value::Num(int4_down as f64)),
                ("int8_savings_ratio", Value::Num(int8_savings)),
                ("int4_savings_ratio", Value::Num(int4_savings)),
            ]),
        ));
    }

    // ---- realloc: per-round plan refits vs the static plan -----------------
    // The same fixed-seed 4-round / 64-device run with the LCD plan
    // frozen (realloc off) and refit every 2 rounds. The refit is an
    // O(cohort) LCD solve plus the EWMA band check — coordination-side
    // only, so the overhead ratio must stay small regardless of runner
    // speed; scripts/bench_diff.py holds `realloc_overhead_ratio` to a
    // hard 1.5× bound. `epochs_adopted` is deterministic (fixed seed)
    // and must match exactly once measured.
    if want("engine_realloc") {
        let realloc_run = |every: usize| -> (f64, usize) {
            let mut s = strategy::by_name("legend", L, R, 32).unwrap();
            let mut fleet = Fleet::new(FleetConfig::sized(64));
            let mut trainer = MockTrainer::new("lora");
            let cfg = FedConfig {
                rounds: 4,
                train_size: 64 * 64,
                test_size: 64,
                realloc_every: every,
                realloc_hysteresis: 0.05,
                ..Default::default()
            };
            let global = TensorMap::zeros(&real_specs());
            let t0 = Instant::now();
            let rec = run_federated(&cfg, &mut fleet, s.as_mut(),
                                    &mut trainer, &meta, &spec, global)
                .unwrap();
            (t0.elapsed().as_secs_f64() * 1e3, rec.rank_realloc_epochs)
        };
        let best = |every: usize| -> (f64, usize) {
            (0..3).map(|_| realloc_run(every)).fold(
                (f64::MAX, 0),
                |acc, x| if x.0 < acc.0 { x } else { acc },
            )
        };
        let (static_ms, _) = best(0);
        let (realloc_ms, epochs) = best(2);
        let overhead = realloc_ms / static_ms.max(1e-9);
        println!(
            "{:<40} {:>9.1} ms {:>9.1} ms {:>11.2}× {:>7}",
            "engine_realloc_k2_vs_static_64dev",
            static_ms,
            realloc_ms,
            overhead,
            64
        );
        engine_doc.push((
            "realloc",
            Value::obj(vec![
                ("devices", Value::Num(64.0)),
                ("rounds", Value::Num(4.0)),
                ("realloc_every", Value::Num(2.0)),
                ("realloc_hysteresis", Value::Num(0.05)),
                ("epochs_adopted", Value::Num(epochs as f64)),
                ("static_ms", Value::Num(static_ms)),
                ("realloc_ms", Value::Num(realloc_ms)),
                ("realloc_overhead_ratio", Value::Num(overhead)),
            ]),
        ));
    }

    // ---- multi-job: 2 tenants through the scheduler vs sequential ----------
    // The same two fixed-seed 24-device-cohort jobs run (a) back to
    // back through the single-job engine and (b) interleaved by the
    // multi-job scheduler over one shared 64-device fleet. Training
    // volume is identical (24 devices × 2 rounds per job), so the
    // ratio isolates what the scheduling layer itself costs — claim
    // order, disjointness filtering, backfill, token buckets;
    // scripts/bench_diff.py holds `multijob_overhead_ratio` to a hard
    // 1.5× bound.
    if want("engine_multijob") {
        use legend::coordinator::{JobScheduler, JobSpec};
        let job_cfg = |seed: u64| FedConfig {
            rounds: 2,
            train_size: 24 * 64,
            test_size: 64,
            seed,
            ..Default::default()
        };
        let single_run = |seed: u64| -> f64 {
            let mut s = strategy::by_name("legend", L, R, 32).unwrap();
            let mut fleet = Fleet::new(FleetConfig::sized(64));
            let mut trainer = MockTrainer::new("lora");
            let global = TensorMap::zeros(&real_specs());
            let t0 = Instant::now();
            let _ = run_federated_with(&job_cfg(seed), &mut fleet,
                                       s.as_mut(), &mut trainer, &meta,
                                       &spec, global,
                                       &mut UniformCount { count: 24 })
                .unwrap();
            t0.elapsed().as_secs_f64() * 1e3
        };
        let sched_run = || -> f64 {
            let mut sched =
                JobScheduler::new(meta.clone(), spec.clone(), 64);
            for j in 0..2u64 {
                let s =
                    strategy::by_name("legend", L, R, 32).unwrap();
                sched
                    .admit(JobSpec::new(job_cfg(1 + j)), s,
                           Box::new(MockTrainer::new("lora")),
                           Box::new(UniformCount { count: 24 }),
                           TensorMap::zeros(&real_specs()))
                    .unwrap();
            }
            let mut fleet = Fleet::new(FleetConfig::sized(64));
            let t0 = Instant::now();
            let _ = sched.run(&mut fleet).unwrap();
            t0.elapsed().as_secs_f64() * 1e3
        };
        let best = |f: &dyn Fn() -> f64| -> f64 {
            (0..3).map(|_| f()).fold(f64::MAX, f64::min)
        };
        let sequential_ms =
            best(&|| single_run(1)) + best(&|| single_run(2));
        let scheduler_ms = best(&sched_run);
        let overhead = scheduler_ms / sequential_ms.max(1e-9);
        println!(
            "{:<40} {:>9.1} ms {:>9.1} ms {:>11.2}× {:>7}",
            "engine_multijob_2jobs_64dev",
            sequential_ms,
            scheduler_ms,
            overhead,
            64
        );
        engine_doc.push((
            "multijob",
            Value::obj(vec![
                ("devices", Value::Num(64.0)),
                ("jobs", Value::Num(2.0)),
                ("rounds", Value::Num(2.0)),
                ("cohort_per_job", Value::Num(24.0)),
                ("sequential_ms", Value::Num(sequential_ms)),
                ("scheduler_ms", Value::Num(scheduler_ms)),
                ("multijob_overhead_ratio", Value::Num(overhead)),
            ]),
        ));
    }

    if !engine_doc.is_empty() {
        let mut fields = vec![
            ("bench", Value::Str("engine".into())),
            ("trainer", Value::Str("mock".into())),
            ("threads_auto",
             Value::Num(effective_threads(0) as f64)),
        ];
        fields.append(&mut engine_doc);
        let doc = Value::obj(fields);
        // The bench's CWD is the crate dir (rust/); BENCH_*.json files
        // belong at the workspace root where CI picks them up.
        let out = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("..")
            .join("BENCH_engine.json");
        match std::fs::write(&out, doc.to_string()) {
            Ok(()) => println!("wrote {}", out.display()),
            Err(e) => println!("({} not written: {e})", out.display()),
        }
    }

    // ---- artifact-backed (L1/L2 hot path) -----------------------------------
    if std::path::Path::new("artifacts/manifest.json").exists() {
        let rt = Runtime::load("artifacts").expect("runtime");
        let dim = rt.manifest.dim.clone();
        let rspec = Spec::load("artifacts/vocab.json").unwrap();
        let mut rng = Rng::new(6);
        let ds =
            grammar::generate(&rspec, "sst2", 256, &mut rng).unwrap();
        let lcfg = LoraConfig {
            layers: LayerSet::Depth(4),
            ranks: arithmetic_ranks(dim.n_layers, 1, 1, 78, dim.r_max),
        };
        let masks = Masks {
            rank_mask: lcfg.rank_mask(dim.n_layers, dim.r_max),
            layer_mask: lcfg.layer_mask(dim.n_layers),
        };
        if want("train_step") {
            let mut srng = Rng::new(7);
            let t = init_trainable(&rt.manifest, &rt.manifest.lora,
                                   &mut srng);
            let o = init_opt(&rt.manifest.lora);
            let mut session = SessionState::from_maps(&t, &o).unwrap();
            let batches = ds.batches(dim.batch_size);
            let mut step = 0f32;
            run_bench("pjrt_train_step (L1+L2 hot path)", 4000, || {
                step += 1.0;
                let b = &batches[(step as usize) % batches.len()];
                rt.train_step("lora", &mut session, &masks, &b.0, &b.1,
                              5e-3, step)
                    .unwrap();
            });
        }
        if want("eval_batch") {
            let mut srng = Rng::new(8);
            let t = init_trainable(&rt.manifest, &rt.manifest.lora,
                                   &mut srng);
            run_bench("pjrt_eval_256_examples", 4000, || {
                rt.evaluate("lora", &t, &masks, &ds).unwrap();
            });
        }
        if want("real_round") {
            let rmeta = ModelMeta::from_manifest(&rt.manifest);
            run_bench("real_federated_round_6dev", 8000, || {
                let mut s = strategy::by_name("legend", rmeta.n_layers,
                                              rmeta.r_max, rmeta.w_max)
                    .unwrap();
                let mut fleet = Fleet::new(FleetConfig::sized(6));
                let mut trainer = PjrtTrainer::new(&rt, "lora", 1);
                let fcfg = FedConfig {
                    rounds: 1,
                    train_size: 192,
                    test_size: 64,
                    max_batches: 4,
                    ..Default::default()
                };
                let mut grng = Rng::new(1).child("global-init");
                let global = init_trainable(&rt.manifest,
                                            &rt.manifest.lora,
                                            &mut grng);
                let _ = run_federated(&fcfg, &mut fleet, s.as_mut(),
                                      &mut trainer, &rmeta, &rspec,
                                      global)
                    .unwrap();
            });
        }
    } else {
        println!(
            "(artifacts/ missing — PJRT benches skipped; run `make \
             artifacts`)"
        );
    }
}
