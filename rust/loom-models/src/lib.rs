//! Loom models of the legend crate's two handoff protocols.
//!
//! The determinism contract says results must be bit-identical at
//! every `threads × agg-shards × window` setting; the runtime oracle
//! harness checks that on the schedules the OS happens to produce.
//! These models re-state the two protocols that *create* those
//! schedules in miniature and let loom enumerate every interleaving:
//!
//! 1. [`window_model`] — `engine::train_parallel`'s in-flight window:
//!    an atomic claim cursor, a `Mutex<usize>` fold cursor with a
//!    `Condvar` parking workers that run ahead of the window, a
//!    reorder buffer delivering outcomes in job-index order, and the
//!    abort flag set *under the cursor lock* so a parked worker can
//!    never miss the wake-up. Checked properties: outcomes reach the
//!    sink in job-index order with the buffer never exceeding the
//!    window, and an aborted round terminates (no lost-wakeup
//!    deadlock).
//! 2. [`shard_model`] — `aggregation::ShardedAggregator`'s fan-out:
//!    each update is broadcast to every shard over a bounded queue
//!    (back-pressure instead of unbounded growth), each shard folds
//!    its disjoint element subset in arrival order, and `finish`
//!    merges shards in shard-index order. Checked properties: every
//!    shard sees the full stream in push order, the close/join
//!    handshake terminates, and the shard-order merge equals the
//!    flat sequential fold.
//!
//! The models use integer "quantized" contributions — like the Q60
//! fold, addition here is exactly associative, so equality checks are
//! bit-exact by construction and the thing under test is purely the
//! synchronization protocol.
//!
//! Kept deliberately tiny (≤ 3 threads, ≤ 3 messages): loom explores
//! the full interleaving space, which grows combinatorially.

#[cfg(loom)]
use loom::{
    sync::{
        atomic::{AtomicBool, AtomicUsize, Ordering},
        Arc, Condvar, Mutex,
    },
    thread,
};
#[cfg(not(loom))]
use std::{
    sync::{
        atomic::{AtomicBool, AtomicUsize, Ordering},
        Arc, Condvar, Mutex,
    },
    thread,
};

use std::collections::{BTreeMap, VecDeque};

/// Run `f` under loom's exhaustive scheduler when built with
/// `--cfg loom`, or once on std sync otherwise.
pub fn model<F>(f: F)
where
    F: Fn() + Sync + Send + 'static,
{
    #[cfg(loom)]
    loom::model(f);
    #[cfg(not(loom))]
    f();
}

// ---------------------------------------------------------------------------
// Model 1: train_parallel's in-flight window
// ---------------------------------------------------------------------------

const N_JOBS: usize = 3;
const WINDOW: usize = 1;
const WORKERS: usize = 2;

/// Unbounded result channel (stands in for `mpsc::channel`): a deque
/// plus a live-sender count so the receiver can observe closure.
struct ResultChan {
    state: Mutex<(VecDeque<(usize, Result<u64, ()>)>, usize)>,
    ready: Condvar,
}

impl ResultChan {
    fn new(senders: usize) -> Self {
        ResultChan {
            state: Mutex::new((VecDeque::new(), senders)),
            ready: Condvar::new(),
        }
    }

    fn send(&self, msg: (usize, Result<u64, ()>)) {
        self.state.lock().unwrap().0.push_back(msg);
        self.ready.notify_all();
    }

    fn sender_done(&self) {
        self.state.lock().unwrap().1 -= 1;
        self.ready.notify_all();
    }

    fn recv(&self) -> Option<(usize, Result<u64, ()>)> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(m) = st.0.pop_front() {
                return Some(m);
            }
            if st.1 == 0 {
                return None;
            }
            st = self.ready.wait(st).unwrap();
        }
    }
}

struct WindowShared {
    next: AtomicUsize,
    abort: AtomicBool,
    cursor: Mutex<usize>,
    unblock: Condvar,
    results: ResultChan,
}

/// One worker of `train_parallel`: claim a job off the atomic cursor,
/// park while it is more than `WINDOW` ahead of the fold cursor, "run"
/// it (a pure function of the index; `fail_at` injects the error
/// path), and send the outcome.
fn window_worker(sh: &WindowShared, fail_at: Option<usize>) {
    loop {
        if sh.abort.load(Ordering::Relaxed) {
            break;
        }
        let i = sh.next.fetch_add(1, Ordering::Relaxed);
        if i >= N_JOBS {
            break;
        }
        {
            let mut c = sh.cursor.lock().unwrap();
            while i >= (*c).saturating_add(WINDOW) {
                if sh.abort.load(Ordering::Relaxed) {
                    sh.results.sender_done();
                    return;
                }
                c = sh.unblock.wait(c).unwrap();
            }
        }
        let out = if fail_at == Some(i) {
            Err(())
        } else {
            Ok((i as u64 + 1) * 10)
        };
        if out.is_err() {
            sh.abort.store(true, Ordering::Relaxed);
        }
        sh.results.send((i, out));
    }
    sh.results.sender_done();
}

/// The receiver half: drain the channel, re-serialize through the
/// reorder buffer, advance the fold cursor under the mutex, signal
/// parked workers. Returns (delivered-in-order, max buffer depth,
/// first failed index).
fn window_receiver(
    sh: &WindowShared,
) -> (Vec<(usize, u64)>, usize, Option<usize>) {
    let mut pending: BTreeMap<usize, u64> = BTreeMap::new();
    let mut delivered = Vec::new();
    let mut next_k = 0usize;
    let mut max_pending = 0usize;
    let mut failed: Option<usize> = None;
    while let Some((i, res)) = sh.results.recv() {
        match res {
            Ok(out) if failed.is_none() => {
                pending.insert(i, out);
                max_pending = max_pending.max(pending.len());
                while let Some(out) = pending.remove(&next_k) {
                    delivered.push((next_k, out));
                    next_k += 1;
                    *sh.cursor.lock().unwrap() = next_k;
                    sh.unblock.notify_all();
                }
            }
            Ok(_) => {}
            Err(()) => {
                if failed.map_or(true, |j| i < j) {
                    failed = Some(i);
                }
                // Set abort under the cursor lock so a worker that
                // read `abort == false` just before parking cannot
                // sleep through the wake-up.
                let _c = sh.cursor.lock().unwrap();
                sh.abort.store(true, Ordering::Relaxed);
                sh.unblock.notify_all();
            }
        }
    }
    (delivered, max_pending, failed)
}

/// Run the full protocol once; return the receiver's observations.
pub fn window_model(
    fail_at: Option<usize>,
) -> (Vec<(usize, u64)>, usize, Option<usize>) {
    let sh = Arc::new(WindowShared {
        next: AtomicUsize::new(0),
        abort: AtomicBool::new(false),
        cursor: Mutex::new(0),
        unblock: Condvar::new(),
        results: ResultChan::new(WORKERS),
    });
    let handles: Vec<_> = (0..WORKERS)
        .map(|_| {
            let sh = Arc::clone(&sh);
            thread::spawn(move || window_worker(&sh, fail_at))
        })
        .collect();
    let got = window_receiver(&sh);
    for h in handles {
        h.join().unwrap();
    }
    got
}

// ---------------------------------------------------------------------------
// Model 2: ShardedAggregator's bounded fan-out + shard-order merge
// ---------------------------------------------------------------------------

const N_SHARDS: usize = 2;
const QUEUE_CAP: usize = 1;

/// Bounded SPSC queue (stands in for `mpsc::sync_channel(cap)`):
/// `send` back-pressures when full, `close` wakes the drain loop.
struct BoundedChan {
    state: Mutex<(VecDeque<(i64, i64)>, bool)>,
    not_full: Condvar,
    not_empty: Condvar,
}

impl BoundedChan {
    fn new() -> Self {
        BoundedChan {
            state: Mutex::new((VecDeque::new(), false)),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
        }
    }

    fn send(&self, msg: (i64, i64)) {
        let mut st = self.state.lock().unwrap();
        while st.0.len() >= QUEUE_CAP {
            st = self.not_full.wait(st).unwrap();
        }
        st.0.push_back(msg);
        self.not_empty.notify_all();
    }

    fn close(&self) {
        self.state.lock().unwrap().1 = true;
        self.not_empty.notify_all();
    }

    fn recv(&self) -> Option<(i64, i64)> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(m) = st.0.pop_front() {
                self.not_full.notify_all();
                return Some(m);
            }
            if st.1 {
                return None;
            }
            st = self.not_empty.wait(st).unwrap();
        }
    }
}

/// Run the sharded fold over `updates`: each update is a pair of
/// already-quantized contributions, shard `s` owns component `s` (the
/// disjoint element subsets of the real layout). Returns the merged
/// per-shard sums (merge in shard-index order) and each shard's
/// observed stream.
pub fn shard_model(
    updates: &[(i64, i64)],
) -> (Vec<i64>, Vec<Vec<(i64, i64)>>) {
    let chans: Vec<Arc<BoundedChan>> = (0..N_SHARDS)
        .map(|_| Arc::new(BoundedChan::new()))
        .collect();
    let handles: Vec<_> = (0..N_SHARDS)
        .map(|s| {
            let rx = Arc::clone(&chans[s]);
            thread::spawn(move || {
                let mut acc = 0i64;
                let mut seen = Vec::new();
                while let Some(msg) = rx.recv() {
                    acc += if s == 0 { msg.0 } else { msg.1 };
                    seen.push(msg);
                }
                (acc, seen)
            })
        })
        .collect();
    // `push`: broadcast every update to every shard, in order.
    for &u in updates {
        for tx in &chans {
            tx.send(u);
        }
    }
    // `finish`: close the queues, then merge in shard-index order.
    for tx in &chans {
        tx.close();
    }
    let mut merged = Vec::new();
    let mut streams = Vec::new();
    for h in handles {
        let (acc, seen) = h.join().unwrap();
        merged.push(acc);
        streams.push(seen);
    }
    (merged, streams)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Happy path: every interleaving delivers outcomes to the sink
    /// in job-index order, with the reorder buffer bounded by the
    /// window.
    #[test]
    fn loom_window_parking_delivers_in_order() {
        model(|| {
            let (delivered, max_pending, failed) = window_model(None);
            assert_eq!(failed, None);
            assert_eq!(delivered, vec![(0, 10), (1, 20), (2, 30)]);
            assert!(
                max_pending <= WINDOW,
                "reorder buffer exceeded window: {max_pending}"
            );
        });
    }

    /// Error path: a failing job aborts the round without deadlock —
    /// in particular, a worker parked on the window condvar is always
    /// woken (abort is set under the cursor lock). Loom fails this
    /// test on any interleaving that deadlocks or loses a wakeup.
    #[test]
    fn loom_window_parking_abort_terminates() {
        model(|| {
            let (delivered, _, failed) = window_model(Some(0));
            assert_eq!(failed, Some(0));
            assert!(
                delivered.is_empty(),
                "nothing may reach the sink after job 0 failed"
            );
        });
    }

    /// Every interleaving of the bounded fan-out preserves per-shard
    /// stream order and merges (in shard-index order) to exactly the
    /// flat sequential fold — the protocol half of the bit-identity
    /// argument; associativity is the integer fold's half.
    #[test]
    fn loom_shard_queue_merge_matches_flat_fold() {
        model(|| {
            let ups = [(1, 10), (2, 20), (3, 30)];
            let (merged, streams) = shard_model(&ups);
            // Flat fold, same order, no sharding.
            let flat = vec![
                ups.iter().map(|u| u.0).sum::<i64>(),
                ups.iter().map(|u| u.1).sum::<i64>(),
            ];
            assert_eq!(merged, flat);
            for (s, seen) in streams.iter().enumerate() {
                assert_eq!(
                    seen.as_slice(),
                    &ups[..],
                    "shard {s} saw a reordered stream"
                );
            }
        });
    }
}
