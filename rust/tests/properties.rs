//! Property-based tests over the coordinator invariants (DESIGN.md §6)
//! using the in-crate `util::prop` harness — LCD, aggregation,
//! assignment/masks, capacity estimation, partitioning, timing, JSON.

use legend::coordinator::aggregation::{aggregate, DeviceUpdate,
                                       EdgeAggregator,
                                       ShardedAggregator,
                                       StreamingAggregator};
use legend::coordinator::async_engine::{staleness_weight, EventKey,
                                        EventQueue};
use legend::coordinator::capacity::{Capacity, CapacityEstimator};
use legend::coordinator::engine::{train_parallel, ExecOpts, TrainJob};
use legend::coordinator::lcd::{self, LcdDevice, LcdParams};
use legend::coordinator::participation::{DeadlineDrop, Participation,
                                         UniformSample};
use legend::coordinator::serialize::trim_to_rank;
use legend::coordinator::strategy as fedstrategy;
use legend::coordinator::trainer::{DeviceTrainer, LocalOutcome,
                                   MockTrainer};
use legend::coordinator::{run_federated, Codec, FedConfig, ModelMeta};
use legend::data::Spec;
use legend::device::{Fleet, FleetConfig, FleetView, LazyFleet};
use legend::data::{partition, Dataset, Example};
use legend::model::masks::{arithmetic_ranks, LayerSet, LoraConfig};
use legend::model::state::TensorMap;
use legend::model::TensorSpec;
use legend::prop_assert;
use legend::runtime::Masks;
use legend::sim::clock::{simulate_round, DeviceRound};
use legend::util::json::Value;
use legend::util::prop::check;
use legend::util::rng::Rng;

const L: usize = 12;
const R: usize = 16;

fn random_lcd_device(rng: &mut Rng) -> LcdDevice {
    let mu = rng.uniform(0.002, 0.6);
    LcdDevice {
        capacity: Capacity { mu, beta: rng.uniform(0.001, 2.0) },
        fwd_time: 0.26 * mu * L as f64,
        n_batches: rng.range_incl(1, 16),
        compute_budget: if rng.bernoulli(0.3) {
            rng.uniform(0.01, 50.0)
        } else {
            f64::MAX
        },
        comm_budget: if rng.bernoulli(0.3) {
            rng.range(1_000, 10_000_000)
        } else {
            usize::MAX
        },
        unit_rank_bytes: 4 * 128 * 4,
    }
}

#[test]
fn prop_lcd_satisfies_all_constraints() {
    check("lcd-constraints", 256, |rng, _| {
        let n = rng.range_incl(1, 40);
        let devices: Vec<LcdDevice> =
            (0..n).map(|_| random_lcd_device(rng)).collect();
        let params = LcdParams::paper(L, R);
        let cfgs = lcd::determine(&params, &devices);
        prop_assert!(cfgs.len() == n, "one config per device");
        for (c, d) in cfgs.iter().zip(&devices) {
            let depth = c.depth(L);
            prop_assert!((1..=L).contains(&depth), "depth {depth}");
            // eq. (10): monotone non-decreasing ranks.
            for w in c.ranks.windows(2) {
                prop_assert!(w[0] <= w[1], "eq.10: {:?}", c.ranks);
            }
            // eq. (11): total rank within ψ.
            prop_assert!(
                c.ranks.iter().sum::<usize>() <= params.psi,
                "eq.11: {:?}",
                c.ranks
            );
            // eq. (14)/(15) at the assigned depth (when depth > min).
            if depth > params.min_depth {
                let compute = d.n_batches as f64
                    * (d.fwd_time + depth as f64 * d.capacity.mu);
                prop_assert!(
                    compute <= d.compute_budget + 1e-9,
                    "eq.14: {compute} > {}",
                    d.compute_budget
                );
                let bytes: usize = c
                    .active_ranks(L)
                    .iter()
                    .sum::<usize>()
                    * d.unit_rank_bytes;
                prop_assert!(
                    bytes <= d.comm_budget,
                    "eq.15: {bytes} > {}",
                    d.comm_budget
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_lcd_fastest_device_gets_max_depth() {
    check("lcd-fastest-max", 128, |rng, _| {
        let n = rng.range_incl(2, 30);
        let devices: Vec<LcdDevice> = (0..n)
            .map(|_| {
                let mut d = random_lcd_device(rng);
                d.compute_budget = f64::MAX;
                d.comm_budget = usize::MAX;
                d
            })
            .collect();
        let params = LcdParams::paper(L, R);
        let ranks = arithmetic_ranks(L, 1, 1, params.psi, R);
        let cfgs = lcd::determine(&params, &devices);
        let times: Vec<f64> =
            devices.iter().map(|d| d.est_completion(L, &ranks)).collect();
        let fastest = times
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.total_cmp(b.1))
            .unwrap()
            .0;
        prop_assert!(
            cfgs[fastest].depth(L) == L,
            "fastest depth {}",
            cfgs[fastest].depth(L)
        );
        let slowest = times
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .unwrap()
            .0;
        prop_assert!(
            cfgs[slowest].depth(L) <= cfgs[fastest].depth(L),
            "slowest deeper than fastest"
        );
        Ok(())
    });
}

fn random_update(rng: &mut Rng, specs: &[TensorSpec]) -> DeviceUpdate {
    let mut t = TensorMap::zeros(specs);
    for (_, v) in &mut t.entries {
        for x in v.iter_mut() {
            *x = rng.uniform(-2.0, 2.0) as f32;
        }
    }
    let depth = rng.range_incl(1, L);
    let uniform = rng.bernoulli(0.5);
    let ranks = if uniform {
        vec![rng.range_incl(1, R); L]
    } else {
        arithmetic_ranks(L, 1, 1, 200, R)
    };
    DeviceUpdate {
        trainable: t,
        config: LoraConfig { layers: LayerSet::Depth(depth), ranks },
        weight: 1.0,
    }
}

#[test]
fn prop_aggregation_matches_naive_reference() {
    let d = 3usize;
    let specs = vec![
        TensorSpec { name: "aq".into(), shape: vec![L, R, d] },
        TensorSpec { name: "bq".into(), shape: vec![L, d, R] },
        TensorSpec { name: "head_w".into(), shape: vec![d, 4] },
    ];
    check("aggregation-vs-naive", 96, |rng, _| {
        let n = rng.range_incl(1, 12);
        let updates: Vec<DeviceUpdate> =
            (0..n).map(|_| random_update(rng, &specs)).collect();
        let mut global = TensorMap::zeros(&specs);
        for (_, v) in &mut global.entries {
            for x in v.iter_mut() {
                *x = rng.uniform(-1.0, 1.0) as f32;
            }
        }
        let before = global.clone();
        aggregate(&mut global, &updates, L, R);

        // Naive per-element reference using each device's rank mask.
        let masks: Vec<Vec<f32>> =
            updates.iter().map(|u| u.config.rank_mask(L, R)).collect();
        for (spec, got) in &global.entries {
            let old = before.get(&spec.name).unwrap();
            for e in 0..got.len() {
                let (mut acc, mut wsum) = (0f64, 0f64);
                for (u, mask) in updates.iter().zip(&masks) {
                    let m = match spec.name.as_str() {
                        "aq" => {
                            let l = e / (R * d);
                            let j = (e / d) % R;
                            mask[l * R + j] as f64
                        }
                        "bq" => {
                            let l = e / (d * R);
                            let j = e % R;
                            mask[l * R + j] as f64
                        }
                        _ => 1.0,
                    };
                    acc += m * u.trainable.get(&spec.name).unwrap()[e]
                        as f64;
                    wsum += m;
                }
                let want = if wsum > 0.0 {
                    (acc / wsum) as f32
                } else {
                    old[e]
                };
                prop_assert!(
                    (got[e] - want).abs() < 1e-4,
                    "{}[{e}]: {} vs {}",
                    spec.name,
                    got[e],
                    want
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_streaming_aggregator_matches_buffered() {
    // The streaming fold must be ELEMENT-WISE IDENTICAL (bit-exact,
    // not approximately equal) to the buffered one-shot aggregate()
    // on random heterogeneous-depth/rank update sets.
    let d = 3usize;
    let specs = vec![
        TensorSpec { name: "aq".into(), shape: vec![L, R, d] },
        TensorSpec { name: "bq".into(), shape: vec![L, d, R] },
        TensorSpec { name: "head_w".into(), shape: vec![d, 4] },
    ];
    check("streaming-vs-buffered", 96, |rng, _| {
        let n = rng.range_incl(0, 14);
        let mut updates: Vec<DeviceUpdate> =
            (0..n).map(|_| random_update(rng, &specs)).collect();
        for u in &mut updates {
            if rng.bernoulli(0.3) {
                u.weight = rng.uniform(0.1, 4.0);
            }
        }
        let mut global = TensorMap::zeros(&specs);
        for (_, v) in &mut global.entries {
            for x in v.iter_mut() {
                *x = rng.uniform(-1.0, 1.0) as f32;
            }
        }
        let mut buffered = global.clone();
        aggregate(&mut buffered, &updates, L, R);

        let mut agg = StreamingAggregator::new(&global, L, R);
        for u in &updates {
            agg.push(&u.trainable, &u.config, u.weight);
        }
        prop_assert!(agg.n_updates() == n, "push count");
        agg.finish(&mut global);

        for (spec, want) in &buffered.entries {
            let got = global.get(&spec.name).unwrap();
            for (e, (&g, &w)) in
                got.iter().zip(want.iter()).enumerate()
            {
                prop_assert!(
                    g.to_bits() == w.to_bits(),
                    "{}[{e}]: streaming {g} != buffered {w}",
                    spec.name
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_sharded_aggregator_matches_streaming_bitwise() {
    // The per-tensor sharded fold must be ELEMENT-WISE IDENTICAL
    // (bit-exact) to the single-thread StreamingAggregator at every
    // shard count — shards own disjoint element sets and fold the
    // same stream in the same order, so nothing may drift.
    let d = 3usize;
    let specs = vec![
        TensorSpec { name: "aq".into(), shape: vec![L, R, d] },
        TensorSpec { name: "bq".into(), shape: vec![L, d, R] },
        TensorSpec { name: "av".into(), shape: vec![L, R, d] },
        TensorSpec { name: "bv".into(), shape: vec![L, d, R] },
        TensorSpec { name: "head_w".into(), shape: vec![d, 4] },
    ];
    check("sharded-vs-streaming", 24, |rng, _| {
        let n = rng.range_incl(0, 14);
        let mut updates: Vec<DeviceUpdate> =
            (0..n).map(|_| random_update(rng, &specs)).collect();
        for u in &mut updates {
            if rng.bernoulli(0.3) {
                u.weight = rng.uniform(0.1, 4.0);
            }
        }
        let mut global = TensorMap::zeros(&specs);
        for (_, v) in &mut global.entries {
            for x in v.iter_mut() {
                *x = rng.uniform(-1.0, 1.0) as f32;
            }
        }
        let mut streamed = global.clone();
        let mut agg = StreamingAggregator::new(&streamed, L, R);
        for u in &updates {
            agg.push(&u.trainable, &u.config, u.weight);
        }
        agg.finish(&mut streamed);

        for shards in [1usize, 2, 4, 8] {
            let mut sharded = global.clone();
            let mut agg =
                ShardedAggregator::new(&sharded, L, R, shards, 4);
            for u in &updates {
                agg.push(u.trainable.clone(), &u.config, u.weight)
                    .map_err(|e| e.to_string())?;
            }
            prop_assert!(agg.n_updates() == n, "push count");
            agg.finish(&mut sharded).map_err(|e| e.to_string())?;
            for (spec, want) in &streamed.entries {
                let got = sharded.get(&spec.name).unwrap();
                for (e, (&g, &w)) in
                    got.iter().zip(want.iter()).enumerate()
                {
                    prop_assert!(
                        g.to_bits() == w.to_bits(),
                        "{} shards, {}[{e}]: {g} != {w}",
                        shards,
                        spec.name
                    );
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_aggregation_idempotent_on_identical_updates() {
    let specs =
        vec![TensorSpec { name: "aq".into(), shape: vec![L, R, 2] }];
    check("aggregation-idempotent", 64, |rng, _| {
        let u = random_update(rng, &specs);
        let n = rng.range_incl(1, 8);
        let updates = vec![u.clone(); n];
        let mut global = TensorMap::zeros(&specs);
        aggregate(&mut global, &updates, L, R);
        // Averaging n identical updates = the update itself on active
        // slots; inactive slots keep the (zero) global.
        let mask = u.config.rank_mask(L, R);
        let got = global.get("aq").unwrap();
        let x = u.trainable.get("aq").unwrap();
        for e in 0..got.len() {
            let m = mask[e / 2];
            let want = if m > 0.0 { x[e] } else { 0.0 };
            prop_assert!(
                (got[e] - want).abs() < 1e-5,
                "e={e} got {} want {want}",
                got[e]
            );
        }
        Ok(())
    });
}

#[test]
fn prop_masks_consistent_with_config() {
    check("mask-consistency", 256, |rng, _| {
        let depth = rng.range_incl(1, L);
        let ranks: Vec<usize> =
            (0..L).map(|_| rng.range_incl(0, R + 4)).collect();
        let cfg = LoraConfig {
            layers: LayerSet::Depth(depth),
            ranks: ranks.clone(),
        };
        let lm = cfg.layer_mask(L);
        let rm = cfg.rank_mask(L, R);
        prop_assert!(
            lm.iter().map(|&x| x as usize).sum::<usize>() == depth,
            "layer mask count"
        );
        for l in 0..L {
            let row: usize = rm[l * R..(l + 1) * R]
                .iter()
                .map(|&x| x as usize)
                .sum();
            let want = if lm[l] > 0.0 { ranks[l].min(R) } else { 0 };
            prop_assert!(row == want, "layer {l}: {row} vs {want}");
            // Prefix property: ones then zeros.
            let mut seen_zero = false;
            for j in 0..R {
                let v = rm[l * R + j];
                if v == 0.0 {
                    seen_zero = true;
                } else {
                    prop_assert!(!seen_zero, "non-prefix mask row");
                }
            }
        }
        let total: usize = cfg.active_ranks(L).iter().sum();
        prop_assert!(
            total == cfg.total_rank(L),
            "active rank total mismatch"
        );
        Ok(())
    });
}

#[test]
fn prop_capacity_estimate_within_hull() {
    check("capacity-hull", 128, |rng, _| {
        let mut est = CapacityEstimator::paper(1);
        let (mut lo, mut hi) = (f64::MAX, f64::MIN);
        for _ in 0..rng.range_incl(1, 50) {
            let mu = rng.uniform(0.001, 1.0);
            lo = lo.min(mu);
            hi = hi.max(mu);
            est.update(0, mu, 1.0);
            let c = est.get(0).unwrap();
            prop_assert!(
                c.mu >= lo - 1e-12 && c.mu <= hi + 1e-12,
                "estimate {} outside [{lo}, {hi}]",
                c.mu
            );
        }
        Ok(())
    });
}

#[test]
fn prop_partition_conserves_and_respects_min() {
    check("partition", 64, |rng, _| {
        let n_ex = rng.range_incl(100, 800);
        let n_dev = rng.range_incl(2, 16);
        let classes = rng.range_incl(2, 4);
        let ds = Dataset {
            examples: (0..n_ex)
                .map(|i| Example {
                    tokens: vec![i as i32 % 7; 4],
                    label: (i % classes) as i32,
                })
                .collect(),
        };
        let min_shard = 4;
        let how = if rng.bernoulli(0.5) {
            partition::Partition::Dirichlet {
                alpha: rng.uniform(0.05, 50.0),
            }
        } else {
            partition::Partition::Iid
        };
        let shards =
            partition::split(&ds, n_dev, how, classes, min_shard, rng);
        prop_assert!(shards.len() == n_dev, "shard count");
        let total: usize = shards.iter().map(|s| s.len()).sum();
        prop_assert!(total == n_ex, "conservation: {total} vs {n_ex}");
        for s in &shards {
            prop_assert!(s.len() >= min_shard, "min shard violated");
        }
        Ok(())
    });
}

#[test]
fn prop_round_timing_invariants() {
    check("timing", 128, |rng, _| {
        let n = rng.range_incl(1, 40);
        let devices: Vec<DeviceRound> = (0..n)
            .map(|i| DeviceRound {
                device_id: i,
                fwd_time_per_batch: rng.uniform(0.0, 0.5),
                mu: rng.uniform(0.001, 0.5),
                beta: rng.uniform(0.0, 1.0),
                depth: rng.range_incl(1, L),
                ranks: (0..rng.range_incl(1, L))
                    .map(|_| rng.range_incl(1, R))
                    .collect(),
                n_batches: rng.range_incl(1, 20),
                extra_upload_s: rng.uniform(0.0, 1.0),
            })
            .collect();
        let t = simulate_round(&devices);
        prop_assert!(t.avg_waiting >= -1e-12, "negative waiting");
        let max = devices
            .iter()
            .map(|d| d.completion_time())
            .fold(0.0f64, f64::max);
        prop_assert!(
            (t.round_time - max).abs() < 1e-9,
            "round != max completion"
        );
        prop_assert!(
            t.avg_waiting <= t.round_time + 1e-9,
            "waiting > round time"
        );
        Ok(())
    });
}

fn engine_spec() -> Spec {
    let json = r#"{
      "vocab_size": 256, "seq_len": 16,
      "special": {"pad": 0, "cls": 1, "mask": 2, "sep": 3},
      "filler": [4, 50], "noise": [200, 256],
      "tasks": {
        "sst2": {"kind": "single", "n_classes": 2,
                 "banks": [[50, 80], [80, 110]],
                 "len_range": [5, 10], "bank_words": [2, 4],
                 "label_noise": 0.0}
      }
    }"#;
    Spec::from_json(&Value::parse(json).unwrap()).unwrap()
}

fn engine_run_cfg(method: &str, cfg: &FedConfig)
                  -> legend::metrics::RunRecord {
    let meta = ModelMeta::synthetic(L, R, 32);
    let mut s = fedstrategy::by_name(method, L, R, 32).unwrap();
    let fc = FleetConfig { seed: cfg.seed, ..FleetConfig::pretest() };
    let mut fleet: Box<dyn FleetView> = if cfg.lazy_fleet {
        Box::new(LazyFleet::new(fc))
    } else {
        Box::new(Fleet::new(fc))
    };
    let mut trainer = MockTrainer::new(s.family());
    let global = TensorMap::zeros(&[
        TensorSpec {
            name: "aq".into(),
            shape: vec![L, meta.rank_dim(s.family()), 4],
        },
        TensorSpec { name: "head_w".into(), shape: vec![4, 2] },
    ]);
    run_federated(cfg, fleet.as_mut(), s.as_mut(), &mut trainer, &meta,
                  &engine_spec(), global)
    .unwrap()
}

/// Like [`engine_run`]/[`engine_run_async`], but with the scale knobs
/// (`edge_aggregators`, `lazy_fleet`) exposed.
fn engine_run_scaled(method: &str, seed: u64, threads: usize,
                     agg_shards: usize, window: usize, edges: usize,
                     lazy: bool, async_mode: bool)
                     -> legend::metrics::RunRecord {
    let cfg = FedConfig {
        rounds: 3,
        train_size: 256,
        test_size: 64,
        seed,
        threads,
        agg_shards,
        window,
        edge_aggregators: edges,
        lazy_fleet: lazy,
        async_mode,
        staleness_alpha: 0.5,
        max_staleness: if async_mode { 2 } else { 0 },
        ..Default::default()
    };
    engine_run_cfg(method, &cfg)
}

fn engine_run(method: &str, seed: u64, threads: usize,
              agg_shards: usize, window: usize)
              -> legend::metrics::RunRecord {
    let cfg = FedConfig {
        rounds: 3,
        train_size: 256,
        test_size: 64,
        seed,
        threads,
        agg_shards,
        window,
        ..Default::default()
    };
    engine_run_cfg(method, &cfg)
}

fn engine_run_async(method: &str, seed: u64, threads: usize,
                    agg_shards: usize, window: usize, alpha: f64,
                    max_staleness: usize)
                    -> legend::metrics::RunRecord {
    let cfg = FedConfig {
        rounds: 3,
        train_size: 256,
        test_size: 64,
        seed,
        threads,
        agg_shards,
        window,
        async_mode: true,
        staleness_alpha: alpha,
        max_staleness,
        ..Default::default()
    };
    engine_run_cfg(method, &cfg)
}

/// Like [`engine_run`]/[`engine_run_async`], but with the uplink
/// codec exposed (`max_staleness` only read when `async_mode`).
#[allow(clippy::too_many_arguments)]
fn engine_run_codec(method: &str, seed: u64, threads: usize,
                    agg_shards: usize, window: usize, codec: Codec,
                    async_mode: bool, max_staleness: usize)
                    -> legend::metrics::RunRecord {
    let cfg = FedConfig {
        rounds: 3,
        train_size: 256,
        test_size: 64,
        seed,
        threads,
        agg_shards,
        window,
        async_mode,
        staleness_alpha: 0.5,
        max_staleness,
        codec,
        ..Default::default()
    };
    engine_run_cfg(method, &cfg)
}

/// Like [`engine_run_codec`], but with the periodic-re-allocation
/// knobs (`realloc_every`, `realloc_hysteresis`) exposed.
#[allow(clippy::too_many_arguments)]
fn engine_run_realloc(method: &str, seed: u64, threads: usize,
                      agg_shards: usize, window: usize, codec: Codec,
                      async_mode: bool, max_staleness: usize,
                      every: usize, hysteresis: f64)
                      -> legend::metrics::RunRecord {
    let cfg = FedConfig {
        rounds: 3,
        train_size: 256,
        test_size: 64,
        seed,
        threads,
        agg_shards,
        window,
        async_mode,
        staleness_alpha: 0.5,
        max_staleness,
        codec,
        realloc_every: every,
        realloc_hysteresis: hysteresis,
        ..Default::default()
    };
    engine_run_cfg(method, &cfg)
}

/// Zero every plan-epoch field so two records can be compared on the
/// model/timing/traffic trajectory alone (the `--realloc-every 1
/// --realloc-hysteresis 0` run adopts the live estimates each round —
/// identical trajectory, moving epochs).
fn strip_epochs(mut r: legend::metrics::RunRecord)
                -> legend::metrics::RunRecord {
    r.rank_realloc_epochs = 0;
    for round in &mut r.rounds {
        round.plan_epoch = 0;
    }
    r
}

#[test]
fn prop_realloc_off_reproduces_the_static_plan_engine_bitwise() {
    // `--realloc-every 0` must be a bitwise no-op: the live capacity
    // estimates pass straight through to the strategy, reproducing
    // the pre-realloc engines' RunRecord at every threads ×
    // agg-shards × window setting, sync and async, under all three
    // codecs — whatever the hysteresis knob says.
    let methods = ["legend", "fedadapter"];
    let codecs = [Codec::None, Codec::Int8, Codec::Int4];
    check("realloc-off-equivalence", 6, |rng, case| {
        let method = methods[case % methods.len()];
        let codec = codecs[case % codecs.len()];
        let seed = rng.next_u64() % 1_000_003;
        for async_mode in [false, true] {
            let s_max = if async_mode { 2 } else { 0 };
            let base = engine_run_codec(method, seed, 1, 1, 0, codec,
                                        async_mode, s_max);
            let want = base.to_json().to_string();
            prop_assert!(
                base.rank_realloc_epochs == 0
                    && base.rounds.iter().all(|r| r.plan_epoch == 0),
                "{method} seed {seed}: off run moved the plan epoch"
            );
            for (threads, shards, window) in
                [(1usize, 1usize, 0usize), (4, 4, 2), (8, 1, 3)]
            {
                let got = engine_run_realloc(
                    method, seed, threads, shards, window, codec,
                    async_mode, s_max, 0, 0.37);
                prop_assert!(
                    got.to_json().to_string() == want,
                    "{method} {codec:?} seed {seed} \
                     async={async_mode}: realloc-off JSON diverged at \
                     threads={threads} shards={shards} window={window}"
                );
                prop_assert!(
                    got.to_csv_rows() == base.to_csv_rows(),
                    "{method} {codec:?} seed {seed} \
                     async={async_mode}: realloc-off CSV diverged at \
                     threads={threads} shards={shards} window={window}"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_realloc_on_keeps_the_determinism_contract() {
    // With re-allocation enabled the run is still a pure function of
    // the seed: bit-identical RunRecord at every threads × agg-shards
    // × window setting, sync and async — and the refits really
    // happen (the epoch counter moves).
    let methods = ["legend", "hetlora"];
    let codecs = [Codec::None, Codec::Int8];
    check("realloc-on-determinism", 4, |rng, case| {
        let method = methods[case % methods.len()];
        let codec = codecs[case % codecs.len()];
        let seed = rng.next_u64() % 1_000_003;
        for async_mode in [false, true] {
            let s_max = if async_mode { 2 } else { 0 };
            let base = engine_run_realloc(method, seed, 1, 1, 0, codec,
                                          async_mode, s_max, 2, 0.05);
            let want = base.to_json().to_string();
            prop_assert!(
                base.rank_realloc_epochs >= 1,
                "{method} seed {seed} async={async_mode}: no refit \
                 ever adopted on the fading fleet"
            );
            prop_assert!(
                base.rounds.iter().all(|r| r.plan_epoch >= 1),
                "{method} seed {seed}: round 1 always adopts the \
                 first fit"
            );
            for (threads, shards, window) in
                [(4usize, 4usize, 2usize), (2, 8, 1)]
            {
                let got = engine_run_realloc(
                    method, seed, threads, shards, window, codec,
                    async_mode, s_max, 2, 0.05);
                prop_assert!(
                    got.to_json().to_string() == want,
                    "{method} {codec:?} seed {seed} \
                     async={async_mode}: realloc-on JSON diverged at \
                     threads={threads} shards={shards} window={window}"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_realloc_every_round_zero_band_matches_off_trajectory() {
    // `--realloc-every 1 --realloc-hysteresis 0` refits every round
    // and adopts whenever anything moved: the strategy sees exactly
    // the live estimates, so the model/timing/traffic trajectory must
    // match the off run BITWISE — only the plan-epoch bookkeeping may
    // differ (and must actually move).
    let methods = ["legend", "fedlora"];
    check("realloc-live-tracking", 4, |rng, case| {
        let method = methods[case % methods.len()];
        let seed = rng.next_u64() % 1_000_003;
        for async_mode in [false, true] {
            let s_max = if async_mode { 2 } else { 0 };
            let off = engine_run_codec(method, seed, 4, 2, 2,
                                       Codec::None, async_mode, s_max);
            let live = engine_run_realloc(
                method, seed, 4, 2, 2, Codec::None, async_mode, s_max,
                1, 0.0);
            prop_assert!(
                live.rank_realloc_epochs >= 1,
                "{method} seed {seed} async={async_mode}: zero-band \
                 every-round refit never adopted"
            );
            prop_assert!(
                strip_epochs(live).to_json().to_string()
                    == strip_epochs(off).to_json().to_string(),
                "{method} seed {seed} async={async_mode}: live \
                 tracking changed the trajectory"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_trimmed_updates_fold_identically_across_all_paths() {
    // The heterogeneous-rank folding contract end to end: an update
    // stored at its own max active rank (`serialize::trim_to_rank`)
    // must fold BITWISE like its full-rank original through every
    // aggregation path — buffered, streaming, sharded, and the edge
    // tier — because all of them pad through the one rule in
    // `layout::pad_to_rank`.
    let d = 3usize;
    let specs = vec![
        TensorSpec { name: "aq".into(), shape: vec![L, R, d] },
        TensorSpec { name: "bq".into(), shape: vec![L, d, R] },
        TensorSpec { name: "head_w".into(), shape: vec![d, 4] },
    ];
    check("hetero-rank-fold-invariance", 32, |rng, _| {
        let n = rng.range_incl(1, 10);
        let mut updates: Vec<DeviceUpdate> =
            (0..n).map(|_| random_update(rng, &specs)).collect();
        for u in &mut updates {
            if rng.bernoulli(0.3) {
                u.weight = rng.uniform(0.1, 4.0);
            }
        }
        let trimmed: Vec<DeviceUpdate> = updates
            .iter()
            .map(|u| DeviceUpdate {
                trainable: trim_to_rank(&u.trainable, &u.config, L, R),
                config: u.config.clone(),
                weight: u.weight,
            })
            .collect();
        let mut global = TensorMap::zeros(&specs);
        for (_, v) in &mut global.entries {
            for x in v.iter_mut() {
                *x = rng.uniform(-1.0, 1.0) as f32;
            }
        }
        let mut want = global.clone();
        aggregate(&mut want, &updates, L, R);

        let compare = |got: &TensorMap, path: &str| -> Result<(), String> {
            for (spec, w) in &want.entries {
                let g = got.get(&spec.name).unwrap();
                for (e, (&a, &b)) in
                    g.iter().zip(w.iter()).enumerate()
                {
                    prop_assert!(
                        a.to_bits() == b.to_bits(),
                        "{path}: {}[{e}]: trimmed {a} != full {b}",
                        spec.name
                    );
                }
            }
            Ok(())
        };

        let mut got = global.clone();
        let mut agg = StreamingAggregator::new(&got, L, R);
        for u in &trimmed {
            agg.push(&u.trainable, &u.config, u.weight);
        }
        agg.finish(&mut got);
        compare(&got, "streaming")?;

        for shards in [1usize, 4] {
            let mut got = global.clone();
            let mut agg = ShardedAggregator::new(&got, L, R, shards, 4);
            for u in &trimmed {
                agg.push(u.trainable.clone(), &u.config, u.weight)
                    .map_err(|e| e.to_string())?;
            }
            agg.finish(&mut got).map_err(|e| e.to_string())?;
            compare(&got, &format!("sharded-{shards}"))?;
        }

        for edges in [2usize, 3] {
            let mut got = global.clone();
            let mut agg =
                EdgeAggregator::new(&got, L, R, edges, 2, 4, n);
            for u in &trimmed {
                agg.push(u.trainable.clone(), &u.config, u.weight)
                    .map_err(|e| e.to_string())?;
            }
            agg.finish(&mut got).map_err(|e| e.to_string())?;
            compare(&got, &format!("edge-{edges}"))?;
        }
        Ok(())
    });
}

/// Fixed-seed realloc oracle mirroring
/// `async_oracle_emits_canonical_run_record`: CI's determinism job
/// runs this twice in separate processes and diffs the artifact, so
/// per-round re-allocation is held to the same cross-process
/// bit-reproducibility bar as the static-plan engines.
#[test]
fn realloc_oracle_emits_canonical_run_record() {
    let seed = 424_245;
    let sync = engine_run_realloc("legend", seed, 4, 4, 2, Codec::None,
                                  false, 0, 2, 0.05);
    let asy = engine_run_realloc("legend", seed, 4, 4, 2, Codec::Int8,
                                 true, 2, 2, 0.05);
    assert!(sync.rank_realloc_epochs >= 1,
            "oracle run never adopted a refit");
    let doc = format!(
        "{{\"realloc_sync\":{},\"realloc_async_int8_s2\":{}}}",
        sync.to_json(),
        asy.to_json()
    );
    std::fs::create_dir_all("results").unwrap();
    std::fs::write("results/DETERMINISM_realloc.json", doc).unwrap();
}

#[test]
fn prop_engine_output_invariant_under_threads_shards_window() {
    // Same seed ⇒ bit-identical RunRecord at every
    // threads × agg-shards × window setting, for every method (the
    // engine's determinism contract). The baseline is the fully
    // serial path: 1 thread, inline fold, unbounded window.
    let methods =
        ["legend", "fedlora", "hetlora", "legend-no-rd", "fedadapter"];
    let combos: [(usize, usize, usize); 4] =
        [(4, 1, 0), (4, 4, 2), (2, 8, 1), (3, 2, 5)];
    check("engine-threads-shards-window-invariance", 10, |rng, case| {
        let method = methods[case % methods.len()];
        let (threads, shards, window) = combos[case % combos.len()];
        let seed = rng.next_u64() % 1_000_003;
        let a = engine_run(method, seed, 1, 1, 0);
        let b = engine_run(method, seed, threads, shards, window);
        prop_assert!(
            a.to_json().to_string() == b.to_json().to_string(),
            "{method} seed {seed}: JSON differs at threads={threads} \
             shards={shards} window={window}"
        );
        prop_assert!(
            a.to_csv_rows() == b.to_csv_rows(),
            "{method} seed {seed}: CSV differs at threads={threads} \
             shards={shards} window={window}"
        );
        Ok(())
    });
}

#[test]
fn prop_codec_none_is_the_default_wire_bitwise() {
    // `--codec none` must reproduce today's RunRecord bitwise at every
    // threads × agg-shards × window setting, sync and async, eager and
    // lazy fleets — the codec layer is a pure pass-through when off.
    let methods = ["legend", "hetlora", "fedadapter"];
    check("codec-none-pass-through", 6, |rng, case| {
        let method = methods[case % methods.len()];
        let seed = rng.next_u64() % 1_000_003;
        for async_mode in [false, true] {
            let legacy = engine_run_scaled(method, seed, 4, 2, 2, 1,
                                           false, async_mode);
            let coded = engine_run_codec(
                method, seed, 2, 8, 1, Codec::None, async_mode,
                if async_mode { 2 } else { 0 });
            prop_assert!(
                legacy.to_json().to_string()
                    == coded.to_json().to_string(),
                "{method} seed {seed} async={async_mode}: codec=none \
                 JSON diverged from the legacy wire"
            );
            prop_assert!(
                legacy.to_csv_rows() == coded.to_csv_rows(),
                "{method} seed {seed} async={async_mode}: codec=none \
                 CSV diverged from the legacy wire"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_quantized_codec_keeps_the_determinism_contract() {
    // Quantization happens device-side before the fold, so a run is a
    // pure function of (seed, codec): bit-identical RunRecord at every
    // threads × agg-shards × window setting — and the async engine at
    // S = 0 still degenerates to the sync engine bitwise, because the
    // dispatch-time delta reference and the fold-time global coincide
    // when every window waits for its own dispatches.
    let methods = ["legend", "fedlora", "fedadapter"];
    let codecs = [Codec::Int8, Codec::Int4];
    check("codec-determinism", 6, |rng, case| {
        let method = methods[case % methods.len()];
        let codec = codecs[case % codecs.len()];
        let seed = rng.next_u64() % 1_000_003;
        let base =
            engine_run_codec(method, seed, 1, 1, 0, codec, false, 0);
        let want = base.to_json().to_string();
        for (threads, shards, window) in [(4usize, 4usize, 2usize),
                                          (2, 8, 1)] {
            let got = engine_run_codec(method, seed, threads, shards,
                                       window, codec, false, 0);
            prop_assert!(
                got.to_json().to_string() == want,
                "{method} {codec:?} seed {seed}: JSON diverged at \
                 threads={threads} shards={shards} window={window}"
            );
        }
        let asy =
            engine_run_codec(method, seed, 4, 4, 2, codec, true, 0);
        prop_assert!(
            asy.to_json().to_string() == want,
            "{method} {codec:?} seed {seed}: async S=0 diverged from \
             the sync engine under quantization"
        );
        prop_assert!(
            asy.to_csv_rows() == base.to_csv_rows(),
            "{method} {codec:?} seed {seed}: async S=0 CSV diverged"
        );
        Ok(())
    });
}

#[test]
fn quantized_codec_shrinks_uplink_traffic() {
    // Fig-11-style comparison at a fixed seed. The toy engine model is
    // tiny, so per-tensor headers and STATUS_BYTES weigh relatively
    // more than on real dims — the hard ≥ 35% total-traffic floor is
    // enforced on paper-dimension tensors by the bench
    // (`int8_savings_ratio` in BENCH_engine.json, bound in
    // scripts/bench_diff.py); here we check the structural facts.
    let seed = 77;
    let none = engine_run_codec("legend", seed, 1, 1, 0, Codec::None,
                                false, 0);
    let int8 = engine_run_codec("legend", seed, 1, 1, 0, Codec::Int8,
                                false, 0);
    let int4 = engine_run_codec("legend", seed, 1, 1, 0, Codec::Int4,
                                false, 0);
    // Round 1 is decided before any quantization error can feed back
    // through losses, so its assignment traffic must match exactly —
    // assignments always travel f32.
    assert_eq!(none.rounds[0].down_bytes, int8.rounds[0].down_bytes,
               "downlink must be codec-independent");
    assert_eq!(none.rounds[0].down_bytes, int4.rounds[0].down_bytes);
    let up = |r: &legend::metrics::RunRecord| -> usize {
        r.rounds.iter().map(|x| x.up_bytes).sum()
    };
    assert!(up(&int8) < up(&none),
            "int8 uplink {} !< f32 uplink {}", up(&int8), up(&none));
    assert!(up(&int4) < up(&int8),
            "int4 uplink {} !< int8 uplink {}", up(&int4), up(&int8));
    // ~4× on the update payload ⇒ well under half even with status
    // reports and headers riding along.
    assert!(up(&int8) * 2 < up(&none),
            "int8 uplink {} not < half of {}", up(&int8), up(&none));
    let savings =
        1.0 - int8.total_traffic() as f64 / none.total_traffic() as f64;
    assert!(savings >= 0.30,
            "int8 total-traffic savings {savings:.3} < 0.30 even on \
             the toy model");
}

/// Fixed-seed int8 oracle run mirroring
/// `async_oracle_emits_canonical_run_record`: CI's determinism job
/// runs this twice in separate processes and diffs the artifact, so
/// the quantized path is held to the same cross-process
/// bit-reproducibility bar as the raw-f32 wire.
#[test]
fn codec_int8_emits_canonical_run_record() {
    let seed = 424_244;
    let sync =
        engine_run_codec("legend", seed, 4, 4, 2, Codec::Int8, false, 0);
    let asy =
        engine_run_codec("legend", seed, 4, 4, 2, Codec::Int8, true, 2);
    let doc = format!(
        "{{\"int8\":{},\"int8_async_s2\":{}}}",
        sync.to_json(),
        asy.to_json()
    );
    std::fs::create_dir_all("results").unwrap();
    std::fs::write("results/DETERMINISM_codec_int8.json", doc).unwrap();
}

#[test]
fn prop_lazy_fleet_matches_eager_fleet_bitwise() {
    // A LazyFleet derives every per-device quantity on demand from
    // (seed, device_id) counter streams; the eager Fleet materializes
    // the same streams up front. Both must agree BITWISE — profiles
    // (μ, β via the DVFS mode), AR(1) fading across rounds, forward
    // times, and the noisy capacity observations — on the 80- and
    // 256-device paper-proportioned configs, probed in the same
    // interleaved order a round loop would use.
    check("lazy-fleet-bitwise", 8, |rng, case| {
        let n = [80usize, 256][case % 2];
        let seed = rng.next_u64() % 1_000_003;
        let fc = FleetConfig { seed, ..FleetConfig::sized(n) };
        let mut eager = Fleet::new(fc.clone());
        let mut lazy = LazyFleet::new(fc);
        prop_assert!(eager.len() == n && lazy.len() == n, "len");
        let unit = 4 * 128 * 4;
        for round in 0..5usize {
            if round > 0 {
                eager.advance_round();
                lazy.advance_round();
            }
            // Probe a scattered cohort, not just a prefix: the lazy
            // derivation must not depend on visiting devices in order.
            for &i in &[0, 1, n / 3, n / 2, n - 2, n - 1] {
                prop_assert!(
                    eager.true_mu(i).to_bits() == lazy.true_mu(i).to_bits(),
                    "μ diverged at device {i} round {round} seed {seed}"
                );
                prop_assert!(
                    eager.true_beta(i, unit).to_bits()
                        == lazy.true_beta(i, unit).to_bits(),
                    "β diverged at device {i} round {round} seed {seed}"
                );
                prop_assert!(
                    eager.forward_time(i, L).to_bits()
                        == lazy.forward_time(i, L).to_bits(),
                    "fwd diverged at device {i} round {round} seed {seed}"
                );
                let (mu_a, beta_a) = eager.observe(i, unit);
                let (mu_b, beta_b) = lazy.observe(i, unit);
                prop_assert!(
                    mu_a.to_bits() == mu_b.to_bits()
                        && beta_a.to_bits() == beta_b.to_bits(),
                    "μ̂/β̂ diverged at device {i} round {round} seed {seed}"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_lazy_fleet_run_record_matches_eager_bitwise() {
    // End-to-end: a federated run over a LazyFleet reproduces the
    // eager fleet's RunRecord BITWISE at the same seed — sync and
    // async, under concurrency (threads/shards/window) and with the
    // edge tier on — so `--lazy` is purely a memory optimization.
    let methods = ["legend", "fedlora", "fedadapter"];
    check("lazy-fleet-run-invariance", 6, |rng, case| {
        let method = methods[case % methods.len()];
        let seed = rng.next_u64() % 1_000_003;
        for async_mode in [false, true] {
            let eager = engine_run_scaled(method, seed, 1, 1, 0, 1,
                                          false, async_mode);
            let lazy = engine_run_scaled(method, seed, 4, 2, 2, 4,
                                         true, async_mode);
            prop_assert!(
                eager.to_json().to_string() == lazy.to_json().to_string(),
                "{method} seed {seed} async={async_mode}: lazy JSON \
                 diverged from eager"
            );
            prop_assert!(
                eager.to_csv_rows() == lazy.to_csv_rows(),
                "{method} seed {seed} async={async_mode}: lazy CSV \
                 diverged from eager"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_edge_aggregators_reproduce_flat_fold_bitwise() {
    // The hierarchical edge tier partitions the cohort into E
    // deterministic slices, folds each on its own edge aggregator and
    // merges the edges ascending at the root. Because the fold runs in
    // fixed point, every E ∈ {2,4,8} must reproduce the flat (E = 1)
    // RunRecord BITWISE — sync and async.
    let methods = ["legend", "hetlora", "fedadapter"];
    check("edge-tier-invariance", 6, |rng, case| {
        let method = methods[case % methods.len()];
        let seed = rng.next_u64() % 1_000_003;
        for async_mode in [false, true] {
            let flat = engine_run_scaled(method, seed, 1, 1, 0, 1,
                                         false, async_mode);
            let want = flat.to_json().to_string();
            for edges in [2usize, 4, 8] {
                let got = engine_run_scaled(method, seed, 4, 2, 2,
                                            edges, false, async_mode);
                prop_assert!(
                    got.to_json().to_string() == want,
                    "{method} seed {seed} async={async_mode}: edge \
                     tier E={edges} diverged from the flat fold"
                );
                prop_assert!(
                    got.to_csv_rows() == flat.to_csv_rows(),
                    "{method} seed {seed} async={async_mode}: edge \
                     tier E={edges} CSV diverged"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_async_max_staleness_zero_matches_sync_engine_bitwise() {
    // The sync-degeneracy oracle: with max_staleness = 0 every commit
    // window waits for all of its own dispatches, so the async engine
    // must reproduce RoundEngine::run's RunRecord BITWISE — same JSON,
    // same CSV — at 1/4/8 threads × 1/4 agg-shards, for every method
    // (including FedAdapter, which exercises the new staleness field).
    let methods =
        ["legend", "fedlora", "hetlora", "legend-no-rd", "fedadapter"];
    let alphas = [0.0, 0.5, 3.0];
    check("async-sync-oracle", 5, |rng, case| {
        let method = methods[case % methods.len()];
        let alpha = alphas[case % alphas.len()];
        let seed = rng.next_u64() % 1_000_003;
        let sync = engine_run(method, seed, 1, 1, 0);
        let want_json = sync.to_json().to_string();
        let want_csv = sync.to_csv_rows();
        for threads in [1usize, 4, 8] {
            for shards in [1usize, 4] {
                // Alternate the in-flight window too: the contract
                // covers threads × agg-shards × window.
                let window = if shards == 1 { 0 } else { 2 };
                let asy = engine_run_async(method, seed, threads,
                                           shards, window, alpha, 0);
                prop_assert!(
                    asy.to_json().to_string() == want_json,
                    "{method} seed {seed} α={alpha}: async S=0 JSON \
                     diverged at threads={threads} shards={shards} \
                     window={window}"
                );
                prop_assert!(
                    asy.to_csv_rows() == want_csv,
                    "{method} seed {seed} α={alpha}: async S=0 CSV \
                     diverged at threads={threads} shards={shards} \
                     window={window}"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_async_output_invariant_under_threads_and_shards() {
    // The determinism contract for the genuinely asynchronous path
    // (S > 0): a fixed seed yields a bit-identical RunRecord at every
    // thread count and shard count.
    let methods = ["legend", "fedlora", "fedadapter"];
    check("async-threads-shards-invariance", 5, |rng, case| {
        let method = methods[case % methods.len()];
        let seed = rng.next_u64() % 1_000_003;
        let base = engine_run_async(method, seed, 1, 1, 0, 0.5, 2);
        let want = base.to_json().to_string();
        for (threads, shards, window) in
            [(4usize, 1usize, 0usize), (8, 4, 2), (2, 8, 1)]
        {
            let got = engine_run_async(method, seed, threads, shards,
                                       window, 0.5, 2);
            prop_assert!(
                got.to_json().to_string() == want,
                "{method} seed {seed}: async S=2 diverged at \
                 threads={threads} shards={shards} window={window}"
            );
        }
        // Sanity: the asynchronous run really differs from the
        // barrier run (it is not the degenerate path in disguise) —
        // on the heterogeneous pretest fleet the first commit window
        // closes at the earliest completion, not the straggler's.
        let sync = engine_run(method, seed, 1, 1, 0);
        prop_assert!(
            base.to_json().to_string() != sync.to_json().to_string(),
            "{method} seed {seed}: S=2 run is identical to the \
             barrier run"
        );
        prop_assert!(
            base.rounds[0].round_time
                <= sync.rounds[0].round_time + 1e-9,
            "{method} seed {seed}: first async window ({}) outlasted \
             the first barrier round ({})",
            base.rounds[0].round_time,
            sync.rounds[0].round_time
        );
        Ok(())
    });
}

#[test]
fn prop_staleness_weights_monotone_and_clamped() {
    check("staleness-weight-laws", 256, |rng, _| {
        let alpha = rng.uniform(0.0, 5.0);
        let s = rng.range_incl(0, 12);
        // Fresh updates fold at exactly weight 1 (the bitwise
        // sync-degeneracy hinges on this).
        prop_assert!(
            staleness_weight(0, s, alpha).to_bits() == 1.0f64.to_bits(),
            "w(0) must be exactly 1.0"
        );
        let mut prev = f64::INFINITY;
        for tau in 0..=(s + 4) {
            let w = staleness_weight(tau, s, alpha);
            prop_assert!(
                w <= prev,
                "α={alpha} S={s}: w({tau})={w} > w({})={prev}",
                tau.saturating_sub(1)
            );
            if tau <= s {
                prop_assert!(w > 0.0, "in-window weight vanished");
                let want = (1.0 + tau as f64).powf(-alpha);
                prop_assert!(
                    tau == 0 || w.to_bits() == want.to_bits(),
                    "α={alpha}: w({tau})={w} != formula {want}"
                );
            } else {
                prop_assert!(
                    w == 0.0,
                    "τ={tau} beyond S={s} must clamp to 0, got {w}"
                );
            }
            prev = w;
        }
        Ok(())
    });
}

#[test]
fn prop_async_fold_order_invariant_under_permuted_event_log() {
    // Push the same completion events in a random order — with
    // duplicated timestamps to force ties — and fold the pop stream
    // into a StreamingAggregator. The (time, device_id) tie-break
    // makes the pop order (and therefore the fold) a pure function of
    // the event set: every permutation must produce a bit-identical
    // global.
    let d = 3usize;
    let specs = vec![
        TensorSpec { name: "aq".into(), shape: vec![L, R, d] },
        TensorSpec { name: "bq".into(), shape: vec![L, d, R] },
        TensorSpec { name: "head_w".into(), shape: vec![d, 4] },
    ];
    check("async-event-order-invariance", 48, |rng, _| {
        let n = rng.range_incl(1, 12);
        // A small time alphabet guarantees timestamp collisions.
        let times: Vec<f64> =
            (0..n).map(|_| rng.range_incl(0, 3) as f64 * 0.5).collect();
        let updates: Vec<DeviceUpdate> =
            (0..n).map(|_| random_update(rng, &specs)).collect();
        let weights: Vec<f64> =
            (0..n).map(|i| staleness_weight(i % 3, 4, 0.7)).collect();

        let fold_permuted = |order: &[usize]| -> TensorMap {
            let mut q: EventQueue<usize> = EventQueue::new();
            for &e in order {
                q.push(
                    EventKey { time: times[e], device_id: e },
                    e,
                );
            }
            let mut global = TensorMap::zeros(&specs);
            let mut agg = StreamingAggregator::new(&global, L, R);
            let mut popped = Vec::new();
            while let Some((k, e)) = q.pop() {
                popped.push(k);
                agg.push(&updates[e].trainable, &updates[e].config,
                         weights[e]);
            }
            // Pop order is (time, device_id)-sorted regardless of
            // push order.
            for w in popped.windows(2) {
                assert!(w[0] < w[1], "pop order violated: {w:?}");
            }
            agg.finish(&mut global);
            global
        };

        let canonical: Vec<usize> = (0..n).collect();
        let want = fold_permuted(&canonical);
        for _ in 0..3 {
            let mut perm = canonical.clone();
            rng.shuffle(&mut perm);
            let got = fold_permuted(&perm);
            for (spec, a) in &want.entries {
                let b = got.get(&spec.name).unwrap();
                for (e, (&x, &y)) in a.iter().zip(b.iter()).enumerate() {
                    prop_assert!(
                        x.to_bits() == y.to_bits(),
                        "{}[{e}]: {x} != {y} after permutation",
                        spec.name
                    );
                }
            }
        }
        Ok(())
    });
}

/// Fixed-seed oracle run that also emits the async RunRecord JSON —
/// CI's determinism job runs this test twice and diffs the artifact
/// across processes (catching any hidden wall-clock/thread/HashMap
/// nondeterminism the in-process property tests cannot).
#[test]
fn async_oracle_emits_canonical_run_record() {
    let seed = 424_243;
    let sync = engine_run("legend", seed, 1, 1, 0);
    let asy = engine_run_async("legend", seed, 4, 4, 2, 0.5, 0);
    assert_eq!(asy.to_json().to_string(), sync.to_json().to_string(),
               "async S=0 must reproduce the sync engine bitwise");
    // A genuinely async record rides along so the CI diff also covers
    // the S > 0 path.
    let stale = engine_run_async("legend", seed, 4, 4, 2, 0.5, 2);
    let doc = format!(
        "{{\"oracle\":{},\"async_s2\":{}}}",
        asy.to_json(),
        stale.to_json()
    );
    std::fs::create_dir_all("results").unwrap();
    std::fs::write("results/DETERMINISM_async_oracle.json", doc)
        .unwrap();
}

/// Adversarial completion order: job 0 straggles while everything
/// else finishes instantly — the order that used to grow the reorder
/// buffer to the whole cohort.
struct StaggeredDevice {
    delay_ms: u64,
}

impl DeviceTrainer for StaggeredDevice {
    fn train_local(&mut self, job: &TrainJob<'_>)
                   -> anyhow::Result<LocalOutcome> {
        std::thread::sleep(std::time::Duration::from_millis(
            self.delay_ms,
        ));
        Ok(LocalOutcome {
            trainable: job.init.clone(),
            mean_loss: job.device_id as f64,
            train_accuracy: 0.0,
            n_steps: 1,
        })
    }
}

#[test]
fn window_bounds_reorder_buffer_under_adversarial_completion() {
    let n = 24usize;
    let init = TensorMap::zeros(&[TensorSpec {
        name: "aq".into(),
        shape: vec![2, 2, 2],
    }]);
    let shard = Dataset {
        examples: vec![Example { tokens: vec![1, 2, 3, 0], label: 0 }],
    };
    let masks = Masks {
        rank_mask: vec![1.0; 4],
        layer_mask: vec![1.0; 2],
    };
    for window in [1usize, 2, 4, 7, 0] {
        let jobs: Vec<TrainJob<'_>> = (0..n)
            .map(|i| TrainJob {
                device_id: i,
                init: &init,
                masks: masks.clone(),
                shard: &shard,
                lr: 1e-3,
                max_batches: 1,
            })
            .collect();
        let mut handles: Vec<StaggeredDevice> = (0..n)
            .map(|i| StaggeredDevice {
                delay_ms: if i == 0 { 40 } else { 0 },
            })
            .collect();
        let mut seen: Vec<(usize, f64)> = Vec::new();
        let stats = train_parallel(
            &jobs,
            &mut handles,
            &ExecOpts { threads: 8, window },
            &mut |k, out| {
                seen.push((k, out.mean_loss));
                Ok(())
            },
        )
        .unwrap();
        // The hard bound: completed-but-undelivered outcomes never
        // exceed W (W = 0 is unbounded, but still ≤ cohort).
        let bound = if window > 0 { window } else { n };
        assert!(
            stats.max_pending <= bound,
            "window {window}: max_pending {} > {bound}",
            stats.max_pending
        );
        // Delivery is in job-index order with the right outcomes, at
        // every window setting.
        assert_eq!(seen.len(), n, "window {window}");
        for (k, (got_k, loss)) in seen.iter().enumerate() {
            assert_eq!(*got_k, k, "window {window}: order");
            assert_eq!(*loss, k as f64, "window {window}: outcome");
        }
    }
}

#[test]
fn prop_participation_cohorts_are_valid() {
    check("participation-valid", 128, |rng, _| {
        let n = rng.range_incl(1, 120);
        let mut p = UniformSample { fraction: rng.uniform(0.0, 1.2) };
        let cohort = p.sample(rng.range_incl(1, 50), n, rng);
        prop_assert!(!cohort.is_empty(), "empty cohort");
        prop_assert!(
            cohort.windows(2).all(|w| w[0] < w[1]),
            "cohort not sorted/unique"
        );
        prop_assert!(cohort.iter().all(|&i| i < n), "out of range");

        let predicted: Vec<f64> =
            cohort.iter().map(|_| rng.uniform(0.1, 100.0)).collect();
        let mut d = DeadlineDrop::new(rng.uniform(0.01, 3.0));
        let admitted = d.admit(1, &cohort, &predicted);
        prop_assert!(!admitted.is_empty(), "deadline emptied the round");
        prop_assert!(
            admitted.iter().all(|i| cohort.contains(i)),
            "admitted ⊄ cohort"
        );
        let mut sorted = admitted.clone();
        sorted.sort_unstable();
        sorted.dedup();
        prop_assert!(sorted.len() == admitted.len(), "duplicates");
        Ok(())
    });
}

#[test]
fn prop_json_roundtrip_random_values() {
    fn random_value(rng: &mut Rng, depth: usize) -> Value {
        match if depth == 0 { rng.range(0, 4) } else { rng.range(0, 6) } {
            0 => Value::Null,
            1 => Value::Bool(rng.bernoulli(0.5)),
            2 => Value::Num(
                (rng.uniform(-1e6, 1e6) * 100.0).round() / 100.0,
            ),
            3 => {
                let n = rng.range(0, 12);
                Value::Str(
                    (0..n)
                        .map(|_| {
                            char::from_u32(rng.range(32, 1000) as u32)
                                .unwrap_or('x')
                        })
                        .collect(),
                )
            }
            4 => Value::Arr(
                (0..rng.range(0, 5))
                    .map(|_| random_value(rng, depth - 1))
                    .collect(),
            ),
            _ => Value::Obj(
                (0..rng.range(0, 5))
                    .map(|i| {
                        (format!("k{i}"), random_value(rng, depth - 1))
                    })
                    .collect(),
            ),
        }
    }
    check("json-roundtrip", 256, |rng, _| {
        let v = random_value(rng, 3);
        let text = v.to_string();
        let parsed = Value::parse(&text)
            .map_err(|e| format!("parse failed on {text}: {e}"))?;
        prop_assert!(parsed == v, "roundtrip mismatch: {text}");
        Ok(())
    });
}

/// A 2-job scheduler run (rate-limited LEGEND + sampling FedLoRA over
/// the pretest fleet) with the fleet flavor and concurrency knobs
/// exposed — the multi-job analogue of [`engine_run_scaled`]. The
/// full invariant suite lives in `tests/multi_job.rs`; here the
/// scheduler is held to the two contracts this file owns: lazy ≡
/// eager bitwise, and invariance under the concurrency knobs.
fn multi_job_records(seed: u64, lazy: bool, threads: usize,
                     shards: usize, window: usize)
                     -> std::collections::BTreeMap<
                         usize, legend::metrics::RunRecord> {
    use legend::coordinator::participation::UniformCount;
    use legend::coordinator::{JobScheduler, JobSpec, RateLimit};
    let meta = ModelMeta::synthetic(L, R, 32);
    let mut sched = JobScheduler::new(meta.clone(), engine_spec(), 10);
    for (j, (method, rate)) in
        [("legend", Some(RateLimit { burst: 2, refill: 1 })),
         ("fedlora", None)]
        .into_iter()
        .enumerate()
    {
        let cfg = FedConfig {
            rounds: 3,
            train_size: 256,
            test_size: 64,
            seed: seed + j as u64,
            threads,
            agg_shards: shards,
            window,
            ..Default::default()
        };
        let mut spec = JobSpec::new(cfg);
        spec.rate = rate;
        let s = fedstrategy::by_name(method, L, R, 32).unwrap();
        let family = s.family();
        let global = TensorMap::zeros(&[
            TensorSpec {
                name: "aq".into(),
                shape: vec![L, meta.rank_dim(family), 4],
            },
            TensorSpec { name: "head_w".into(), shape: vec![4, 2] },
        ]);
        sched
            .admit(spec, s, Box::new(MockTrainer::new(family)),
                   Box::new(UniformCount { count: 4 }), global)
            .unwrap();
    }
    let fc = FleetConfig { seed, ..FleetConfig::pretest() };
    let mut fleet: Box<dyn FleetView> = if lazy {
        Box::new(LazyFleet::new(fc))
    } else {
        Box::new(Fleet::new(fc))
    };
    sched.run(fleet.as_mut()).unwrap().records
}

#[test]
fn prop_multi_job_run_is_a_pure_function_of_the_seed() {
    // The multi-job scheduler inherits the engines' determinism
    // contract wholesale: per-job RunRecords are bit-identical
    // between eager and lazy fleets and at every threads ×
    // agg-shards × window setting.
    check("multi-job-lazy-eager-invariance", 5, |rng, case| {
        let seed = rng.next_u64() % 1_000_003;
        let base = multi_job_records(seed, false, 1, 1, 0);
        prop_assert!(base.len() == 2, "two jobs, two records");
        let (threads, shards, window) =
            [(4usize, 2usize, 2usize), (2, 8, 1), (3, 2, 5)]
                [case % 3];
        for lazy in [false, true] {
            let got =
                multi_job_records(seed, lazy, threads, shards, window);
            for (id, want) in &base {
                prop_assert!(
                    want.to_json().to_string()
                        == got[id].to_json().to_string(),
                    "seed {seed} job {id} lazy={lazy}: JSON diverged \
                     at threads={threads} shards={shards} \
                     window={window}"
                );
                prop_assert!(
                    want.to_csv_rows() == got[id].to_csv_rows(),
                    "seed {seed} job {id} lazy={lazy}: CSV diverged \
                     at threads={threads} shards={shards} \
                     window={window}"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_rng_range_bounds() {
    check("rng-ranges", 256, |rng, _| {
        let lo = rng.range(0, 1000);
        let hi = lo + rng.range(1, 1000);
        for _ in 0..20 {
            let x = rng.range(lo, hi);
            prop_assert!((lo..hi).contains(&x), "{x} not in {lo}..{hi}");
            let y = rng.range_incl(lo, hi);
            prop_assert!(
                (lo..=hi).contains(&y),
                "{y} not in {lo}..={hi}"
            );
        }
        Ok(())
    });
}
