//! Coordinator integration tests: the full PS round loop over the
//! 80-device fleet with the mock trainer (fast, no artifacts), plus a
//! real-PJRT mini federated run when artifacts are present.

use legend::coordinator::participation::{DeadlineDrop, UniformCount,
                                         UniformSample};
use legend::coordinator::strategy::{self, Strategy};
use legend::coordinator::trainer::{MockTrainer, PjrtTrainer};
use legend::coordinator::{run_federated, run_federated_with, FedConfig,
                          ModelMeta};
use legend::data::Spec;
use legend::device::{Fleet, FleetConfig, FleetView, LazyFleet};
use legend::metrics::RunRecord;
use legend::model::state::TensorMap;
use legend::model::TensorSpec;
use legend::runtime::Runtime;
use legend::util::json::Value;

fn toy_spec() -> Spec {
    let json = r#"{
      "vocab_size": 256, "seq_len": 16,
      "special": {"pad": 0, "cls": 1, "mask": 2, "sep": 3},
      "filler": [4, 50], "noise": [200, 256],
      "tasks": {
        "sst2": {"kind": "single", "n_classes": 2,
                 "banks": [[50, 80], [80, 110]],
                 "len_range": [5, 10], "bank_words": [2, 4],
                 "label_noise": 0.0}
      }
    }"#;
    Spec::from_json(&Value::parse(json).unwrap()).unwrap()
}

fn toy_global(meta: &ModelMeta, rank_dim: usize) -> TensorMap {
    TensorMap::zeros(&[
        TensorSpec {
            name: "aq".into(),
            shape: vec![meta.n_layers, rank_dim, 4],
        },
        TensorSpec { name: "head_w".into(), shape: vec![4, 2] },
    ])
}

fn mock_run_cfg(method: &str, rounds: usize, threads: usize,
                agg_shards: usize, window: usize) -> RunRecord {
    let meta = ModelMeta::synthetic(12, 16, 32);
    let mut s =
        strategy::by_name(method, meta.n_layers, meta.r_max, meta.w_max)
            .unwrap();
    let family = s.family();
    let rank_dim = meta.rank_dim(family);
    let mut fleet = Fleet::new(FleetConfig::paper()); // all 80 devices
    let mut trainer = MockTrainer::new(family);
    let cfg = FedConfig {
        rounds,
        train_size: 2048,
        test_size: 64,
        threads,
        agg_shards,
        window,
        ..Default::default()
    };
    run_federated(&cfg, &mut fleet, s.as_mut(), &mut trainer, &meta,
                  &toy_spec(), toy_global(&meta, rank_dim))
    .unwrap()
}

fn mock_run(method: &str, rounds: usize) -> RunRecord {
    mock_run_cfg(method, rounds, 0, 1, 0)
}

fn mock_run_async(method: &str, rounds: usize, alpha: f64,
                  max_staleness: usize) -> RunRecord {
    let meta = ModelMeta::synthetic(12, 16, 32);
    let mut s =
        strategy::by_name(method, meta.n_layers, meta.r_max, meta.w_max)
            .unwrap();
    let family = s.family();
    let rank_dim = meta.rank_dim(family);
    let mut fleet = Fleet::new(FleetConfig::paper());
    let mut trainer = MockTrainer::new(family);
    let cfg = FedConfig {
        rounds,
        train_size: 2048,
        test_size: 64,
        async_mode: true,
        staleness_alpha: alpha,
        max_staleness,
        ..Default::default()
    };
    run_federated(&cfg, &mut fleet, s.as_mut(), &mut trainer, &meta,
                  &toy_spec(), toy_global(&meta, rank_dim))
    .unwrap()
}

#[test]
fn all_methods_complete_on_the_paper_fleet() {
    for method in ["legend", "legend-no-ld", "legend-no-rd", "fedlora",
                   "hetlora", "fedadapter"] {
        let rec = mock_run(method, 6);
        assert_eq!(rec.rounds.len(), 6, "{method}");
        assert!(rec.rounds.iter().all(|r| r.round_time > 0.0), "{method}");
        assert!(rec.rounds.iter().all(|r| r.up_bytes > 0), "{method}");
        assert!(rec.final_accuracy() > 0.0, "{method}");
    }
}

#[test]
fn paper_orderings_hold_on_the_80_device_fleet() {
    let legend = mock_run("legend", 10);
    let fedlora = mock_run("fedlora", 10);
    let hetlora = mock_run("hetlora", 10);
    // Fig. 12 ordering: LEGEND waits least, FedLoRA most.
    assert!(legend.mean_waiting() < hetlora.mean_waiting());
    assert!(legend.mean_waiting() < fedlora.mean_waiting());
    // Fig. 11 ordering: LEGEND moves the fewest bytes per round.
    assert!(legend.total_traffic() < fedlora.total_traffic());
    // Round time: LEGEND's rounds are shorter (eq. 12 driven).
    assert!(legend.total_time() < fedlora.total_time());
}

#[test]
fn legend_depth_adapts_while_fedlora_is_flat() {
    let legend = mock_run("legend", 8);
    let fedlora = mock_run("fedlora", 8);
    let ld = legend.rounds.last().unwrap().mean_depth;
    let fd = fedlora.rounds.last().unwrap().mean_depth;
    assert!(ld < 12.0, "LEGEND mean depth {ld} should be < L");
    assert!((fd - 12.0).abs() < 1e-9, "FedLoRA depth {fd} must be L");
}

#[test]
fn deterministic_given_seed() {
    let a = mock_run("legend", 5);
    let b = mock_run("legend", 5);
    for (x, y) in a.rounds.iter().zip(&b.rounds) {
        assert_eq!(x.up_bytes, y.up_bytes);
        assert!((x.sim_time - y.sim_time).abs() < 1e-9);
        assert!((x.avg_waiting - y.avg_waiting).abs() < 1e-9);
    }
}

#[test]
fn run_record_bit_identical_across_threads_shards_window() {
    // Acceptance: a fixed seed produces identical RunRecord JSON on
    // the full 80-device fleet whether the engine runs fully serial
    // (1 thread, inline fold, unbounded window) or fully concurrent
    // (8 threads, sharded fold, tight in-flight window).
    let seq = mock_run_cfg("legend", 5, 1, 1, 0);
    for (threads, shards, window) in
        [(8, 1, 0), (8, 4, 4), (4, 0, 2), (8, 2, 64)]
    {
        let par = mock_run_cfg("legend", 5, threads, shards, window);
        assert_eq!(seq.to_json().to_string(), par.to_json().to_string(),
                   "threads={threads} shards={shards} window={window}");
        assert_eq!(seq.to_csv_rows(), par.to_csv_rows());
        for (a, b) in seq.rounds.iter().zip(&par.rounds) {
            assert_eq!(a.up_bytes, b.up_bytes);
            assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits());
            assert_eq!(a.test_acc.to_bits(), b.test_acc.to_bits());
            assert_eq!(a.sim_time.to_bits(), b.sim_time.to_bits());
        }
    }
}

#[test]
fn client_sampling_completes_on_the_paper_fleet() {
    let meta = ModelMeta::synthetic(12, 16, 32);
    let mut s = strategy::by_name("legend", 12, 16, 32).unwrap();
    let mut fleet = Fleet::new(FleetConfig::paper());
    let mut trainer = MockTrainer::new("lora");
    let cfg = FedConfig {
        rounds: 5,
        train_size: 2048,
        test_size: 64,
        ..Default::default()
    };
    let rec = run_federated_with(
        &cfg, &mut fleet, s.as_mut(), &mut trainer, &meta, &toy_spec(),
        toy_global(&meta, 16),
        &mut UniformSample { fraction: 0.25 },
    )
    .unwrap();
    assert_eq!(rec.rounds.len(), 5);
    // ⌈0.25 · 80⌉ = 20 devices per round, every round.
    assert!(rec.rounds.iter().all(|r| r.participants == 20));
    assert!((rec.mean_participation() - 20.0).abs() < 1e-12);
    assert!(rec.rounds.iter().all(|r| r.up_bytes > 0));
    // Distinct cohorts across rounds ⇒ traffic varies with the
    // sampled devices' heterogeneous configs.
    assert!(rec.final_accuracy() > 0.0);
}

#[test]
fn fedadapter_semi_sync_run_completes_with_drops() {
    // Regression for the stale-loss cohort feedback: a semi-sync run
    // (deadline drops most rounds) with the search-based strategy must
    // complete with sane records — deadline-dropped devices no longer
    // fold phantom loss-drops into the candidate scores, because their
    // stale losses surface as 0 and id-keyed feedback skips them.
    let meta = ModelMeta::synthetic(12, 16, 32);
    let mut s = strategy::by_name("fedadapter", 12, 16, 32).unwrap();
    let rank_dim = meta.rank_dim(s.family());
    let mut fleet = Fleet::new(FleetConfig::paper());
    let mut trainer = MockTrainer::new(s.family());
    let cfg = FedConfig {
        rounds: 6,
        train_size: 2048,
        test_size: 64,
        ..Default::default()
    };
    let rec = run_federated_with(
        &cfg, &mut fleet, s.as_mut(), &mut trainer, &meta, &toy_spec(),
        toy_global(&meta, rank_dim),
        &mut DeadlineDrop::new(1.05),
    )
    .unwrap();
    assert_eq!(rec.rounds.len(), 6);
    assert!(rec.rounds.iter().any(|r| r.dropped > 0),
            "tight deadline on the heterogeneous fleet must drop");
    assert!(rec.rounds.iter().all(|r| r.participants > 0));
    assert!(rec.final_accuracy() > 0.0);
}

#[test]
fn async_engine_completes_on_the_paper_fleet() {
    for method in ["legend", "fedlora", "fedadapter"] {
        let rec = mock_run_async(method, 8, 0.5, 2);
        assert_eq!(rec.rounds.len(), 8, "{method}");
        // Every commit window folds at least one update (the progress
        // guarantee) and accounts its uplink.
        assert!(rec.rounds.iter().all(|r| r.participants >= 1),
                "{method}");
        assert!(rec.rounds.iter().all(|r| r.up_bytes > 0), "{method}");
        // Virtual time never runs backwards.
        for w in rec.rounds.windows(2) {
            assert!(w[1].sim_time >= w[0].sim_time - 1e-12, "{method}");
        }
        // Genuine asynchrony on the heterogeneous 80-device fleet: the
        // first window commits at the earliest completion, long before
        // the whole cohort lands.
        assert!(rec.rounds[0].participants < 80,
                "{method}: first window waited for the full cohort");
        assert!(rec.final_accuracy() > 0.0, "{method}");
    }
}

#[test]
fn async_max_staleness_zero_matches_sync_on_the_paper_fleet() {
    // Full-scale sync-degeneracy oracle: 80 devices, S = 0 ⇒ the async
    // engine's RunRecord is bitwise the synchronous engine's.
    let sync = mock_run("legend", 5);
    let asy = mock_run_async("legend", 5, 0.5, 0);
    assert_eq!(asy.to_json().to_string(), sync.to_json().to_string());
    assert_eq!(asy.to_csv_rows(), sync.to_csv_rows());
}

fn mock_run_realloc(method: &str, rounds: usize, threads: usize,
                    agg_shards: usize, window: usize, async_mode: bool,
                    every: usize, hysteresis: f64) -> RunRecord {
    let meta = ModelMeta::synthetic(12, 16, 32);
    let mut s =
        strategy::by_name(method, meta.n_layers, meta.r_max, meta.w_max)
            .unwrap();
    let family = s.family();
    let rank_dim = meta.rank_dim(family);
    let mut fleet = Fleet::new(FleetConfig::paper());
    let mut trainer = MockTrainer::new(family);
    let cfg = FedConfig {
        rounds,
        train_size: 2048,
        test_size: 64,
        threads,
        agg_shards,
        window,
        async_mode,
        staleness_alpha: 0.5,
        max_staleness: if async_mode { 2 } else { 0 },
        realloc_every: every,
        realloc_hysteresis: hysteresis,
        ..Default::default()
    };
    run_federated(&cfg, &mut fleet, s.as_mut(), &mut trainer, &meta,
                  &toy_spec(), toy_global(&meta, rank_dim))
    .unwrap()
}

#[test]
fn realloc_off_matches_the_static_plan_engine_on_the_paper_fleet() {
    // `--realloc-every 0` on the full 80-device fleet: bitwise the
    // pre-realloc engine, fully serial vs fully concurrent, whatever
    // the hysteresis knob says.
    let seq = mock_run_cfg("legend", 5, 1, 1, 0);
    let off = mock_run_realloc("legend", 5, 8, 4, 4, false, 0, 0.37);
    assert_eq!(seq.to_json().to_string(), off.to_json().to_string());
    assert_eq!(seq.to_csv_rows(), off.to_csv_rows());
    assert_eq!(off.rank_realloc_epochs, 0);
    assert!(off.rounds.iter().all(|r| r.plan_epoch == 0));
}

#[test]
fn periodic_realloc_is_deterministic_on_the_paper_fleet() {
    // Re-allocation ON (K = 2): same seed ⇒ bit-identical RunRecord
    // serial vs concurrent, sync and async — and the refits really
    // adopt on the fading fleet.
    for async_mode in [false, true] {
        let a = mock_run_realloc("legend", 6, 1, 1, 0, async_mode,
                                 2, 0.05);
        let b = mock_run_realloc("legend", 6, 8, 4, 4, async_mode,
                                 2, 0.05);
        assert_eq!(a.to_json().to_string(), b.to_json().to_string(),
                   "async={async_mode}");
        assert_eq!(a.to_csv_rows(), b.to_csv_rows());
        assert!(a.rank_realloc_epochs >= 1,
                "async={async_mode}: no refit adopted in 6 rounds");
        // Epochs are monotone: a round never reports an older plan
        // than its predecessor (sync engine; async windows fold
        // updates trained under older epochs, but the *window's* plan
        // epoch still only moves forward).
        for w in a.rounds.windows(2) {
            assert!(w[1].plan_epoch >= w[0].plan_epoch);
        }
    }
}

#[test]
fn wide_hysteresis_band_freezes_the_plan_after_round_one() {
    // Round 1 always adopts (nothing frozen yet); with an effectively
    // infinite band every later refit sees all 80 devices inside it
    // and must keep the frozen fit bitwise — the epoch counter parks
    // at 1.
    let rec = mock_run_realloc("legend", 6, 4, 2, 2, false, 2, 1e9);
    assert_eq!(rec.rank_realloc_epochs, 1);
    assert!(rec.rounds.iter().all(|r| r.plan_epoch == 1));
}

#[test]
fn failure_injection_empty_shard_is_rebalanced() {
    // A fleet larger than the dataset forces the per-device shard
    // derivation's one-batch floor (no device ever sees an empty
    // shard); the run must still complete.
    let meta = ModelMeta::synthetic(12, 16, 32);
    let mut s = strategy::by_name("legend", 12, 16, 32).unwrap();
    let mut fleet = Fleet::new(FleetConfig::sized(16));
    let mut trainer = MockTrainer::new("lora");
    let cfg = FedConfig {
        rounds: 3,
        train_size: 80, // 16 devices × bs4 → barely enough
        test_size: 64,
        ..Default::default()
    };
    let rec = run_federated(&cfg, &mut fleet, s.as_mut(), &mut trainer,
                            &meta, &toy_spec(), toy_global(&meta, 16))
        .unwrap();
    assert_eq!(rec.rounds.len(), 3);
}

#[test]
fn lazy_fleet_with_edge_tier_matches_flat_eager_on_a_large_fleet() {
    // Scale smoke at integration size: a 4 096-device lazy fleet with
    // a 64-device sampled cohort and a 4-edge aggregation tier must
    // reproduce — bitwise — the eager flat-fold run at the same seed.
    let meta = ModelMeta::synthetic(12, 16, 32);
    let run = |lazy: bool, edges: usize, threads: usize| -> RunRecord {
        let mut s = strategy::by_name("legend", 12, 16, 32).unwrap();
        let mut trainer = MockTrainer::new("lora");
        let cfg = FedConfig {
            rounds: 3,
            train_size: 4096,
            test_size: 64,
            threads,
            agg_shards: if threads > 1 { 2 } else { 1 },
            edge_aggregators: edges,
            ..Default::default()
        };
        let fc = FleetConfig { seed: cfg.seed,
                               ..FleetConfig::sized(4096) };
        let mut fleet: Box<dyn FleetView> = if lazy {
            Box::new(LazyFleet::new(fc))
        } else {
            Box::new(Fleet::new(fc))
        };
        run_federated_with(
            &cfg, fleet.as_mut(), s.as_mut(), &mut trainer, &meta,
            &toy_spec(), toy_global(&meta, 16),
            &mut UniformCount { count: 64 },
        )
        .unwrap()
    };
    let flat = run(false, 1, 1);
    assert_eq!(flat.rounds.len(), 3);
    assert!(flat.rounds.iter().all(|r| r.participants == 64));
    for (lazy, edges, threads) in
        [(true, 1, 1), (false, 4, 4), (true, 4, 4), (true, 8, 2)]
    {
        let rec = run(lazy, edges, threads);
        assert_eq!(flat.to_json().to_string(),
                   rec.to_json().to_string(),
                   "lazy={lazy} edges={edges} threads={threads}");
        assert_eq!(flat.to_csv_rows(), rec.to_csv_rows());
    }
}

#[test]
fn oversized_cohort_is_rejected() {
    // `UniformCount` with count > n must surface an Err from the
    // engine, not silently clamp or panic.
    let meta = ModelMeta::synthetic(12, 16, 32);
    let mut s = strategy::by_name("legend", 12, 16, 32).unwrap();
    let mut fleet = Fleet::new(FleetConfig::sized(16));
    let mut trainer = MockTrainer::new("lora");
    let cfg = FedConfig {
        rounds: 1,
        train_size: 128,
        test_size: 64,
        ..Default::default()
    };
    let err = run_federated_with(
        &cfg, &mut fleet, s.as_mut(), &mut trainer, &meta, &toy_spec(),
        toy_global(&meta, 16),
        &mut UniformCount { count: 17 },
    );
    assert!(err.is_err(), "cohort of 17 from a 16-device fleet");
}

// ---------------------------------------------------------------------------
// Real-runtime federated mini-run (needs artifacts)
// ---------------------------------------------------------------------------

fn artifacts_dir() -> Option<String> {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    std::path::Path::new(&format!("{dir}/manifest.json"))
        .exists()
        .then(|| dir.to_string())
}

#[test]
fn real_federated_run_learns_sst2() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(&dir).unwrap();
    let spec = Spec::load(&format!("{dir}/vocab.json")).unwrap();
    let meta = ModelMeta::from_manifest(&rt.manifest);

    let mut s = strategy::by_name("legend", meta.n_layers, meta.r_max,
                                  meta.w_max)
        .unwrap();
    let mut fleet = Fleet::new(FleetConfig::sized(6));
    let mut trainer = PjrtTrainer::new(&rt, "lora", 1);
    let cfg = FedConfig {
        rounds: 8,
        train_size: 384,
        test_size: 128,
        max_batches: 8,
        verbose: false,
        ..Default::default()
    };
    let mut rng = legend::util::rng::Rng::new(1).child("global-init");
    let global = legend::model::state::init_trainable(
        &rt.manifest, &rt.manifest.lora, &mut rng);
    let rec = run_federated(&cfg, &mut fleet, s.as_mut(), &mut trainer,
                            &meta, &spec, global)
        .unwrap();
    // Accuracy must beat chance (0.5 on binary) after 8 rounds.
    assert!(
        rec.final_accuracy() > 0.6,
        "federated run failed to learn: acc {}",
        rec.final_accuracy()
    );
    // Train loss decreased.
    let first = rec.rounds.first().unwrap().train_loss;
    let last = rec.rounds.last().unwrap().train_loss;
    assert!(last < first, "loss {first} → {last}");
}
