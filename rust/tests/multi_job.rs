//! Property-test invariant suite for the multi-job scheduler
//! (`coordinator/jobs.rs`, docs/MULTIJOB.md):
//!
//! (a) cohort disjointness — no device serves two jobs in one round;
//! (b) starvation-freedom — every admitted job's cohort is non-empty
//!     at least once every `P = |active jobs|` rounds, however skewed
//!     the priorities;
//! (c) token-bucket contract — never more than `burst + w·refill`
//!     grants over `w` round advances; `reset`/`disable` restore the
//!     documented states;
//! (d) single-job degeneracy — a one-job scheduler reproduces
//!     `RoundEngine::run`'s `RunRecord` bitwise;
//! (e) determinism — fixed seed ⇒ bit-identical per-job `RunRecord`s
//!     at every threads × agg-shards × window setting;
//! plus admission-control behavior and the fixed-seed oracle CI diffs
//! across processes.

use std::collections::{BTreeMap, BTreeSet};

use legend::coordinator::participation::{Full, Participation,
                                         UniformCount, UniformSample};
use legend::coordinator::strategy as fedstrategy;
use legend::coordinator::trainer::MockTrainer;
use legend::coordinator::{run_federated, AdmissionError, FedConfig,
                          JobScheduler, JobSpec, ModelMeta, RateLimit,
                          TokenBucket};
use legend::data::Spec;
use legend::device::{Fleet, FleetConfig};
use legend::metrics::RunRecord;
use legend::model::state::TensorMap;
use legend::model::TensorSpec;
use legend::prop_assert;
use legend::util::json::Value;
use legend::util::prop::check;

const L: usize = 12;
const R: usize = 16;
/// `FleetConfig::pretest()` fleet size — small enough that jobs
/// genuinely contend for devices.
const N: usize = 10;

fn toy_spec() -> Spec {
    let json = r#"{
      "vocab_size": 256, "seq_len": 16,
      "special": {"pad": 0, "cls": 1, "mask": 2, "sep": 3},
      "filler": [4, 50], "noise": [200, 256],
      "tasks": {
        "sst2": {"kind": "single", "n_classes": 2,
                 "banks": [[50, 80], [80, 110]],
                 "len_range": [5, 10], "bank_words": [2, 4],
                 "label_noise": 0.0}
      }
    }"#;
    Spec::from_json(&Value::parse(json).unwrap()).unwrap()
}

fn multi_cfg(seed: u64, rounds: usize, threads: usize,
             agg_shards: usize, window: usize) -> FedConfig {
    FedConfig {
        rounds,
        train_size: 256,
        test_size: 64,
        seed,
        threads,
        agg_shards,
        window,
        verbose: false,
        ..Default::default()
    }
}

fn scheduler() -> JobScheduler<'static> {
    JobScheduler::new(ModelMeta::synthetic(L, R, 32), toy_spec(), N)
}

/// Admit one job built the way the engine property tests build runs:
/// `strategy::by_name`, a mock trainer of the strategy's family, and
/// a zeroed global sized off `meta.rank_dim`.
fn admit(sched: &mut JobScheduler<'static>, method: &str, spec: JobSpec,
         part: Box<dyn Participation>)
         -> Result<usize, AdmissionError> {
    let meta = ModelMeta::synthetic(L, R, 32);
    let s = fedstrategy::by_name(method, L, R, 32).unwrap();
    let family = s.family();
    let trainer = MockTrainer::new(family);
    let global = TensorMap::zeros(&[
        TensorSpec {
            name: "aq".into(),
            shape: vec![L, meta.rank_dim(family), 4],
        },
        TensorSpec { name: "head_w".into(), shape: vec![4, 2] },
    ]);
    sched.admit(spec, s, Box::new(trainer), part, global)
}

fn pretest_fleet(seed: u64) -> Fleet {
    Fleet::new(FleetConfig { seed, ..FleetConfig::pretest() })
}

/// The single-job reference: exactly `properties.rs::engine_run`.
fn engine_run(method: &str, seed: u64, threads: usize,
              agg_shards: usize, window: usize) -> RunRecord {
    let meta = ModelMeta::synthetic(L, R, 32);
    let mut s = fedstrategy::by_name(method, L, R, 32).unwrap();
    let mut fleet = pretest_fleet(seed);
    let mut trainer = MockTrainer::new(s.family());
    let global = TensorMap::zeros(&[
        TensorSpec {
            name: "aq".into(),
            shape: vec![L, meta.rank_dim(s.family()), 4],
        },
        TensorSpec { name: "head_w".into(), shape: vec![4, 2] },
    ]);
    let cfg = multi_cfg(seed, 3, threads, agg_shards, window);
    run_federated(&cfg, &mut fleet, s.as_mut(), &mut trainer, &meta,
                  &toy_spec(), global)
    .unwrap()
}

/// The same run through a one-job scheduler (full participation, no
/// rate limit — the `--jobs 1` path).
fn scheduler_run_single(method: &str, seed: u64, threads: usize,
                        agg_shards: usize, window: usize) -> RunRecord {
    let mut sched = scheduler();
    let cfg = multi_cfg(seed, 3, threads, agg_shards, window);
    admit(&mut sched, method, JobSpec::new(cfg), Box::new(Full))
        .unwrap();
    let mut fleet = pretest_fleet(seed);
    let mut report = sched.run(&mut fleet).unwrap();
    report.records.remove(&0).unwrap()
}

// ---------------------------------------------------------------
// (a) Disjointness
// ---------------------------------------------------------------

#[test]
fn prop_no_device_serves_two_jobs_in_one_round() {
    // Three tenants whose sampling policies overlap hard on the
    // 10-device fleet: whatever each one asks for, the partition the
    // scheduler hands out must be disjoint, sorted, unique, in range.
    let methods = ["legend", "fedlora", "hetlora"];
    check("multi-job-disjoint-cohorts", 8, |rng, case| {
        let seed = rng.next_u64() % 1_000_003;
        let mut sched = scheduler();
        sched.record_cohorts(true);
        let mut spec0 = JobSpec::new(multi_cfg(seed, 4, 1, 1, 0));
        spec0.priority = 5;
        admit(&mut sched, methods[case % 3], spec0,
              Box::new(UniformCount { count: 4 }))
            .unwrap();
        admit(&mut sched, methods[(case + 1) % 3],
              JobSpec::new(multi_cfg(seed + 1, 4, 1, 1, 0)),
              Box::new(UniformSample { fraction: 0.5 }))
            .unwrap();
        admit(&mut sched, methods[(case + 2) % 3],
              JobSpec::new(multi_cfg(seed + 2, 4, 1, 1, 0)),
              Box::new(Full))
            .unwrap();
        let mut fleet = pretest_fleet(seed);
        let report = sched.run(&mut fleet).unwrap();
        prop_assert!(report.cohorts.len() == 4, "one entry per round");
        for (h, parts) in report.cohorts.iter().enumerate() {
            let mut seen: BTreeSet<usize> = BTreeSet::new();
            for (id, cohort) in parts {
                prop_assert!(
                    !cohort.is_empty(),
                    "round {}: job {id} recorded an empty cohort",
                    h + 1
                );
                prop_assert!(
                    cohort.windows(2).all(|w| w[0] < w[1]),
                    "round {}: job {id} cohort not sorted/unique",
                    h + 1
                );
                for &i in cohort {
                    prop_assert!(i < N, "device {i} out of range");
                    prop_assert!(
                        seen.insert(i),
                        "seed {seed} round {}: device {i} serves two \
                         jobs",
                        h + 1
                    );
                }
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------
// (b) Starvation-freedom
// ---------------------------------------------------------------

#[test]
fn prop_every_job_runs_within_the_starvation_bound() {
    // Worst case by construction: every tenant wants the WHOLE fleet
    // (full participation), so whoever claims first each round takes
    // everything and nobody else can even backfill. The rotating
    // guarantee slot must still hand every job a non-empty cohort at
    // least once every P = |active jobs| rounds — whatever the
    // priority skew says.
    check("multi-job-starvation-freedom", 6, |rng, case| {
        let seed = rng.next_u64() % 1_000_003;
        let n_jobs = 2 + case % 3; // 2..=4 tenants
        let rounds = 3 * n_jobs;
        let mut sched = scheduler();
        sched.record_cohorts(true);
        for j in 0..n_jobs {
            let mut spec = JobSpec::new(
                multi_cfg(seed + j as u64, rounds, 1, 1, 0));
            // Skewed priorities: without the guarantee slot, the
            // highest-priority job would claim the fleet every round.
            spec.priority = (n_jobs - j) as i64 * 100;
            admit(&mut sched, "legend", spec, Box::new(Full)).unwrap();
        }
        let p = sched.starvation_bound();
        prop_assert!(p == n_jobs, "bound is the active job count");
        let mut fleet = pretest_fleet(seed);
        let report = sched.run(&mut fleet).unwrap();
        for id in 0..n_jobs {
            let served: Vec<usize> = report
                .cohorts
                .iter()
                .enumerate()
                .filter(|(_, parts)| {
                    parts.get(&id).is_some_and(|c| !c.is_empty())
                })
                .map(|(h, _)| h + 1)
                .collect();
            prop_assert!(
                !served.is_empty(),
                "seed {seed}: job {id} never served in {rounds} rounds"
            );
            prop_assert!(
                served[0] <= p,
                "seed {seed}: job {id} first served in round {} > P={p}",
                served[0]
            );
            for w in served.windows(2) {
                prop_assert!(
                    w[1] - w[0] <= p,
                    "seed {seed}: job {id} starved for {} rounds \
                     (P={p})",
                    w[1] - w[0]
                );
            }
            prop_assert!(
                rounds + 1 - served.last().unwrap() <= p,
                "seed {seed}: job {id} starved at the tail"
            );
        }
        Ok(())
    });
}

// ---------------------------------------------------------------
// (c) Token bucket
// ---------------------------------------------------------------

#[test]
fn prop_token_bucket_never_exceeds_burst_plus_refills() {
    // Over any op sequence, an enabled bucket grants at most
    // burst + advances·refill tokens since its last reset, and the
    // stored level never exceeds burst.
    check("token-bucket-admission-bound", 256, |rng, _| {
        let burst = rng.range_incl(0, 20);
        let refill = rng.range_incl(0, 10);
        let mut b = TokenBucket::new(burst, refill);
        let mut granted = 0usize;
        let mut advances = 0usize;
        for _ in 0..rng.range_incl(1, 60) {
            match rng.range(0, 3) {
                0 => {
                    let want = rng.range_incl(0, 30);
                    let g = b.take(want);
                    prop_assert!(g <= want, "granted more than asked");
                    granted += g;
                }
                1 => {
                    b.advance_round();
                    advances += 1;
                }
                _ => {
                    b.reset();
                    prop_assert!(
                        b.tokens() == burst,
                        "reset must restore a full bucket"
                    );
                    granted = 0;
                    advances = 0;
                }
            }
            prop_assert!(
                b.tokens() <= burst,
                "stored level {} above burst {burst}",
                b.tokens()
            );
            prop_assert!(
                granted <= burst + advances * refill,
                "granted {granted} > {burst} + {advances}·{refill}"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_token_bucket_reset_and_disable_restore_documented_state() {
    check("token-bucket-reset-disable", 128, |rng, _| {
        let burst = rng.range_incl(1, 20);
        let refill = rng.range_incl(0, 10);
        let mut b = TokenBucket::new(burst, refill);
        for _ in 0..rng.range_incl(0, 20) {
            match rng.range(0, 2) {
                0 => {
                    b.take(rng.range_incl(0, 30));
                }
                _ => b.advance_round(),
            }
        }
        // reset: full bucket, enablement untouched.
        b.reset();
        prop_assert!(b.tokens() == burst && b.is_enabled(), "reset");
        // disable: unlimited grants, stored level untouched by takes
        // but still refilled by round advances.
        b.take(rng.range_incl(0, burst));
        let level = b.tokens();
        b.disable();
        prop_assert!(b.available() == usize::MAX, "disabled available");
        let want = rng.range_incl(0, 1000);
        prop_assert!(
            b.take(want) == want,
            "disabled bucket must grant everything"
        );
        prop_assert!(
            b.tokens() == level,
            "disabled take must not deduct"
        );
        b.advance_round();
        let refilled = (level + refill).min(burst);
        prop_assert!(
            b.tokens() == refilled,
            "stored level must refill while disabled"
        );
        // enable resumes exactly where an idle limiter would be.
        b.enable();
        prop_assert!(
            b.is_enabled() && b.available() == refilled,
            "enable must resume the stored level"
        );
        Ok(())
    });
}

// ---------------------------------------------------------------
// Rate limiting through the whole round loop
// ---------------------------------------------------------------

#[test]
fn rate_limited_job_folds_at_most_its_grants() {
    // One full-participation job on the 10-device fleet, bucket
    // burst 3 / refill 2: the coordinator folds 3 updates in round 1,
    // then 2 per later round — the RunRecord's participants column is
    // exactly the token schedule.
    let mut sched = scheduler();
    let mut spec = JobSpec::new(multi_cfg(11, 3, 1, 1, 0));
    spec.rate = Some(RateLimit { burst: 3, refill: 2 });
    admit(&mut sched, "legend", spec, Box::new(Full)).unwrap();
    let mut fleet = pretest_fleet(11);
    let report = sched.run(&mut fleet).unwrap();
    let parts: Vec<usize> =
        report.records[&0].rounds.iter().map(|r| r.participants).collect();
    assert_eq!(parts, vec![3, 2, 2], "token schedule violated");

    // burst 1 / refill 0: one update in round 1, then the bucket is
    // dry forever — the job idles (no record rows, no RNG draws).
    let mut sched = scheduler();
    let mut spec = JobSpec::new(multi_cfg(11, 3, 1, 1, 0));
    spec.rate = Some(RateLimit { burst: 1, refill: 0 });
    admit(&mut sched, "legend", spec, Box::new(Full)).unwrap();
    let mut fleet = pretest_fleet(11);
    let report = sched.run(&mut fleet).unwrap();
    let rec = &report.records[&0];
    assert_eq!(rec.rounds.len(), 1, "dry bucket must idle the job");
    assert_eq!(rec.rounds[0].participants, 1);
}

// ---------------------------------------------------------------
// (d) Single-job degeneracy
// ---------------------------------------------------------------

#[test]
fn prop_single_job_scheduler_reproduces_engine_bitwise() {
    // `--jobs 1` is not allowed to cost anything: a one-job scheduler
    // (no rate limit, full participation) must reproduce
    // RoundEngine::run's RunRecord BITWISE — same JSON, same CSV — at
    // every threads × agg-shards × window setting.
    let methods = ["legend", "fedlora", "hetlora", "fedadapter"];
    let combos: [(usize, usize, usize); 5] =
        [(1, 1, 0), (4, 1, 0), (4, 4, 2), (2, 8, 1), (3, 2, 5)];
    check("single-job-scheduler-bitwise", 8, |rng, case| {
        let method = methods[case % methods.len()];
        let seed = rng.next_u64() % 1_000_003;
        for (threads, shards, window) in combos {
            let want = engine_run(method, seed, threads, shards, window);
            let got = scheduler_run_single(method, seed, threads,
                                           shards, window);
            prop_assert!(
                want.to_json().to_string() == got.to_json().to_string(),
                "{method} seed {seed}: scheduler JSON diverged from \
                 the engine at threads={threads} shards={shards} \
                 window={window}"
            );
            prop_assert!(
                want.to_csv_rows() == got.to_csv_rows(),
                "{method} seed {seed}: scheduler CSV diverged from \
                 the engine at threads={threads} shards={shards} \
                 window={window}"
            );
        }
        Ok(())
    });
}

// ---------------------------------------------------------------
// (e) Determinism across concurrency knobs
// ---------------------------------------------------------------

/// A 2-job run (rate-limited LEGEND tenant + sampling FedLoRA tenant)
/// at the given concurrency knobs.
fn scheduler_run_two(seed: u64, threads: usize, agg_shards: usize,
                     window: usize) -> BTreeMap<usize, RunRecord> {
    let mut sched = scheduler();
    let mut spec0 =
        JobSpec::new(multi_cfg(seed, 3, threads, agg_shards, window));
    spec0.priority = 3;
    spec0.rate = Some(RateLimit { burst: 2, refill: 1 });
    admit(&mut sched, "legend", spec0,
          Box::new(UniformCount { count: 4 }))
        .unwrap();
    admit(&mut sched, "fedlora",
          JobSpec::new(multi_cfg(seed + 1, 3, threads, agg_shards,
                                 window)),
          Box::new(UniformSample { fraction: 0.5 }))
        .unwrap();
    let mut fleet = pretest_fleet(seed);
    sched.run(&mut fleet).unwrap().records
}

#[test]
fn prop_multi_job_records_invariant_under_threads_shards_window() {
    // Fixed seed ⇒ bit-identical per-job RunRecords at every
    // threads × agg-shards × window setting. The baseline is the
    // fully serial path: 1 thread, inline fold, unbounded window.
    let combos: [(usize, usize, usize); 4] =
        [(4, 1, 0), (4, 4, 2), (2, 8, 1), (3, 2, 5)];
    check("multi-job-concurrency-invariance", 6, |rng, case| {
        let seed = rng.next_u64() % 1_000_003;
        let base = scheduler_run_two(seed, 1, 1, 0);
        prop_assert!(base.len() == 2, "two jobs, two records");
        let (threads, shards, window) = combos[case % combos.len()];
        let got = scheduler_run_two(seed, threads, shards, window);
        for (id, want) in &base {
            prop_assert!(
                want.to_json().to_string()
                    == got[id].to_json().to_string(),
                "seed {seed} job {id}: JSON diverged at \
                 threads={threads} shards={shards} window={window}"
            );
            prop_assert!(
                want.to_csv_rows() == got[id].to_csv_rows(),
                "seed {seed} job {id}: CSV diverged at \
                 threads={threads} shards={shards} window={window}"
            );
        }
        Ok(())
    });
}

// ---------------------------------------------------------------
// Admission control
// ---------------------------------------------------------------

#[test]
fn admission_control_rejects_without_panicking() {
    let mut sched = scheduler();
    // Job 0 reserves 6 of the 10 devices.
    let mut spec = JobSpec::new(multi_cfg(1, 3, 1, 1, 0));
    spec.min_cohort = 6;
    admit(&mut sched, "legend", spec, Box::new(Full)).unwrap();
    assert_eq!(sched.residual_capacity(), 4);

    // min_cohort above the residual: a typed capacity rejection.
    let mut spec = JobSpec::new(multi_cfg(2, 3, 1, 1, 0));
    spec.min_cohort = 5;
    let err = admit(&mut sched, "fedlora", spec, Box::new(Full))
        .unwrap_err();
    assert_eq!(
        err,
        AdmissionError::InsufficientCapacity {
            need: 5,
            residual: 4,
            fleet: N
        }
    );

    // A zero minimum cohort can never be satisfied meaningfully.
    let mut spec = JobSpec::new(multi_cfg(3, 3, 1, 1, 0));
    spec.min_cohort = 0;
    let err = admit(&mut sched, "fedlora", spec, Box::new(Full))
        .unwrap_err();
    assert_eq!(err, AdmissionError::EmptyMinCohort);

    // An oversized --sample-count is validated against the RESIDUAL
    // slice, not the whole fleet: 8 ≤ 10 but 8 > 4 → a proper Err
    // (satellite: this used to be only debug-guarded downstream).
    let spec = JobSpec::new(multi_cfg(4, 3, 1, 1, 0));
    let err = admit(&mut sched, "fedlora", spec,
                    Box::new(UniformCount { count: 8 }))
        .unwrap_err();
    match err {
        AdmissionError::Participation(msg) => {
            assert!(msg.contains("exceeds fleet size"), "{msg}")
        }
        other => panic!("expected a participation rejection: {other}"),
    }

    // Nothing above touched the ledger; a fitting job still enters.
    assert_eq!(sched.n_jobs(), 1);
    assert_eq!(sched.residual_capacity(), 4);
    let mut spec = JobSpec::new(multi_cfg(5, 3, 1, 1, 0));
    spec.min_cohort = 4;
    admit(&mut sched, "fedlora", spec,
          Box::new(UniformCount { count: 4 }))
        .unwrap();
    assert_eq!(sched.residual_capacity(), 0);

    // Fully reserved: even a 1-device job is refused now.
    let err =
        admit(&mut sched, "legend", JobSpec::new(multi_cfg(6, 3, 1, 1, 0)),
              Box::new(Full))
            .unwrap_err();
    assert!(matches!(err,
                     AdmissionError::InsufficientCapacity { .. }));
}

#[test]
fn stop_at_target_releases_the_reservation_early() {
    // Job 0 crosses its (trivial) target after round 1 and finishes:
    // its 4 reserved devices stop being claimed, so job 1's
    // full-participation cohort grows from 6 back to the whole fleet.
    let mut sched = scheduler();
    sched.record_cohorts(true);
    let mut spec0 = JobSpec::new(multi_cfg(21, 4, 1, 1, 0));
    spec0.min_cohort = 4;
    spec0.target_acc = 0.0;
    spec0.stop_at_target = true;
    spec0.priority = 10;
    admit(&mut sched, "legend", spec0,
          Box::new(UniformCount { count: 4 }))
        .unwrap();
    admit(&mut sched, "fedlora",
          JobSpec::new(multi_cfg(22, 4, 1, 1, 0)), Box::new(Full))
        .unwrap();
    let mut fleet = pretest_fleet(21);
    let report = sched.run(&mut fleet).unwrap();
    assert_eq!(report.records[&0].rounds.len(), 1,
               "job 0 must stop after hitting its target");
    assert_eq!(report.records[&1].rounds.len(), 4,
               "job 1 runs its full budget");
    assert_eq!(report.cohorts[0][&1].len(), N - 4,
               "round 1: job 1 works around job 0's cohort");
    for h in 1..4 {
        assert!(!report.cohorts[h].contains_key(&0),
                "round {}: finished job must not claim devices", h + 1);
        assert_eq!(report.cohorts[h][&1].len(), N,
                   "round {}: freed devices return to job 1", h + 1);
    }
}

// ---------------------------------------------------------------
// Fixed-seed oracle (CI diffs this across two processes)
// ---------------------------------------------------------------

/// Mirrors `async_oracle_emits_canonical_run_record`: CI's
/// determinism job runs this twice in separate processes and diffs
/// `results/DETERMINISM_multijob.json`, holding the multi-job
/// scheduler to the same cross-process bit-reproducibility bar as the
/// engines.
#[test]
fn multijob_oracle_emits_canonical_run_records() {
    let seed = 424_246;
    let mut sched = scheduler();
    let mut spec0 = JobSpec::new(multi_cfg(seed, 3, 4, 4, 2));
    spec0.priority = 2;
    spec0.rate = Some(RateLimit { burst: 6, refill: 3 });
    admit(&mut sched, "legend", spec0,
          Box::new(UniformCount { count: 4 }))
        .unwrap();
    admit(&mut sched, "fedlora",
          JobSpec::new(multi_cfg(seed + 1, 3, 4, 4, 2)),
          Box::new(UniformSample { fraction: 0.5 }))
        .unwrap();
    let mut fleet = pretest_fleet(seed);
    let report = sched.run(&mut fleet).unwrap();
    assert_eq!(report.records.len(), 2);
    let doc = legend::metrics::multi_job_json(&report.records);
    std::fs::create_dir_all("results").unwrap();
    std::fs::write("results/DETERMINISM_multijob.json", doc.to_string())
        .unwrap();
}
