//! Integration tests over the real AOT artifacts + PJRT runtime.
//!
//! These exercise the full three-layer compose: Pallas kernel (L1) and
//! JAX train/eval steps (L2) lowered to HLO text, loaded and executed
//! from rust (L3). They require `make artifacts` to have run; each
//! test skips (passes vacuously) if artifacts/ is absent so `cargo
//! test` stays green on a fresh clone.

use legend::data::{grammar, Spec};
use legend::model::masks::{LayerSet, LoraConfig};
use legend::model::state::{init_opt, init_trainable};
use legend::runtime::session::SessionState;
use legend::runtime::{KernelDims, Masks, Runtime};
use legend::util::rng::Rng;

fn artifacts_dir() -> Option<String> {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    std::path::Path::new(&format!("{dir}/manifest.json"))
        .exists()
        .then(|| dir.to_string())
}

/// Host-side reference of the fused LoRA linear (mirrors ref.py).
fn lora_linear_host(x: &[f32], w: &[f32], a: &[f32], b: &[f32],
                    mask: &[f32], scale: f32, m: usize, k: usize,
                    n: usize, r: usize) -> Vec<f32> {
    let mut out = vec![0f32; m * n];
    for i in 0..m {
        // low = x · (mask ⊙ a)^T
        let mut low = vec![0f32; r];
        for j in 0..r {
            let mut acc = 0f32;
            for t in 0..k {
                acc += x[i * k + t] * a[j * k + t];
            }
            low[j] = acc * mask[j];
        }
        for j in 0..n {
            let mut acc = 0f32;
            for t in 0..k {
                acc += x[i * k + t] * w[t * n + j];
            }
            let mut byp = 0f32;
            for t in 0..r {
                byp += low[t] * b[j * r + t] * mask[t];
            }
            out[i * n + j] = acc + scale * byp;
        }
    }
    out
}

#[test]
fn pallas_kernel_matches_host_reference() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::load(&dir).expect("runtime loads");
    let dims = KernelDims::from_manifest(&dir).unwrap();
    let (m, k, n, r) = (dims.m, dims.k, dims.n, dims.r);
    let mut rng = Rng::new(99);
    let gen = |rng: &mut Rng, len: usize| -> Vec<f32> {
        (0..len).map(|_| rng.normal() as f32 * 0.5).collect()
    };
    let x = gen(&mut rng, m * k);
    let w = gen(&mut rng, k * n);
    let a = gen(&mut rng, r * k);
    let b = gen(&mut rng, n * r);
    let mut mask = vec![1f32; r];
    for item in mask.iter_mut().skip(r / 2) {
        *item = 0.0; // half the rank slots padded
    }
    let scale = 1.75f32;

    let got = rt.run_kernel(&x, &w, &a, &b, &mask, scale, &dims).unwrap();
    let want = lora_linear_host(&x, &w, &a, &b, &mask, scale, m, k, n, r);
    assert_eq!(got.len(), want.len());
    let max_err = got
        .iter()
        .zip(&want)
        .map(|(g, w)| (g - w).abs())
        .fold(0f32, f32::max);
    assert!(max_err < 1e-3, "kernel vs host ref max err {max_err}");
}

#[test]
fn train_step_decreases_loss_and_respects_masks() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(&dir).expect("runtime loads");
    let dim = rt.manifest.dim.clone();

    let spec = Spec::load(&format!("{dir}/vocab.json")).unwrap();
    let mut rng = Rng::new(5);
    let ds = grammar::generate(&spec, "sst2", 64, &mut rng).unwrap();

    let mut state_rng = Rng::new(7);
    let trainable = init_trainable(&rt.manifest, &rt.manifest.lora,
                                   &mut state_rng);
    let opt = init_opt(&rt.manifest.lora);
    let mut session = SessionState::from_maps(&trainable, &opt).unwrap();

    // LEGEND-style config: depth 4, increasing ranks.
    let cfg = LoraConfig {
        layers: LayerSet::Depth(4),
        ranks: (1..=dim.n_layers).collect(),
    };
    let masks = Masks {
        rank_mask: cfg.rank_mask(dim.n_layers, dim.r_max),
        layer_mask: cfg.layer_mask(dim.n_layers),
    };

    let batches = ds.batches(dim.batch_size);
    let mut losses = Vec::new();
    let mut step = 0f32;
    for epoch in 0..6 {
        let _ = epoch;
        for (toks, labels) in &batches {
            step += 1.0;
            let stats = rt
                .train_step("lora", &mut session, &masks, toks, labels,
                            2e-3, step)
                .unwrap();
            assert!(stats.loss.is_finite(), "loss diverged");
            losses.push(stats.loss as f64);
        }
    }
    let head = losses[..batches.len()].iter().sum::<f64>()
        / batches.len() as f64;
    let tail = losses[losses.len() - batches.len()..].iter().sum::<f64>()
        / batches.len() as f64;
    assert!(
        tail < head,
        "loss should fall during local fine-tuning: {head} → {tail}"
    );

    // Masked invariants: inactive layers + padded ranks never move.
    let (t2, _) = session.to_maps().unwrap();
    let l = dim.n_layers;
    let r = dim.r_max;
    let d = dim.d_model;
    let old_aq = trainable.get("aq").unwrap();
    let new_aq = t2.get("aq").unwrap();
    // layer 0 is inactive at depth 4 → whole [r, d] slab unchanged.
    assert_eq!(&old_aq[..r * d], &new_aq[..r * d], "inactive layer moved");
    // deepest layer: active ranks move, padded ranks don't.
    let lay = l - 1;
    let active_r = dim.n_layers.min(r); // ranks[l-1] = L
    let slab = |buf: &[f32], row: usize| -> Vec<f32> {
        buf[lay * r * d + row * d..lay * r * d + (row + 1) * d].to_vec()
    };
    if active_r < r {
        assert_eq!(
            slab(old_aq, r - 1),
            slab(new_aq, r - 1),
            "padded rank slot moved"
        );
    }
    // Eval runs and returns sane numbers.
    let (loss, acc) = rt.evaluate("lora", &t2, &masks, &ds).unwrap();
    assert!(loss.is_finite() && (0.0..=1.0).contains(&acc));
}

#[test]
fn adapter_family_trains() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(&dir).expect("runtime loads");
    let dim = rt.manifest.dim.clone();
    let spec = Spec::load(&format!("{dir}/vocab.json")).unwrap();
    let mut rng = Rng::new(6);
    let ds = grammar::generate(&spec, "mmlu", 64, &mut rng).unwrap();

    let mut state_rng = Rng::new(8);
    let trainable = init_trainable(&rt.manifest, &rt.manifest.adapter,
                                   &mut state_rng);
    let opt = init_opt(&rt.manifest.adapter);
    let mut session = SessionState::from_maps(&trainable, &opt).unwrap();

    // FedAdapter-style: width 8 adapters on the deepest 6 layers.
    let cfg = LoraConfig::uniform(LayerSet::Depth(6), 8, dim.n_layers);
    let masks = Masks {
        rank_mask: cfg.rank_mask(dim.n_layers, dim.adapter_w_max),
        layer_mask: cfg.layer_mask(dim.n_layers),
    };
    let batches = ds.batches(dim.batch_size);
    let mut step = 0f32;
    let mut first = None;
    let mut last = 0f32;
    for _ in 0..4 {
        for (toks, labels) in &batches {
            step += 1.0;
            let stats = rt
                .train_step("adapter", &mut session, &masks, toks, labels,
                            2e-3, step)
                .unwrap();
            assert!(stats.loss.is_finite());
            first.get_or_insert(stats.loss);
            last = stats.loss;
        }
    }
    assert!(last < first.unwrap() + 0.5, "adapter training unstable");
}
