//! detlint — a determinism static-analysis pass over `rust/src/`.
//!
//! The repo's contract (ROADMAP.md, docs/DETERMINISM.md) is that a
//! fixed seed produces a bit-identical `RunRecord` at every
//! `threads × agg-shards × window × edge-aggregators` setting. The
//! runtime oracle harness catches contract breaks after the fact;
//! this pass rejects the *sources* of nondeterminism at build time:
//!
//! * `unordered-collection` — `HashMap`/`HashSet` (and their hasher
//!   machinery) in determinism-critical modules. Iteration order is
//!   randomized per process, so any fold/serialize over one is a
//!   latent contract break. Use `BTreeMap`/`BTreeSet`.
//! * `wall-clock` — `Instant`/`SystemTime`. Simulated time must come
//!   from `sim::clock::VirtualClock`; wall-clock reads make timing
//!   (and everything keyed on it) machine-dependent.
//! * `ambient-random` — `thread_rng`/`OsRng`/`from_entropy`/
//!   `getrandom`. All randomness must flow from the seeded
//!   `util::rng::Rng` counter streams.
//! * `float-accum` — raw `+=` whose LHS is not provably an integer
//!   and whose RHS is not an integer literal / provably-integer
//!   identifier. Float addition is non-associative, so accumulation
//!   order leaks into results; cross-device reductions must go
//!   through the Q60 fixed-point `FoldSums` path
//!   (`coordinator/aggregation.rs`, the one allowlisted file).
//! * `float-ord` — `.partial_cmp(` calls. `None` on NaN makes sort
//!   comparators panic or (with `unwrap_or`) silently reorder; use
//!   `total_cmp`. Defining `fn partial_cmp` for a `PartialOrd` impl
//!   is fine and exempt.
//!
//! Escape hatch: a justified annotation on the violating line or the
//! line directly above it —
//!
//! ```text
//! // detlint-allow: <rule> <reason>
//! ```
//!
//! The reason is mandatory (`bad-allow` otherwise), an allow that
//! matches no violation is itself an error (`stale-allow`), and every
//! allow in force is printed in the census so drift is visible in CI
//! logs.
//!
//! Implementation: a comment/string-stripping lexer plus token scans.
//! No `syn` — the pass must build with zero dependencies in hermetic
//! environments — so it is deliberately conservative: it only claims
//! a `+=` is safe when the integer-ness is locally provable, and
//! anything else needs the Q60 path or an annotation.

use std::collections::BTreeSet;
use std::fs;
use std::path::{Path, PathBuf};

const INT_TYPES: &[&str] = &[
    "u8", "u16", "u32", "u64", "u128", "usize",
    "i8", "i16", "i32", "i64", "i128", "isize",
];
const FLOAT_TYPES: &[&str] = &["f32", "f64"];

/// Longest-first so `usize` is stripped before a bare `e` check could
/// misread its `e` as a float exponent (`0usize` is an integer).
const INT_SUFFIXES: &[&str] = &[
    "usize", "isize", "u128", "i128", "u16", "u32", "u64", "i16",
    "i32", "i64", "u8", "i8",
];

/// Banned identifiers and the rule each one trips.
const BANNED: &[(&str, &str)] = &[
    ("HashMap", "unordered-collection"),
    ("HashSet", "unordered-collection"),
    ("hash_map", "unordered-collection"),
    ("hash_set", "unordered-collection"),
    ("RandomState", "unordered-collection"),
    ("DefaultHasher", "unordered-collection"),
    ("Instant", "wall-clock"),
    ("SystemTime", "wall-clock"),
    ("thread_rng", "ambient-random"),
    ("ThreadRng", "ambient-random"),
    ("OsRng", "ambient-random"),
    ("from_entropy", "ambient-random"),
    ("getrandom", "ambient-random"),
    // Only the `.partial_cmp(` call form — see banned_violations.
    ("partial_cmp", "float-ord"),
];

/// Determinism-critical scopes, relative to `rust/src/`.
const CHECKED_DIRS: &[&str] =
    &["coordinator/", "device/", "sim/", "runtime/"];
const CHECKED_FILES: &[&str] = &["util/rng.rs"];

/// The one place raw float `+=` is the point: the Q60 quantize/fold
/// kernels themselves (plus their tests, which compare against naive
/// float folds on purpose).
const FLOAT_ACCUM_ALLOWLIST: &[&str] = &["coordinator/aggregation.rs"];

#[derive(Debug, Clone)]
pub struct Allow {
    pub line: usize,
    pub rule: String,
    pub reason: String,
}

#[derive(Debug, Clone)]
pub struct Violation {
    pub line: usize,
    pub rule: &'static str,
    pub msg: String,
}

fn is_checked(rel: &str) -> bool {
    CHECKED_DIRS.iter().any(|d| rel.starts_with(d))
        || CHECKED_FILES.contains(&rel)
}

fn ident_char(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Blank out comments, string/char literals, and raw strings while
/// preserving every newline and column position, so token positions in
/// the sanitized text map 1:1 onto the original source. Collects
/// `detlint-allow:` annotations (plain, doc `///`, and inner `//!`
/// comment forms) on the way.
fn sanitize(src: &str) -> (Vec<char>, Vec<Allow>) {
    let s: Vec<char> = src.chars().collect();
    let n = s.len();
    let mut out = vec![' '; n];
    let mut allows = Vec::new();
    let mut line = 1usize;
    let mut i = 0usize;
    while i < n {
        let c = s[i];
        if c == '\n' {
            out[i] = '\n';
            line += 1;
            i += 1;
        } else if c == '/' && i + 1 < n && s[i + 1] == '/' {
            let mut j = i;
            while j < n && s[j] != '\n' {
                j += 1;
            }
            let mut text: String =
                s[i + 2..j].iter().collect::<String>().trim().to_string();
            if text.starts_with('!') || text.starts_with('/') {
                text = text[1..].trim().to_string();
            }
            if let Some(rest) = text.strip_prefix("detlint-allow:") {
                let rest = rest.trim();
                let mut parts = rest.splitn(2, char::is_whitespace);
                let rule = parts.next().unwrap_or("").to_string();
                let reason =
                    parts.next().unwrap_or("").trim().to_string();
                allows.push(Allow { line, rule, reason });
            }
            i = j;
        } else if c == '/' && i + 1 < n && s[i + 1] == '*' {
            let mut depth = 1usize;
            i += 2;
            while i < n && depth > 0 {
                if s[i] == '/' && i + 1 < n && s[i + 1] == '*' {
                    depth += 1;
                    i += 2;
                } else if s[i] == '*' && i + 1 < n && s[i + 1] == '/' {
                    depth -= 1;
                    i += 2;
                } else {
                    if s[i] == '\n' {
                        out[i] = '\n';
                        line += 1;
                    }
                    i += 1;
                }
            }
        } else if c == '"' {
            out[i] = '"';
            i += 1;
            while i < n {
                if s[i] == '\\' && i + 1 < n {
                    i += 2;
                } else if s[i] == '"' {
                    out[i] = '"';
                    i += 1;
                    break;
                } else {
                    if s[i] == '\n' {
                        out[i] = '\n';
                        line += 1;
                    }
                    i += 1;
                }
            }
        } else if c == 'r'
            && (i == 0 || !ident_char(s[i - 1]))
            && i + 1 < n
            && (s[i + 1] == '#' || s[i + 1] == '"')
        {
            // Raw string r"..." / r#"..."#.
            let mut j = i + 1;
            let mut hashes = 0usize;
            while j < n && s[j] == '#' {
                hashes += 1;
                j += 1;
            }
            if j < n && s[j] == '"' {
                i = j + 1;
                while i < n {
                    if s[i] == '"'
                        && s[i + 1..].iter().take(hashes).all(|&h| h == '#')
                        && s[i + 1..].len() >= hashes
                    {
                        i += 1 + hashes;
                        break;
                    }
                    if s[i] == '\n' {
                        out[i] = '\n';
                        line += 1;
                    }
                    i += 1;
                }
            } else {
                // `r#ident` raw identifier or similar — keep the `r`.
                out[i] = c;
                i += 1;
            }
        } else if c == '\'' {
            if i + 1 < n && s[i + 1] == '\\' {
                // Escaped char literal '\n', '\u{..}'.
                i += 2;
                while i < n && s[i] != '\'' {
                    i += 1;
                }
                i += 1;
            } else if i + 2 < n && s[i + 2] == '\'' {
                // Plain char literal 'a'.
                i += 3;
            } else {
                // Lifetime tick — keep it so the type-ascription scan
                // can skip over `&'a`.
                out[i] = c;
                i += 1;
            }
        } else {
            out[i] = c;
            i += 1;
        }
    }
    (out, allows)
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Kind {
    Ident,
    Num,
    Punct,
}

#[derive(Debug, Clone)]
struct Tok {
    kind: Kind,
    text: String,
    line: usize,
    start: usize,
}

fn tokenize(clean: &[char]) -> Vec<Tok> {
    let mut toks = Vec::new();
    let mut line = 1usize;
    let mut i = 0usize;
    let n = clean.len();
    while i < n {
        let c = clean[i];
        if c == '\n' {
            line += 1;
            i += 1;
        } else if c.is_whitespace() {
            i += 1;
        } else if c.is_alphabetic() || c == '_' {
            let mut j = i;
            while j < n && ident_char(clean[j]) {
                j += 1;
            }
            toks.push(Tok {
                kind: Kind::Ident,
                text: clean[i..j].iter().collect(),
                line,
                start: i,
            });
            i = j;
        } else if c.is_ascii_digit() {
            let mut j = i;
            while j < n && (ident_char(clean[j]) || clean[j] == '.') {
                if clean[j] == '.' && j + 1 < n && clean[j + 1] == '.' {
                    break; // range `..`
                }
                j += 1;
            }
            toks.push(Tok {
                kind: Kind::Num,
                text: clean[i..j].iter().collect(),
                line,
                start: i,
            });
            i = j;
        } else {
            toks.push(Tok {
                kind: Kind::Punct,
                text: c.to_string(),
                line,
                start: i,
            });
            i += 1;
        }
    }
    toks
}

/// Is this numeric literal an integer? `0x..`/`0o..`/`0b..` yes; a
/// `.` or an `f32`/`f64` suffix no; otherwise strip any integer
/// suffix first, then a remaining `e` marks a float exponent.
fn num_is_int(t: &str) -> bool {
    let mut low = t.to_ascii_lowercase();
    if low.starts_with("0x") || low.starts_with("0o")
        || low.starts_with("0b")
    {
        return true;
    }
    if t.contains('.') || low.ends_with("f32") || low.ends_with("f64") {
        return false;
    }
    for suf in INT_SUFFIXES {
        if low.ends_with(suf) {
            low.truncate(low.len() - suf.len());
            break;
        }
    }
    !low.contains('e')
}

/// Identifiers whose integer-ness or float-ness is locally provable:
/// type ascriptions (`x: usize`, fn params, struct fields — skipping
/// `&`, `mut`, `[`, lifetimes) and literal-initialized lets
/// (`let mut n = 0usize`).
fn typed_idents(toks: &[Tok]) -> (BTreeSet<String>, BTreeSet<String>) {
    let mut known_int = BTreeSet::new();
    let mut known_float = BTreeSet::new();
    let n = toks.len();
    for k in 0..n {
        let t = &toks[k];
        if t.kind == Kind::Ident
            && k + 1 < n
            && toks[k + 1].kind == Kind::Punct
            && toks[k + 1].text == ":"
            && (k + 2 >= n || toks[k + 2].text != ":")
            && (k == 0 || toks[k - 1].text != ":")
        {
            let mut j = k + 2;
            while j < n
                && matches!(toks[j].text.as_str(),
                            "&" | "mut" | "[" | "'")
            {
                j += 1;
            }
            if j < n && toks[j].kind == Kind::Ident {
                if INT_TYPES.contains(&toks[j].text.as_str()) {
                    known_int.insert(t.text.clone());
                } else if FLOAT_TYPES.contains(&toks[j].text.as_str()) {
                    known_float.insert(t.text.clone());
                }
            }
        }
        if t.kind == Kind::Ident && t.text == "let" {
            let mut j = k + 1;
            if j < n && toks[j].text == "mut" {
                j += 1;
            }
            if j < n
                && toks[j].kind == Kind::Ident
                && j + 1 < n
                && toks[j + 1].text == "="
                && (j + 2 >= n || toks[j + 2].text != "=")
                && j + 2 < n
                && toks[j + 2].kind == Kind::Num
            {
                let name = toks[j].text.clone();
                if num_is_int(&toks[j + 2].text) {
                    known_int.insert(name);
                } else {
                    known_float.insert(name);
                }
            }
        }
    }
    (known_int, known_float)
}

/// Tokens that terminate the leftward scan for a `+=` LHS.
fn is_lhs_boundary(text: &str) -> bool {
    matches!(text, ";" | "{" | "}" | "(" | "," | "|" | "=" | "+" | "-"
                 | ">" | "<")
}

fn float_accum_violations(toks: &[Tok]) -> Vec<Violation> {
    let (known_int, known_float) = typed_idents(toks);
    let mut out = Vec::new();
    let n = toks.len();
    for k in 0..n {
        // `+` immediately followed by `=` in the source text.
        if !(toks[k].kind == Kind::Punct
            && toks[k].text == "+"
            && k + 1 < n
            && toks[k + 1].text == "="
            && toks[k + 1].start == toks[k].start + 1)
        {
            continue;
        }
        let line = toks[k].line;
        // LHS: walk back to a statement/expression boundary, then take
        // the last bracket-depth-0 identifier as the base place
        // (`self.scores[c].0 += …` → `scores`, `*x += …` → `x`).
        let mut lhs: Vec<&Tok> = Vec::new();
        let mut j = k as isize - 1;
        while j >= 0 {
            let t = &toks[j as usize];
            if t.kind == Kind::Punct && is_lhs_boundary(&t.text) {
                break;
            }
            lhs.push(t);
            j -= 1;
        }
        lhs.reverse();
        let mut base: Option<&str> = None;
        let mut depth = 0i32;
        for t in &lhs {
            if t.text == "[" {
                depth += 1;
            } else if t.text == "]" {
                depth -= 1;
            } else if depth == 0 && t.kind == Kind::Ident {
                base = Some(&t.text);
            }
        }
        // RHS: forward to the statement-ending `;`.
        let mut rhs: Vec<&Tok> = Vec::new();
        let mut m = k + 2;
        let mut pdepth = 0i32;
        while m < n {
            let t = &toks[m];
            if t.kind == Kind::Punct && t.text == "(" {
                pdepth += 1;
            } else if t.kind == Kind::Punct && t.text == ")" {
                pdepth -= 1;
            } else if t.kind == Kind::Punct
                && t.text == ";"
                && pdepth <= 0
            {
                break;
            }
            rhs.push(t);
            m += 1;
        }
        let rhs_int_literal = rhs.len() == 1
            && rhs[0].kind == Kind::Num
            && num_is_int(&rhs[0].text);
        let rhs_int_ident = rhs.len() == 1
            && rhs[0].kind == Kind::Ident
            && known_int.contains(rhs[0].text.as_str())
            && !known_float.contains(rhs[0].text.as_str());
        let lhs_int = base.is_some_and(|b| {
            known_int.contains(b) && !known_float.contains(b)
        });
        if !(rhs_int_literal || rhs_int_ident || lhs_int) {
            out.push(Violation {
                line,
                rule: "float-accum",
                msg: format!(
                    "`{} += ...` may accumulate floats",
                    base.unwrap_or("?")
                ),
            });
        }
    }
    out
}

fn banned_violations(clean: &[char], toks: &[Tok]) -> Vec<Violation> {
    let mut out = Vec::new();
    for t in toks {
        if t.kind != Kind::Ident {
            continue;
        }
        let Some(&(_, rule)) =
            BANNED.iter().find(|(name, _)| *name == t.text)
        else {
            continue;
        };
        if t.text == "partial_cmp" {
            // Only the `.partial_cmp(` call form is a hazard; the
            // `fn partial_cmp` definition in a PartialOrd impl is not.
            let mut p = t.start as isize - 1;
            while p >= 0 && clean[p as usize].is_whitespace() {
                p -= 1;
            }
            if p < 0 || clean[p as usize] != '.' {
                continue;
            }
        }
        out.push(Violation {
            line: t.line,
            rule,
            msg: format!("`{}`", t.text),
        });
    }
    out
}

/// Lint one file's source. Returns the surviving violations and the
/// allows that actually suppressed something (the census).
pub fn check_source(rel: &str, src: &str) -> (Vec<Violation>, Vec<Allow>) {
    let (clean, allows) = sanitize(src);
    let toks = tokenize(&clean);
    let mut viol = banned_violations(&clean, &toks);
    if !FLOAT_ACCUM_ALLOWLIST.contains(&rel) {
        viol.extend(float_accum_violations(&toks));
    }
    // An allow at line L suppresses same-rule violations at L and L+1
    // (annotation on the violating line, or on its own line above).
    let mut used = vec![false; allows.len()];
    let mut kept = Vec::new();
    for v in viol {
        let mut suppressed = false;
        for (a_i, a) in allows.iter().enumerate() {
            if a.rule == v.rule
                && (v.line == a.line || v.line == a.line + 1)
                && !a.reason.is_empty()
            {
                used[a_i] = true;
                suppressed = true;
            }
        }
        if !suppressed {
            kept.push(v);
        }
    }
    for (a_i, a) in allows.iter().enumerate() {
        if a.reason.is_empty() {
            kept.push(Violation {
                line: a.line,
                rule: "bad-allow",
                msg: "reason required".to_string(),
            });
        } else if !used[a_i] {
            kept.push(Violation {
                line: a.line,
                rule: "stale-allow",
                msg: format!(
                    "allow for `{}` matches no violation",
                    a.rule
                ),
            });
        }
    }
    let in_force: Vec<Allow> = allows
        .into_iter()
        .zip(used)
        .filter(|(_, u)| *u)
        .map(|(a, _)| a)
        .collect();
    (kept, in_force)
}

fn collect_rs_files(
    dir: &Path,
    out: &mut Vec<PathBuf>,
) -> std::io::Result<()> {
    for entry in fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Walk `<repo_root>/rust/src`, lint every checked file, print
/// violations and the allow census. Exit status: 0 clean, 1 any
/// violation, 2 IO failure.
pub fn run(repo_root: &Path) -> i32 {
    let src_root = repo_root.join("rust").join("src");
    let mut files = Vec::new();
    if let Err(e) = collect_rs_files(&src_root, &mut files) {
        eprintln!("detlint: cannot walk {}: {e}", src_root.display());
        return 2;
    }
    files.sort();
    let mut total_v = 0usize;
    let mut total_a = 0usize;
    for path in &files {
        let rel = path
            .strip_prefix(&src_root)
            .expect("file under src_root")
            .to_string_lossy()
            .replace('\\', "/");
        if !is_checked(&rel) {
            continue;
        }
        let src = match fs::read_to_string(path) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("detlint: cannot read {rel}: {e}");
                return 2;
            }
        };
        let (mut viol, in_force) = check_source(&rel, &src);
        viol.sort_by(|a, b| {
            (a.line, a.rule, &a.msg).cmp(&(b.line, b.rule, &b.msg))
        });
        for v in &viol {
            println!("VIOLATION {rel}:{}: [{}] {}", v.line, v.rule, v.msg);
            total_v += 1;
        }
        for a in &in_force {
            println!(
                "allow     {rel}:{}: [{}] {}",
                a.line, a.rule, a.reason
            );
            total_a += 1;
        }
    }
    println!("== {total_v} violation(s), {total_a} allow(s) in force");
    if total_v > 0 {
        1
    } else {
        0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Surviving violations of `src` linted as a checked coordinator
    /// file, as (line, rule) pairs.
    fn lint(src: &str) -> Vec<(usize, &'static str)> {
        let (kept, _) = check_source("coordinator/seeded.rs", src);
        kept.into_iter().map(|v| (v.line, v.rule)).collect()
    }

    fn rules(src: &str) -> Vec<&'static str> {
        lint(src).into_iter().map(|(_, r)| r).collect()
    }

    // -- seeded violations: every rule must fire ----------------------

    #[test]
    fn seeded_hashmap_fires() {
        let src = "use std::collections::HashMap;\n\
                   fn f() { let m: HashMap<u32, u32> = HashMap::new(); }\n";
        let got = rules(src);
        assert_eq!(got, vec!["unordered-collection"; 3], "{got:?}");
    }

    #[test]
    fn seeded_hashset_and_hasher_fire() {
        assert_eq!(rules("use std::collections::HashSet;\n"),
                   vec!["unordered-collection"]);
        assert_eq!(rules("use std::collections::hash_map::RandomState;\n"),
                   vec!["unordered-collection"; 2]);
    }

    #[test]
    fn seeded_wall_clock_fires() {
        let src = "fn f() { let t = std::time::Instant::now(); }\n";
        assert_eq!(rules(src), vec!["wall-clock"]);
        let src = "fn f() { let t = std::time::SystemTime::now(); }\n";
        assert_eq!(rules(src), vec!["wall-clock"]);
    }

    #[test]
    fn seeded_ambient_random_fires() {
        assert_eq!(rules("fn f() { let mut r = rand::thread_rng(); }\n"),
                   vec!["ambient-random"]);
        assert_eq!(rules("fn f() { let mut r = OsRng; }\n"),
                   vec!["ambient-random"]);
    }

    #[test]
    fn seeded_float_accum_fires() {
        let src = "fn f(xs: &[f64]) -> f64 {\n\
                   \x20   let mut s = 0.0;\n\
                   \x20   for x in xs {\n\
                   \x20       s += x;\n\
                   \x20   }\n\
                   \x20   s\n\
                   }\n";
        assert_eq!(lint(src), vec![(4, "float-accum")]);
    }

    #[test]
    fn seeded_float_ord_fires() {
        let src = "fn f(v: &mut [f64]) {\n\
                   \x20   v.sort_by(|a, b| a.partial_cmp(b).unwrap());\n\
                   }\n";
        assert_eq!(lint(src), vec![(2, "float-ord")]);
    }

    // -- exemptions ---------------------------------------------------

    #[test]
    fn fn_partial_cmp_definition_is_exempt() {
        let src = "impl PartialOrd for X {\n\
                   \x20   fn partial_cmp(&self, o: &Self)\n\
                   \x20       -> Option<std::cmp::Ordering> {\n\
                   \x20       Some(self.cmp(o))\n\
                   \x20   }\n\
                   }\n";
        assert!(rules(src).is_empty());
    }

    #[test]
    fn integer_accumulation_is_exempt() {
        // Literal RHS, known-int ident RHS, and known-int LHS base.
        let src = "fn f(k: usize, xs: &[f64]) -> usize {\n\
                   \x20   let mut n = 0usize;\n\
                   \x20   n += 1;\n\
                   \x20   n += k;\n\
                   \x20   n += xs.len();\n\
                   \x20   n\n\
                   }\n";
        assert!(lint(src).is_empty(), "{:?}", lint(src));
    }

    #[test]
    fn num_literal_classification() {
        for t in ["0", "1", "0usize", "10u64", "1_000", "0x1f", "0b10",
                  "3i128"] {
            assert!(num_is_int(t), "{t} should be int");
        }
        for t in ["0.0", "1e-3", "1E9", "2.5", "1f64", "1f32",
                  "0.5e2"] {
            assert!(!num_is_int(t), "{t} should be float");
        }
    }

    #[test]
    fn banned_names_in_strings_and_comments_are_ignored() {
        let src = "// HashMap Instant thread_rng partial_cmp\n\
                   /* SystemTime\n   OsRng */\n\
                   fn f() -> &'static str {\n\
                   \x20   let c = 'I';\n\
                   \x20   \"HashMap via Instant\"\n\
                   }\n";
        assert!(rules(src).is_empty());
    }

    #[test]
    fn allowlisted_file_skips_float_accum_but_not_banned_names() {
        let src = "fn f(xs: &[f64]) -> f64 {\n\
                   \x20   let mut s = 0.0;\n\
                   \x20   for x in xs { s += x; }\n\
                   \x20   s\n\
                   }\n\
                   use std::collections::HashMap;\n";
        let (kept, _) = check_source("coordinator/aggregation.rs", src);
        let got: Vec<_> = kept.iter().map(|v| v.rule).collect();
        assert_eq!(got, vec!["unordered-collection"]);
    }

    #[test]
    fn scope_covers_exactly_the_critical_modules() {
        for rel in ["coordinator/engine.rs", "device/network.rs",
                    "sim/clock.rs", "runtime/mod.rs", "util/rng.rs"] {
            assert!(is_checked(rel), "{rel} must be checked");
        }
        for rel in ["model/forward.rs", "util/stats.rs", "lib.rs",
                    "data/mod.rs"] {
            assert!(!is_checked(rel), "{rel} must not be checked");
        }
    }

    // -- escape hatch -------------------------------------------------

    #[test]
    fn allow_above_line_suppresses_and_is_censused() {
        let src = "fn f(xs: &[f64]) -> f64 {\n\
                   \x20   let mut s = 0.0;\n\
                   \x20   // detlint-allow: float-accum fixed fold order\n\
                   \x20   for x in xs { s += x; }\n\
                   \x20   s\n\
                   }\n";
        let (kept, in_force) = check_source("coordinator/x.rs", src);
        assert!(kept.is_empty(), "{kept:?}");
        assert_eq!(in_force.len(), 1);
        assert_eq!(in_force[0].rule, "float-accum");
        assert_eq!(in_force[0].reason, "fixed fold order");
    }

    #[test]
    fn allow_on_same_line_suppresses() {
        let src = "fn f(xs: &[f64]) -> f64 {\n\
                   \x20   let mut s = 0.0;\n\
                   \x20   for x in xs { s += x; } \
                   // detlint-allow: float-accum fixed fold order\n\
                   \x20   s\n\
                   }\n";
        let (kept, in_force) = check_source("coordinator/x.rs", src);
        assert!(kept.is_empty(), "{kept:?}");
        assert_eq!(in_force.len(), 1);
    }

    #[test]
    fn allow_without_reason_is_rejected_and_does_not_suppress() {
        let src = "fn f(xs: &[f64]) -> f64 {\n\
                   \x20   let mut s = 0.0;\n\
                   \x20   // detlint-allow: float-accum\n\
                   \x20   for x in xs { s += x; }\n\
                   \x20   s\n\
                   }\n";
        let got = rules(src);
        assert!(got.contains(&"bad-allow"), "{got:?}");
        assert!(got.contains(&"float-accum"), "{got:?}");
    }

    #[test]
    fn stale_allow_is_rejected() {
        let src = "// detlint-allow: wall-clock nothing here uses time\n\
                   fn f() {}\n";
        assert_eq!(rules(src), vec!["stale-allow"]);
    }

    #[test]
    fn allow_for_wrong_rule_does_not_suppress() {
        let src = "fn f(xs: &[f64]) -> f64 {\n\
                   \x20   let mut s = 0.0;\n\
                   \x20   // detlint-allow: wall-clock wrong rule\n\
                   \x20   for x in xs { s += x; }\n\
                   \x20   s\n\
                   }\n";
        let got = rules(src);
        assert!(got.contains(&"float-accum"), "{got:?}");
        assert!(got.contains(&"stale-allow"), "{got:?}");
    }

    // -- the tree itself ----------------------------------------------

    /// The pass over the real tree must be clean. This is what makes
    /// plain `cargo test` (tier-1) enforce the determinism lint.
    #[test]
    fn tree_is_clean() {
        let root =
            Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/.."));
        assert_eq!(
            run(root),
            0,
            "detlint violations — run `cargo run -p xtask -- detlint` \
             and fix or annotate (docs/DETERMINISM.md)"
        );
    }
}
