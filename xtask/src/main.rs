//! Repo automation (`cargo xtask` pattern).
//!
//! Subcommands:
//!
//! * `detlint [repo-root]` — the determinism lint pass over
//!   `rust/src/` (see `detlint.rs` and `docs/DETERMINISM.md`).
//!   Exit 0 = clean, 1 = violations, 2 = usage/IO error.

use std::path::PathBuf;
use std::process::exit;

mod detlint;

fn main() {
    let mut args = std::env::args().skip(1);
    match args.next().as_deref() {
        Some("detlint") => {
            // Default to the workspace root this binary was built in,
            // so `cargo run -p xtask -- detlint` works from anywhere.
            let root = args
                .next()
                .map(PathBuf::from)
                .unwrap_or_else(|| {
                    PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/.."))
                });
            exit(detlint::run(&root));
        }
        _ => {
            eprintln!("usage: cargo run -p xtask -- detlint [repo-root]");
            exit(2);
        }
    }
}
