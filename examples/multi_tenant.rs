//! Multi-tenant coordination: three fine-tuning jobs sharing one
//! 80-device fleet through the capacity-aware job scheduler
//! (docs/MULTIJOB.md).
//!
//! Job 0 is a high-priority LEGEND run sampling a fixed 16-device
//! cohort under an ingest rate limit (token bucket: burst 8,
//! refill 4/round), job 1 a FedLoRA run sampling 20% of the fleet,
//! job 2 a small LEGEND run that releases its reservation as soon as
//! it crosses its accuracy target. A fourth job is rejected at
//! admission because the residual fleet cannot reserve its minimum
//! cohort. Every round the scheduler partitions the fleet into
//! disjoint cohorts — the example verifies that, and prints the
//! partition it recorded.
//!
//! Run:  cargo run --release --example multi_tenant

use std::collections::BTreeSet;

use legend::coordinator::participation::{UniformCount, UniformSample};
use legend::coordinator::strategy::{FedLora, Legend};
use legend::coordinator::trainer::MockTrainer;
use legend::coordinator::{AdmissionError, FedConfig, JobScheduler,
                          JobSpec, ModelMeta, RateLimit};
use legend::data::Spec;
use legend::device::{Fleet, FleetConfig};
use legend::model::state::TensorMap;
use legend::model::TensorSpec;
use legend::util::json::Value;

fn toy_spec() -> Spec {
    let json = r#"{
      "vocab_size": 256, "seq_len": 16,
      "special": {"pad": 0, "cls": 1, "mask": 2, "sep": 3},
      "filler": [4, 50], "noise": [200, 256],
      "tasks": {
        "sst2": {"kind": "single", "n_classes": 2,
                 "banks": [[50, 80], [80, 110]],
                 "len_range": [5, 10], "bank_words": [2, 4],
                 "label_noise": 0.0}
      }
    }"#;
    Spec::from_json(&Value::parse(json).unwrap()).unwrap()
}

fn global(meta: &ModelMeta) -> TensorMap {
    TensorMap::zeros(&[
        TensorSpec {
            name: "aq".into(),
            shape: vec![meta.n_layers, meta.r_max, 8],
        },
        TensorSpec { name: "head_w".into(), shape: vec![8, 2] },
    ])
}

fn main() -> anyhow::Result<()> {
    let meta = ModelMeta::synthetic(12, 16, 32);
    let spec = toy_spec();
    let fleet_cfg = FleetConfig::paper(); // 80 heterogeneous devices
    let n = fleet_cfg.total();
    let base = FedConfig {
        rounds: 12,
        train_size: 2048,
        test_size: 64,
        verbose: false,
        ..Default::default()
    };

    let mut sched = JobScheduler::new(meta.clone(), spec, n);
    sched.record_cohorts(true);

    // Job 0: priority tenant, fixed 16-device cohorts, rate-limited
    // ingest (the coordinator folds at most 8 of its updates in round
    // 1, then at most min(burst, tokens + 4) per later round).
    let mut spec0 = JobSpec::new(FedConfig { seed: 1, ..base.clone() });
    spec0.priority = 10;
    spec0.min_cohort = 16;
    spec0.rate = Some(RateLimit { burst: 8, refill: 4 });
    sched.admit(
        spec0,
        Box::new(Legend::paper(meta.n_layers, meta.r_max)),
        Box::new(MockTrainer::new("lora")),
        Box::new(UniformCount { count: 16 }),
        global(&meta),
    )?;

    // Job 1: background tenant sampling 20% of the fleet, unlimited.
    let mut spec1 = JobSpec::new(FedConfig { seed: 2, ..base.clone() });
    spec1.min_cohort = 8;
    sched.admit(
        spec1,
        Box::new(FedLora { rank: 8 }),
        Box::new(MockTrainer::new("lora")),
        Box::new(UniformSample { fraction: 0.2 }),
        global(&meta),
    )?;

    // Job 2: short job that frees its reservation once it crosses its
    // (deliberately easy) target.
    let mut spec2 = JobSpec::new(FedConfig {
        seed: 3,
        target_acc: 0.30,
        ..base.clone()
    });
    spec2.min_cohort = 4;
    spec2.stop_at_target = true;
    sched.admit(
        spec2,
        Box::new(Legend::paper(meta.n_layers, meta.r_max)),
        Box::new(MockTrainer::new("lora")),
        Box::new(UniformCount { count: 4 }),
        global(&meta),
    )?;

    // Admission control in action: with 16 + 8 + 4 devices reserved,
    // the residual is 52 — a tenant demanding 60 is turned away.
    let mut greedy = JobSpec::new(FedConfig { seed: 4, ..base.clone() });
    greedy.min_cohort = 60;
    let rejected = sched.admit(
        greedy,
        Box::new(FedLora { rank: 8 }),
        Box::new(MockTrainer::new("lora")),
        Box::new(UniformCount { count: 60 }),
        global(&meta),
    );
    match rejected {
        Err(e @ AdmissionError::InsufficientCapacity { .. }) => {
            println!("admission: rejected 4th job — {e}")
        }
        other => anyhow::bail!("expected a capacity rejection, got \
                                {other:?}"),
    }
    println!(
        "admitted {} jobs over {} devices ({} residual); starvation \
         bound P = {} rounds\n",
        sched.n_jobs(), n, sched.residual_capacity(),
        sched.starvation_bound()
    );

    let mut fleet = Fleet::new(fleet_cfg);
    let report = sched.run(&mut fleet)?;

    println!("{:<7} {:>14} {:>14} {:>14}", "round", "job0", "job1",
             "job2");
    for (h, parts) in report.cohorts.iter().enumerate() {
        let size = |id: usize| {
            parts.get(&id).map(|c| c.len().to_string())
                 .unwrap_or_else(|| "-".into())
        };
        // The invariant the scheduler guarantees: cohorts are disjoint.
        let mut seen = BTreeSet::new();
        for c in parts.values() {
            for &i in c {
                assert!(seen.insert(i),
                        "device {i} in two cohorts in round {}", h + 1);
            }
        }
        println!("{:<7} {:>14} {:>14} {:>14}", h + 1, size(0), size(1),
                 size(2));
    }

    println!();
    for (id, rec) in &report.records {
        println!(
            "job{id} ({:<22}) rounds recorded {:>2}, best acc {:.3}",
            rec.method, rec.rounds.len(), rec.best_accuracy()
        );
    }
    let t = &report.fleet_traffic;
    println!(
        "\nfleet traffic (all tenants): {} B down / {} B up / {} msgs",
        t.downlink, t.uplink, t.messages
    );
    println!(
        "job2 stops early (stop_at_target) and its 4 reserved devices \
         return to the pool; job0's rate limit caps what the \
         coordinator folds, not what it samples."
    );
    Ok(())
}
