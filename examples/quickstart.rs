//! Quickstart: the three layers composing in ~80 lines.
//!
//!  1. load the AOT artifacts (L1 Pallas kernel + L2 JAX train/eval
//!     steps, compiled once by `make artifacts`);
//!  2. run the Pallas fused LoRA-linear from rust;
//!  3. fine-tune LoRA adapters on a synthetic SST-2 shard for a few
//!     steps and evaluate.
//!
//! Run:  cargo run --release --example quickstart

use legend::data::{grammar, Spec};
use legend::model::masks::{LayerSet, LoraConfig};
use legend::model::state::{init_opt, init_trainable};
use legend::runtime::session::SessionState;
use legend::runtime::{KernelDims, Masks, Runtime};
use legend::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    // ---- 1. load artifacts -------------------------------------------------
    let mut rt = Runtime::load("artifacts")?;
    let dim = rt.manifest.dim.clone();
    println!(
        "loaded {} transformer layers, d_model={}, r_max={}",
        dim.n_layers, dim.d_model, dim.r_max
    );

    // ---- 2. the L1 Pallas kernel, straight from rust -----------------------
    let dims = KernelDims::from_manifest("artifacts")?;
    let mut rng = Rng::new(7);
    let mut gen = |n: usize| -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32 * 0.3).collect()
    };
    let (x, w) = (gen(dims.m * dims.k), gen(dims.k * dims.n));
    let (a, b) = (gen(dims.r * dims.k), gen(dims.n * dims.r));
    let y = rt.run_kernel(&x, &w, &a, &b, &vec![1.0; dims.r], 1.0, &dims)?;
    println!("pallas lora_linear ok: {} outputs", y.len());

    // ---- 3. a few LoRA fine-tuning steps ------------------------------------
    let spec = Spec::load("artifacts/vocab.json")?;
    let mut data_rng = Rng::new(1);
    let train = grammar::generate(&spec, "sst2", 128, &mut data_rng)?;
    let test = grammar::generate(&spec, "sst2", 128, &mut data_rng)?;

    // LEGEND-style configuration: LoRA on the deepest 4 layers with
    // increasing ranks (the paper's §2 insight).
    let config = LoraConfig {
        layers: LayerSet::Depth(4),
        ranks: (1..=dim.n_layers).collect(),
    };
    let masks = Masks {
        rank_mask: config.rank_mask(dim.n_layers, dim.r_max),
        layer_mask: config.layer_mask(dim.n_layers),
    };

    let mut state_rng = Rng::new(2);
    let trainable =
        init_trainable(&rt.manifest, &rt.manifest.lora, &mut state_rng);
    let opt = init_opt(&rt.manifest.lora);
    let mut session = SessionState::from_maps(&trainable, &opt)?;

    let mut step = 0f32;
    for epoch in 1..=3 {
        let mut loss = 0.0;
        let batches = train.batches(dim.batch_size);
        for (toks, labels) in &batches {
            step += 1.0;
            loss += rt
                .train_step("lora", &mut session, &masks, toks, labels,
                            5e-3, step)?
                .loss as f64;
        }
        println!("epoch {epoch}: mean loss {:.4}",
                 loss / batches.len() as f64);
    }

    let (tuned, _) = session.to_maps()?;
    let (eval_loss, acc) = rt.evaluate("lora", &tuned, &masks, &test)?;
    println!("eval: loss {eval_loss:.4}, accuracy {acc:.3}");
    Ok(())
}
