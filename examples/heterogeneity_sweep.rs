//! Heterogeneity sweep: how LCD's adaptive depths pay off as the fleet
//! gets more uneven.
//!
//! Sweeps the fraction of slow (TX2-class) devices and the WiFi group
//! spread, running the full coordinator (capacity EMA → LCD →
//! aggregation → virtual clock) with the mock trainer — zero FLOPs, so
//! the sweep covers fleets up to the paper's 80 devices in seconds.
//! Reports mean waiting time and round time, LEGEND vs FedLoRA
//! (the paper's Fig. 12 mechanism, isolated).
//!
//! Run:  cargo run --release --example heterogeneity_sweep

// Wall-clock here only reports how long the sweep itself took; it
// never feeds simulation state, so the determinism contract's
// wall-clock ban does not apply.
#![allow(clippy::disallowed_methods)]

use std::time::Instant;

use legend::coordinator::engine::effective_threads;
use legend::coordinator::participation::DeadlineDrop;
use legend::coordinator::strategy::{FedLora, Legend};
use legend::coordinator::trainer::MockTrainer;
use legend::coordinator::{run_federated, run_federated_with, FedConfig,
                          ModelMeta};
use legend::data::Spec;
use legend::device::{Fleet, FleetConfig};
use legend::model::state::TensorMap;
use legend::model::TensorSpec;
use legend::util::json::Value;

fn toy_spec() -> Spec {
    let json = r#"{
      "vocab_size": 256, "seq_len": 16,
      "special": {"pad": 0, "cls": 1, "mask": 2, "sep": 3},
      "filler": [4, 50], "noise": [200, 256],
      "tasks": {
        "sst2": {"kind": "single", "n_classes": 2,
                 "banks": [[50, 80], [80, 110]],
                 "len_range": [5, 10], "bank_words": [2, 4],
                 "label_noise": 0.0}
      }
    }"#;
    Spec::from_json(&Value::parse(json).unwrap()).unwrap()
}

fn global(meta: &ModelMeta) -> TensorMap {
    TensorMap::zeros(&[
        TensorSpec {
            name: "aq".into(),
            shape: vec![meta.n_layers, meta.r_max, 8],
        },
        TensorSpec { name: "head_w".into(), shape: vec![8, 2] },
    ])
}

fn main() -> anyhow::Result<()> {
    let meta = ModelMeta::synthetic(12, 16, 32);
    let spec = toy_spec();
    let t0 = Instant::now();
    // threads: 0 → the RoundEngine trains mock devices on every
    // available core; results are bit-identical to a 1-thread run.
    let cfg = FedConfig {
        rounds: 30,
        train_size: 4096,
        test_size: 64,
        verbose: false,
        ..Default::default()
    };

    println!(
        "{:<28} {:>10} {:>10} {:>10} {:>9}",
        "fleet (tx2/nx/agx)", "LEG wait", "FL wait", "LEG round",
        "FL round"
    );
    // Sweep slow-device share at the paper's 80-device scale.
    for tx2_share in [0usize, 20, 30, 50, 70] {
        let n = 80;
        let n_tx2 = n * tx2_share / 100;
        let n_agx = 10.min(n - n_tx2);
        let fleet_cfg = FleetConfig {
            n_tx2,
            n_nx: n - n_tx2 - n_agx,
            n_agx,
            ..FleetConfig::paper()
        };
        let mut results = Vec::new();
        for legend_on in [true, false] {
            let mut fleet = Fleet::new(fleet_cfg.clone());
            let mut trainer = MockTrainer::new("lora");
            let rec = if legend_on {
                let mut s = Legend::paper(meta.n_layers, meta.r_max);
                run_federated(&cfg, &mut fleet, &mut s, &mut trainer,
                              &meta, &spec, global(&meta))?
            } else {
                let mut s = FedLora { rank: 8 };
                run_federated(&cfg, &mut fleet, &mut s, &mut trainer,
                              &meta, &spec, global(&meta))?
            };
            results.push(rec);
        }
        let (leg, fl) = (&results[0], &results[1]);
        println!(
            "{:<28} {:>9.1}s {:>9.1}s {:>9.1}s {:>8.1}s",
            format!("{}/{}/{}", fleet_cfg.n_tx2, fleet_cfg.n_nx,
                    fleet_cfg.n_agx),
            leg.mean_waiting(),
            fl.mean_waiting(),
            leg.total_time() / cfg.rounds as f64,
            fl.total_time() / cfg.rounds as f64,
        );
    }
    println!(
        "\nLEGEND's waiting-time advantage grows with heterogeneity \
         (paper Fig. 12); with a homogeneous fleet the two converge."
    );
    println!(
        "sweep wall-clock: {:.2}s on {} worker thread(s)",
        t0.elapsed().as_secs_f64(),
        effective_threads(cfg.threads)
    );

    // Semi-synchronous variant: drop predicted stragglers at
    // 1.25×median (eq. 12 deadline) and compare round time.
    let mut fleet = Fleet::new(FleetConfig::paper());
    let mut trainer = MockTrainer::new("lora");
    let mut s = FedLora { rank: 8 };
    let full = {
        let mut fleet = Fleet::new(FleetConfig::paper());
        let mut tr = MockTrainer::new("lora");
        let mut s = FedLora { rank: 8 };
        run_federated(&cfg, &mut fleet, &mut s, &mut tr, &meta, &spec,
                      global(&meta))?
    };
    let semi = run_federated_with(&cfg, &mut fleet, &mut s, &mut trainer,
                                  &meta, &spec, global(&meta),
                                  &mut DeadlineDrop::new(1.25))?;
    println!(
        "semi-sync (deadline 1.25×median): round {:.1}s → {:.1}s, \
         mean participation {:.1}/{} (dropped {} device-rounds)",
        full.total_time() / cfg.rounds as f64,
        semi.total_time() / cfg.rounds as f64,
        semi.mean_participation(),
        fleet.len(),
        semi.total_dropped(),
    );

    // Fully async variant: no barrier at all — devices fold whenever
    // they finish, staleness-weighted by 1/(1+τ)^α, and a commit
    // window never waits for anything older than max_staleness
    // versions. Same seed, same fleet: only the round discipline
    // changes.
    let async_cfg = FedConfig {
        async_mode: true,
        staleness_alpha: 0.5,
        max_staleness: 2,
        ..cfg.clone()
    };
    let mut fleet = Fleet::new(FleetConfig::paper());
    let mut trainer = MockTrainer::new("lora");
    let mut s = FedLora { rank: 8 };
    let asy = run_federated(&async_cfg, &mut fleet, &mut s, &mut trainer,
                            &meta, &spec, global(&meta))?;
    println!(
        "async (α=0.5, S=2): commit window {:.1}s vs barrier round \
         {:.1}s, mean folds/window {:.1}/{} — stale folds ride across \
         window boundaries instead of stalling the fleet",
        asy.total_time() / async_cfg.rounds as f64,
        full.total_time() / cfg.rounds as f64,
        asy.mean_participation(),
        fleet.len(),
    );
    Ok(())
}
