//! End-to-end driver (EXPERIMENTS.md §E2E): full federated fine-tuning
//! of the transformer on synthetic SST-2 with a heterogeneous fleet,
//! LEGEND vs FedLoRA, several hundred real gradient steps through the
//! PJRT runtime. Logs the loss curve and accuracy-vs-virtual-time, and
//! writes results/e2e_sst2.csv.
//!
//! Run:  cargo run --release --example fedft_sst2 [-- --rounds 25]

use legend::coordinator::FedConfig;
use legend::device::FleetConfig;
use legend::exp::{shared_target, ExpEnv};
use legend::metrics;
use legend::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let rounds = args.get_parse("rounds", 20usize)?;
    let devices = args.get_parse("devices", 10usize)?;

    let env = ExpEnv::load("artifacts")?;
    let cfg = FedConfig {
        task: "sst2".into(),
        rounds,
        train_size: 1024,
        test_size: 256,
        verbose: true,
        ..Default::default()
    };
    let fleet = FleetConfig::sized(devices);

    println!("== e2e federated fine-tuning: {devices} devices, {rounds} \
              rounds, real gradients via PJRT ==\n");
    let mut runs = Vec::new();
    for method in ["legend", "fedlora"] {
        println!("--- {method} ---");
        let rec = env.run_method(method, &cfg, &fleet)?;
        let steps: usize = rec.rounds.len() * devices * cfg.max_batches;
        println!(
            "{method}: ~{steps} device-steps, final acc {:.3}\n",
            rec.final_accuracy()
        );
        runs.push(rec);
    }

    let target = shared_target(&runs);
    println!("loss curve (train_loss by round):");
    for r in &runs {
        let curve: Vec<String> = r
            .rounds
            .iter()
            .step_by(2)
            .map(|x| format!("{:.2}", x.train_loss))
            .collect();
        println!("  {:<10} {}", r.method, curve.join(" "));
    }
    println!("\n{}", metrics::summary_table(&runs, target));
    if let (Some(tl), Some(tf)) = (
        runs[0].time_to_accuracy(target),
        runs[1].time_to_accuracy(target),
    ) {
        println!("LEGEND speedup to target: {:.2}× (paper band 1.5–2.8×)",
                 tf / tl);
    }
    let path = metrics::write_csv("e2e_sst2", &runs)?;
    println!("wrote {path}");
    Ok(())
}
