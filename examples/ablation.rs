//! Ablation (paper Fig. 13): LEGEND vs LEGEND w/o LoRA-depth vs
//! LEGEND w/o rank-distribution, on synthetic SST-2 with real
//! gradients. Shows both factors matter, in different ways: w/o LD
//! keeps accuracy but pays time; w/o RD keeps time but loses accuracy.
//!
//! Run:  cargo run --release --example ablation [-- --rounds 15]

use legend::coordinator::FedConfig;
use legend::device::FleetConfig;
use legend::exp::{shared_target, ExpEnv};
use legend::metrics;
use legend::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let rounds = args.get_parse("rounds", 15usize)?;

    let env = ExpEnv::load("artifacts")?;
    let cfg = FedConfig {
        task: "sst2".into(),
        rounds,
        train_size: 1024,
        test_size: 256,
        verbose: true,
        ..Default::default()
    };
    let fleet = FleetConfig::sized(10);

    let mut runs = Vec::new();
    for method in ["legend", "legend-no-ld", "legend-no-rd"] {
        println!("--- {method} ---");
        runs.push(env.run_method(method, &cfg, &fleet)?);
    }
    let target = shared_target(&runs);
    println!("\n{}", metrics::summary_table(&runs, target));
    println!("expected shape (paper §6.3): w/o LD ≈ LEGEND accuracy but \
              slower; w/o RD faster than w/o LD but lower accuracy.");
    let path = metrics::write_csv("ablation_sst2", &runs)?;
    println!("wrote {path}");
    Ok(())
}
